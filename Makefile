# Test entry points.  `make smoke` is the fast inner-loop subset (no
# multi-device subprocesses, no end-to-end transformer training); `make
# tier1` is the full suite ROADMAP.md names as the verify gate.  The
# subprocess-heavy tests spawn children with
# --xla_force_host_platform_device_count and are bounded by `timeout`.
PYTEST := env PYTHONPATH=src timeout

SMOKE_TIMEOUT ?= 300
TIER1_TIMEOUT ?= 900

.PHONY: smoke tier1 bench strategies elastic hybrid comm kernels serve obs \
	bench-regress

# Fast subset: pure-host unit tests (collectives shim units, compression,
# schedulers, configs, models). ~1 min.
smoke:
	$(PYTEST) $(SMOKE_TIMEOUT) python -m pytest -q -x \
	    tests/test_compression.py tests/test_comm_scheduler.py \
	    tests/test_configs.py tests/test_specs.py tests/test_sched.py \
	    tests/test_data_parallel.py -k "not 8dev"

# Strategy-matrix gate: every registered (sync x arch x compression) cell
# runs 2 steps on 2 virtual devices (see docs/strategies.md); fails if a
# registered cell is untested or broken.
strategies:
	$(PYTEST) $(SMOKE_TIMEOUT) python tools/strategy_smoke.py

# Elasticity gate: one crash, one resize, one straggler, and one
# scheduler-trace-driven scenario on 2 virtual devices
# (see docs/elasticity.md); fails if any scenario can't recover.
elastic:
	$(PYTEST) $(SMOKE_TIMEOUT) python tools/elastic_smoke.py

# Hybrid-parallel gate: representative mesh x ZeRO cells (data x tensor
# x stage, ZeRO-1/2/3, sgd + adamw, compressed data axis) on 8 virtual
# devices (see docs/hybrid.md); uncompressed sgd cells are cross-checked
# against the single-device stacked reference.
hybrid:
	$(PYTEST) $(SMOKE_TIMEOUT) python tools/hybrid_smoke.py

# Communication-plane gate: every topology x codec cell with encoded
# payloads inside the schedule (wire=measured) on 4 virtual devices,
# with the measured-vs-modeled byte assertion (see docs/comm.md).
comm:
	$(PYTEST) $(SMOKE_TIMEOUT) python tools/comm_smoke.py

# Kernel-backend gate: every codec x backend cell on 4 virtual devices
# (ref vs kernel: losses in band, wire bytes bitwise) plus one
# flash-attention fwd/grad/decode cell, all in interpret mode
# (see docs/kernels.md).
kernels:
	$(PYTEST) $(SMOKE_TIMEOUT) python tools/kernel_smoke.py

# Serving gate: paged/contiguous/seed-loop token equivalence,
# continuous-vs-oneshot latency win, pool-exhaustion stalls, the
# autoscale->sched->elastic plan loop, and a 2-virtual-device
# tensor-parallel decode cell (see docs/serving.md).
serve:
	$(PYTEST) $(SMOKE_TIMEOUT) python tools/serve_smoke.py

# Observability gate: a traced bsp/ring/onebit@8 run on 8 virtual
# devices (well-formed Chrome trace, step->exchange->bucket nesting,
# same-seed byte identity, analyzer attribution + overlap bounds), a
# traced d2.t2.s2 pipeline run (measured vs analytic bubble fraction),
# and a traced serve episode (request lifecycles, KV occupancy, stall
# instants, SLO burn alert); see docs/observability.md.
obs:
	$(PYTEST) $(SMOKE_TIMEOUT) python tools/obs_smoke.py

# Bench-lineage gate: the newest committed BENCH_pr<N>.json vs its
# predecessors on the keyed deterministic metrics (wire bytes, seeded
# loss bands, modeled times, virtual-clock latencies); see
# docs/observability.md "Analysis & SLOs".
bench-regress:
	$(PYTEST) $(SMOKE_TIMEOUT) python tools/bench_regress.py

# Full tier-1 verify (ROADMAP.md): the strategy-matrix, elasticity,
# hybrid-mesh, comm-plane, kernel-backend, serving, observability, and
# bench-lineage gates plus everything in tests/, including the
# 8-virtual-device subprocess tests and end-to-end training
# compositions.
tier1: strategies elastic hybrid comm kernels serve obs bench-regress
	$(PYTEST) $(TIER1_TIMEOUT) python -m pytest -q

bench:
	env PYTHONPATH=src python -m benchmarks.run
