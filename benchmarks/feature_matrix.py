"""Survey Table 3 (framework comparison), applied to `repro` itself —
prints the feature matrix in the survey's own vocabulary, proving which
taxonomy entries this framework implements."""
from __future__ import annotations

from benchmarks.common import emit

FEATURES = [
    ("distribution", "centralized(PS:RS+AG) + decentralized(ring/tree/"
                     "butterfly/fc) + federated(FedAvg)"),
    ("synchronization", "sync(BSP) + bounded-async(SSP) + async(ASP) "
                        "+ SMA"),
    ("model_quantization", "bf16 policy + stochastic rounding (Gupta[55])"),
    ("gradient_quantization", "1bit-EF(Seide[159]) + TernGrad[190] "
                              "+ QSGD[8] + DGC topk[106]"),
    ("communication_scheduling", "TicTac[60]-style ordering + bucketing"),
    ("parallelism", "data + tensor(model) + pipeline(GPipe[70]) + hybrid "
                    "+ expert(MoE)"),
    ("multi_tenant_scheduling", "FIFO/SRTF/Optimus[141]/SLAQ[205]/"
                                "Gandiva[195] simulator"),
    ("data_management", "sharded loader + prefetch + Hoard[142] cache "
                        "+ Dirichlet non-IID"),
    ("model_management", "sharded npz checkpoints + ModelDB[177] registry"),
    ("architectures", "dense/MoE/MLA/VLM/audio-encdec/RG-LRU-hybrid/RWKV6 "
                      "(10 configs x 4 shapes)"),
    ("kernels", "Pallas: flash-attention + 4 compression kernels "
                "(interpret-validated)"),
    ("dry_run", "16x16 and 2x16x16 meshes, 78/78 lower+compile"),
]


def main():
    rows = [("feature_matrix.feature", "supported", "detail")]
    for name, detail in FEATURES:
        rows.append((f"feature_matrix.{name}", 1, detail))
    emit(rows)


if __name__ == "__main__":
    main()
