"""Shared benchmark helpers: a small real transformer + timing utils."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model


def small_lm(arch: str = "tinyllama-1.1b", seq_len: int = 32,
             batch_size: int = 8):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                        batch_size=batch_size)
    batches = make_lm_batches(data)

    def grad_fn(p, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
            has_aux=True)(p)
        return loss, g

    return cfg, model, params, batches, grad_fn


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows: List[Tuple]):
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)


def emit_json(rows: List[Dict]):
    """One JSON object per line — the format BENCH_*.json files collect
    when a benchmark reports a keyed matrix rather than a flat CSV."""
    for r in rows:
        print(json.dumps(r, sort_keys=True), flush=True)
