"""Survey Table 2: communication-efficiency methods.

For each compression method: bytes on the wire per step (the method's
point), compression ratio vs fp32, and final loss after the same number of
BSP steps (the accuracy cost) on the same reduced transformer.
"""
from __future__ import annotations

from repro.core import Compressor
from repro.train import Strategy

from benchmarks.common import emit, small_lm

STEPS = 12


def main(steps: int = STEPS):
    _, _, params, batches, grad_fn = small_lm()
    rows = [("table2_compression.method", "wire_MB_per_step",
             "ratio_vs_fp32,final_loss")]
    base_wire = None
    for method in ("none", "onebit", "terngrad", "qsgd", "dgc"):
        comp = Compressor(method, density=0.01)
        eng = Strategy(sync="bsp", workers=2, lr=0.02, compression=comp,
                       backend="sim").build(grad_fn)
        _, hist, wire = eng.run(params, batches, steps)
        per_step = wire / steps / 2 / 1e6     # per worker per step
        if method == "none":
            base_wire = per_step
        rows.append((f"table2_compression.{method}", round(per_step, 4),
                     f"{round(base_wire / per_step, 1)}x,"
                     f"{round(hist[-1]['loss'], 4)}"))
    emit(rows)


if __name__ == "__main__":
    main()
