"""Survey §3.4.2: multi-tenant scheduling policies on a loaded cluster
trace — avg JCT / makespan / time-to-90%-quality per policy (the metrics
Optimus, Gandiva, and SLAQ optimize)."""
from __future__ import annotations

from repro.sched import Cluster, make_trace, simulate

from benchmarks.common import emit


def main():
    jobs = make_trace(80, 16, seed=7, mean_interarrival=8.0)
    rows = [("scheduler.policy", "avg_jct_s", "makespan_s,t90_s")]
    for policy in ("fifo", "srtf", "optimus", "slaq"):
        r = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=8),
                     policy=policy)
        rows.append((f"scheduler.{policy}", round(r.avg_jct, 0),
                     f"{round(r.makespan, 0)},{round(r.mean_t90, 0)}"))
    r = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=8), policy="fifo",
                 gandiva=True)
    rows.append(("scheduler.fifo+gandiva", round(r.avg_jct, 0),
                 f"{round(r.makespan, 0)},{round(r.mean_t90, 0)}"))
    emit(rows)


if __name__ == "__main__":
    main()
