"""Communication-plane benchmark: compressed payloads inside the
collective schedule (docs/comm.md).

One JSON row per (topology × codec × kernel_backend) cell on 4 virtual
host devices, training the tiny regression problem for a few BSP steps:

  * ``modeled_wire`` — the compressor's analytic per-push accounting
    (what the simulator reports; the ``wire="modeled"`` increment),
    measured once per cell (it is backend-independent by construction);
  * ``measured_wire`` — bytes counted from the encoded planes actually
    exchanged inside the schedule (``wire="measured"``), plus the static
    per-worker/step tx and its ratio to the fp32 schedule.  Reported per
    kernel backend (ref = jnp oracle, kernel = Pallas interpret mode on
    CPU) — the bytes must agree bitwise across backends, the wall time
    differs;
  * ``step_us`` — wall time per measured-mode step (jit-compiled).

  PYTHONPATH=src python -m benchmarks.comm_plane_bench
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit_json

TOPOLOGIES = ("ring", "tree", "butterfly", "fully_connected")
CODECS = ("none", "onebit", "terngrad", "qsgd", "dgc")

_CHILD = r"""
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.train import Strategy

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (64, 1))
def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 64))
    return {"X": X, "y": X @ W_TRUE}
def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)
P0 = {"W": jnp.zeros((64, 1)), "b": jnp.zeros((4096,))}

def run(spec, wire, kb):
    eng = Strategy.parse(spec, lr=0.05, backend="device", wire=wire,
                         kernel_backend=kb).build(grad_fn)
    st = eng.init(P0)
    st, _ = eng.step(st, make_batch, 0)          # compile
    t0 = time.perf_counter()
    for t in range(1, 4):
        st, ev = eng.step(st, make_batch, t)
    dt = (time.perf_counter() - t0) / 3 * 1e6
    return st, ev, eng.metrics(), dt

rows = []
for topology in %(topologies)s:
    for codec in %(codecs)s:
        comp = "dgc:0.1" if codec == "dgc" else codec
        spec = f"bsp/{topology}/{comp}@4"
        st_m, _, _, _ = run(spec, "modeled", "ref")
        for kb in ("ref", "kernel"):
            st, ev, m, dt = run(spec, "measured", kb)
            rows.append({
                "bench": "comm_plane", "spec": spec,
                "topology": topology, "codec": codec,
                "kernel_backend": kb,
                "modeled_wire": st_m["wire"],
                "measured_wire": st["wire"],
                "step_us": round(dt, 1),
                "tx_bytes_per_worker_step": m["measured_step_tx_bytes"],
                "fp32_tx_bytes_per_worker_step": m["fp32_step_tx_bytes"],
                "tx_ratio_vs_fp32": round(
                    m["measured_step_tx_bytes"] / m["fp32_step_tx_bytes"],
                    4),
                "loss_final": float(ev[-1]["loss"]),
            })
        a, b = rows[-2], rows[-1]
        assert a["measured_wire"] == b["measured_wire"], (spec, a, b)
print("ROWS " + json.dumps(rows))
"""


def main() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    child = _CHILD % {"topologies": repr(list(TOPOLOGIES)),
                      "codecs": repr(list(CODECS))}
    res = subprocess.run([sys.executable, "-c", child], env=env,
                         capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        sys.stderr.write(res.stderr[-2000:])
        raise RuntimeError("comm_plane_bench child failed")
    for line in res.stdout.splitlines():
        if line.startswith("ROWS "):
            emit_json(json.loads(line[5:]))
            return
    raise RuntimeError("comm_plane_bench child produced no rows")


if __name__ == "__main__":
    main()
