"""Survey §3.3.3(3): communication scheduling (TicTac) + bucketing —
projected iteration time for a command-r-scale backward pass under
no-overlap / random order / TicTac order, and the bucket-size sweep."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.comm_scheduler import (LayerCost, LinkModel, bucketize,
                                       random_order, schedule_no_overlap,
                                       schedule_overlap, tictac_order)
from repro.launch.mesh import ICI_BW_PER_LINK, PEAK_FLOPS_BF16

from benchmarks.common import emit


def _layers_for(arch="command-r-35b", chips=256):
    cfg = get_config(arch)
    per_layer = cfg.param_count() / cfg.num_layers
    grad_bytes = per_layer * 4 / chips          # fp32 grads, sharded
    back_s = 4 * per_layer * 4096 / chips / PEAK_FLOPS_BF16
    return [LayerCost(f"L{i}", back_s, grad_bytes)
            for i in range(cfg.num_layers)]


def main():
    link = LinkModel(alpha_s=5e-6, beta_Bps=ICI_BW_PER_LINK)
    ls = _layers_for()
    rows = [("comm_schedule.variant", "iter_ms", "speedup_vs_no_overlap")]
    t_no = schedule_no_overlap(ls, link)
    t_rand = schedule_overlap(ls, link, random_order(ls, 0))
    t_tictac = schedule_overlap(ls, link, tictac_order(ls))
    for name, t in [("no_overlap", t_no), ("random_order", t_rand),
                    ("tictac_order", t_tictac)]:
        rows.append((f"comm_schedule.{name}", round(t * 1e3, 3),
                     round(t_no / t, 2)))
    # bucket sweep in the latency-bound regime
    slow = LinkModel(alpha_s=5e-4, beta_Bps=ICI_BW_PER_LINK)
    for mb in (1, 8, 64):
        bs = bucketize(ls, mb * 1e6)
        t = schedule_overlap(bs, slow, tictac_order(bs))
        rows.append((f"comm_schedule.bucket_{mb}MB", round(t * 1e3, 3),
                     f"n_buckets={len(bs)}"))
    emit(rows)


if __name__ == "__main__":
    main()
