"""Survey Table 1: parameter-synchronization models.

Trains the same reduced transformer under BSP / SSP(s) / ASP / SMA with
deterministic heterogeneous workers and reports final loss, observed max
staleness, and events — the convergence-vs-staleness trade-off the table
categorizes.
"""
from __future__ import annotations

from repro.train import Strategy

from benchmarks.common import emit, small_lm

STEPS = 12
WORKERS = 4
PERIODS = (1, 2, 3, 5)     # heterogeneous speeds -> stragglers exist


def main(steps: int = STEPS):
    _, _, params, batches, grad_fn = small_lm()
    rows = [("table1_sync.mode", "final_loss", "max_staleness,events")]
    for mode, kw in [("bsp", {}), ("ssp", dict(staleness=1)),
                     ("ssp", dict(staleness=4)), ("asp", {}), ("sma", {})]:
        eng = Strategy(sync=mode, workers=WORKERS, lr=0.02,
                       periods=PERIODS, backend="sim", **kw).build(grad_fn)
        _, hist, _ = eng.run(params, batches, steps)
        label = mode if mode != "ssp" else f"ssp(s={kw['staleness']})"
        stale = max(h["max_staleness"] for h in hist)
        rows.append((f"table1_sync.{label}", round(hist[-1]["loss"], 4),
                     f"{stale},{len(hist)}"))
    emit(rows)


if __name__ == "__main__":
    main()
