"""Survey §3.3.1(3): FedAvg under IID vs non-IID partitions — reproduces
the Nilsson et al. [130] finding that non-IID degrades federated averaging
relative to the IID / centralized regime."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.federated import FedConfig, run_fedavg
from repro.data.partition import (dirichlet_partition, iid_partition,
                                  label_skew, make_classification_data)

from benchmarks.common import emit

N, DIM, CLASSES, CLIENTS = 2000, 16, 8, 10
ROUNDS = 15


def _mlp_init(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (DIM, 32)) * 0.2,
            "w2": jax.random.normal(k2, (32, CLASSES)) * 0.2}


def _grad_fn(params, batch):
    def loss(p):
        h = jnp.tanh(batch["X"] @ p["w1"])
        logits = h @ p["w2"]
        logz = jax.nn.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, batch["y"][:, None], 1)[:, 0]
        return jnp.mean(logz - ll)
    return jax.value_and_grad(loss)(params)


def _clients(X, y, parts, batch=32):
    out = []
    for idx in parts:
        def fn(step, idx=idx):
            rng = np.random.RandomState(step)
            sel = idx[rng.randint(0, len(idx), size=min(batch, len(idx)))]
            return {"X": jnp.asarray(X[sel]), "y": jnp.asarray(y[sel])}
        out.append(fn)
    return out


def main(rounds: int = ROUNDS):
    X, y = make_classification_data(N, DIM, CLASSES, seed=0)
    cfg = FedConfig(num_clients=CLIENTS, clients_per_round=5, local_steps=4,
                    local_lr=0.1)
    rows = [("federated.partition", "final_loss", "label_skew_tv")]
    for name, parts in [
            ("iid", iid_partition(N, CLIENTS, seed=0)),
            ("dirichlet_a1.0", dirichlet_partition(y, CLIENTS, 1.0, seed=0)),
            ("dirichlet_a0.1", dirichlet_partition(y, CLIENTS, 0.1, seed=0))]:
        p0 = _mlp_init(jax.random.PRNGKey(1))
        _, hist = run_fedavg(p0, _clients(X, y, parts), _grad_fn, cfg, rounds)
        rows.append((f"federated.{name}", round(hist[-1]["loss"], 4),
                     round(label_skew(parts, y), 3)))
    emit(rows)


if __name__ == "__main__":
    main()
