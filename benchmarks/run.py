"""Benchmark harness — one module per survey table/figure.

Prints ``name,value,derived`` CSV rows per benchmark.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table1     # substring filter
"""
from __future__ import annotations

import sys
import time
import traceback

BENCHES = [
    ("table1_sync", "benchmarks.table1_sync"),          # survey Table 1
    ("table2_compression", "benchmarks.table2_compression"),  # Table 2
    ("feature_matrix", "benchmarks.feature_matrix"),    # Table 3
    ("topology", "benchmarks.topology_bench"),          # §3.3.1(2)
    ("architecture", "benchmarks.architecture_bench"),  # §3.3.1(1) vs (2)
    ("federated", "benchmarks.federated_bench"),        # §3.3.1(3)
    ("comm_schedule", "benchmarks.comm_schedule_bench"),  # §3.3.3(3)
    ("comm_plane", "benchmarks.comm_plane_bench"),  # codec-in-schedule
    ("data_parallel", "benchmarks.data_parallel_bench"),  # §3.3 executable
    ("hybrid", "benchmarks.hybrid_bench"),              # §3.2 mesh x ZeRO
    ("scheduler", "benchmarks.scheduler_bench"),        # §3.4.2
    ("elastic", "benchmarks.elastic_bench"),            # §3.2.3 / §3.4.2
    ("kernel", "benchmarks.kernel_bench"),              # §3.3.3 hot spots
    ("serve", "benchmarks.serve_bench"),                # §5 serving plane
]


def main() -> None:
    flt = sys.argv[1] if len(sys.argv) > 1 else ""
    failures = []
    for name, module in BENCHES:
        if flt and flt not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILED: {failures}")
        raise SystemExit(1)
    print("# all benchmarks ok")


if __name__ == "__main__":
    main()
