"""Benchmark harness — one module per survey table/figure.

Prints ``name,value,derived`` CSV rows per benchmark.  Machine-readable
rows are single-line JSON objects starting with ``{`` (the BENCH_pr*.json
convention: ``python -m benchmarks.run <filter> | grep '^{'``); the
harness validates that every such row actually parses, so a benchmark
that prints a torn/malformed object fails loudly instead of silently
corrupting the committed BENCH file.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table1     # substring filter
"""
from __future__ import annotations

import io
import json
import sys
import time
import traceback

BENCHES = [
    ("table1_sync", "benchmarks.table1_sync"),          # survey Table 1
    ("table2_compression", "benchmarks.table2_compression"),  # Table 2
    ("feature_matrix", "benchmarks.feature_matrix"),    # Table 3
    ("topology", "benchmarks.topology_bench"),          # §3.3.1(2)
    ("architecture", "benchmarks.architecture_bench"),  # §3.3.1(1) vs (2)
    ("federated", "benchmarks.federated_bench"),        # §3.3.1(3)
    ("comm_schedule", "benchmarks.comm_schedule_bench"),  # §3.3.3(3)
    ("comm_plane", "benchmarks.comm_plane_bench"),  # codec-in-schedule
    ("data_parallel", "benchmarks.data_parallel_bench"),  # §3.3 executable
    ("hybrid", "benchmarks.hybrid_bench"),              # §3.2 mesh x ZeRO
    ("scheduler", "benchmarks.scheduler_bench"),        # §3.4.2
    ("elastic", "benchmarks.elastic_bench"),            # §3.2.3 / §3.4.2
    ("kernel", "benchmarks.kernel_bench"),              # §3.3.3 hot spots
    ("serve", "benchmarks.serve_bench"),                # §5 serving plane
]


class _RowChecker(io.TextIOBase):
    """Tee for a benchmark's stdout that validates machine-readable rows:
    every line starting with ``{`` must parse as a single JSON object
    (the rows ``grep '^{'`` harvests into BENCH_pr*.json)."""

    def __init__(self, out):
        self.out = out
        self._buf = ""
        self.json_rows = 0
        self.malformed: list = []

    def write(self, s: str) -> int:
        self.out.write(s)
        self._buf += s
        while "\n" in self._buf:
            line, self._buf = self._buf.split("\n", 1)
            self._check(line)
        return len(s)

    def flush(self) -> None:
        self.out.flush()

    def finish(self) -> None:
        if self._buf:            # unterminated last line still counts
            self._check(self._buf)
            self._buf = ""

    def _check(self, line: str) -> None:
        if not line.startswith("{"):
            return
        try:
            json.loads(line)
            self.json_rows += 1
        except ValueError:
            self.malformed.append(line[:200])


def main() -> None:
    flt = sys.argv[1] if len(sys.argv) > 1 else ""
    failures = []
    for name, module in BENCHES:
        if flt and flt not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        checker = _RowChecker(sys.stdout)
        sys.stdout = checker
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        finally:
            checker.finish()
            sys.stdout = checker.out
        if checker.malformed:
            if name not in failures:
                failures.append(name)
            print(f"# {name}: {len(checker.malformed)} malformed JSON "
                  f"row(s):", flush=True)
            for bad in checker.malformed:
                print(f"#   {bad!r}", flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s "
              f"({checker.json_rows} json rows)", flush=True)
    if failures:
        print(f"# FAILED: {failures}")
        raise SystemExit(1)
    print("# all benchmarks ok")


if __name__ == "__main__":
    main()
