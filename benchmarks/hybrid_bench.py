"""Hybrid-parallel benchmark: mesh × ZeRO × schedule × precision cells
(§3.2 / docs/hybrid.md).

One JSON row per mesh cell on 8 virtual host devices, tracking the
quantities the hybrid subsystem trades against each other:

  * measured step wall time (post-compile).  NOTE: virtual host devices
    time-share one CPU, so the pipeline-schedule win does NOT appear
    here — every "parallel" stage serializes onto the same core;
  * the modeled per-device critical path (``modeled_stage_units``:
    schedule ticks × per-tick stage work) and analytic bubble, where the
    1F1B rows must beat their GPipe twin, asserted;
  * wire accounting: the data-axis exchange plus the modeled ring-
    schedule bytes and the pipeline/tensor activation traffic,
  * measured per-device persistent state bytes (params + optimizer) —
    the ZeRO rows must show ~the data-axis-factor reduction, and the
    quantized-moment (qmom) AdamW row ~half the fp32 moment bytes,
    both asserted.

  PYTHONPATH=src python -m benchmarks.hybrid_bench                 # default matrix
  PYTHONPATH=src python -m benchmarks.hybrid_bench bsp/ring/none@8:d2.t2.s2 ...
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit_json

DEFAULT_SPECS = [
    "bsp/ring/none@8:d8",
    "bsp/ring/none@8:d4.s2",
    "bsp/ring/none@8:d4.t2",
    "bsp/ring/none@8:d2.t2.s2",
    "bsp/ring/onebit@8:d2.t2.s2",
    "bsp/ring/none@8:d8.adamw",
    "bsp/ps/none@8:d8.z1.adamw",
    "bsp/ps/none@8:d8.z2.adamw",
    "bsp/ps/none@8:d8.z3.adamw",
    "bsp/ps/none@8:d2.t2.s2.z3.adamw",
    # schedule × precision plane: gpipe twin first — the 1f1b rows
    # assert their modeled critical path against it
    "bsp/ring/none@8:d2.t2.s2.m8",
    "bsp/ring/none@8:d2.t2.s2.m8.1f1b",
    "bsp/ring/none@8:d2.t2.s2.m8.1f1b.bf16",
    "bsp/ps/none@8:d8.z2.qmom.adamw",
]

_CHILD = r"""
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from repro.core.pipeline import (bubble_fraction, gpipe_ticks,
                                 onefb_bubble_fraction, onefb_ticks)
from repro.parallel import make_tiny_transformer
from repro.train import Strategy

S_LAYERS, D_MODEL, FF = 4, 32, 64
params, model = make_tiny_transformer(S_LAYERS, D_MODEL, FF, seed=0)
KEY = jax.random.PRNGKey(1)
W_T = jax.random.normal(KEY, (D_MODEL, D_MODEL))
def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    x = jax.random.normal(k, (16, D_MODEL))
    return {"x": x, "y": jnp.tanh(x @ W_T)}

STEPS = 3
baseline_bytes = {}
stage_units = {}     # (mesh, micro) -> gpipe modeled critical path
opt_bytes = {}       # (zero, optimizer, mesh) -> fp32-moment opt bytes
for spec in sys.argv[1:]:
    strat = Strategy.parse(spec, lr=0.01, bucket_mb=1e-3, backend="device")
    engine = strat.build(model)
    st = engine.init(params)
    # one step to compile, then timed steps
    st, _ = engine.inner.step(st, make_batch, 0)
    t0 = time.perf_counter()
    hist = []
    for t in range(1, 1 + STEPS):
        st, ev = engine.inner.step(st, make_batch, t)
        hist.extend(ev)
    step_us = (time.perf_counter() - t0) / STEPS * 1e6
    mets = engine.metrics()
    state = engine.inner.per_device_state_bytes(st)
    mesh = strat.mesh_spec
    key = (strat.optimizer, mesh.tensor, mesh.stage)
    if strat.zero == 0:
        baseline_bytes[key] = state["total"]
    row = {
        "bench": "hybrid",
        "strategy": strat.spec(),
        "mesh": mesh.spec(), "data": mesh.data, "tensor": mesh.tensor,
        "stage": mesh.stage, "zero": strat.zero,
        "optimizer": strat.optimizer,
        "compression": strat.compressor.method,
        "step_time_us": round(step_us, 1),
        "wire_bytes_per_step": engine.inner.wire_bytes() // (STEPS + 1),
        "modeled_data_bytes_per_dev": mets.get("modeled_data_bytes_per_dev"),
        "modeled_pipeline_bytes_per_dev":
            mets.get("modeled_pipeline_bytes_per_dev", 0),
        "modeled_tensor_bytes_per_dev":
            mets.get("modeled_tensor_bytes_per_dev", 0),
        "state_bytes_per_dev": state["total"],
        "state_param_bytes_per_dev": state["params"],
        "state_opt_bytes_per_dev": state["opt"],
        "loss_last": round(hist[-1]["loss"], 4),
    }
    # schedule/precision/moments dimensions ride only on non-default rows
    # so every pre-existing row keeps its exact lineage key
    if strat.micro_batches:
        row["micro"] = strat.micro_batches
    if strat.schedule != "gpipe":
        row["schedule"] = strat.schedule
        row["interleave"] = int(mets.get("interleave", 1))
    if strat.precision != "fp32":
        row["precision"] = strat.precision
    if strat.moments != "float32":
        row["moments"] = strat.moments
    if mesh.stage > 1:
        micro = engine.inner.plan.micro
        if strat.schedule == "1f1b":
            v = int(mets.get("interleave", 1))
            ticks = onefb_ticks(mesh.stage, micro, v)
            units = ticks / v          # each tick does 1/v of a stage
            row["analytic_bubble"] = round(
                onefb_bubble_fraction(mesh.stage, micro, v), 4)
        else:
            ticks = units = gpipe_ticks(mesh.stage, micro)
            row["analytic_bubble"] = round(
                bubble_fraction(mesh.stage, micro), 4)
        row["modeled_step_ticks"] = ticks
        row["modeled_stage_units"] = round(units, 2)
        sched_key = (mesh.spec(), micro, strat.precision)
        if strat.schedule == "gpipe":
            stage_units[sched_key] = (units, row["analytic_bubble"])
        elif sched_key in stage_units or (mesh.spec(), micro, "fp32") \
                in stage_units:
            gu, gb = stage_units.get(
                sched_key, stage_units.get((mesh.spec(), micro, "fp32")))
            # the 1F1B acceptance: a strictly shorter modeled critical
            # path AND a strictly smaller analytic bubble than GPipe on
            # the same mesh at the same micro count
            assert units < gu and row["analytic_bubble"] < gb, \
                (row, gu, gb)
            row["modeled_speedup_vs_gpipe"] = round(gu / units, 3)
    okey = (strat.zero, strat.optimizer, mesh.spec())
    if strat.moments == "float32":
        opt_bytes.setdefault(okey, state["opt"])
    elif okey in opt_bytes:
        cut = opt_bytes[okey] / state["opt"]
        # the qmom acceptance: ~2x fewer persistent moment bytes
        assert 1.8 <= cut <= 2.2, (row, opt_bytes[okey])
        row["moment_bytes_cut"] = round(cut, 2)
    base = baseline_bytes.get(key)
    if strat.zero == 3 and base:
        row["state_reduction_vs_z0"] = round(base / state["total"], 2)
        # the ZeRO acceptance: ~data-axis-factor fewer persistent bytes
        assert row["state_reduction_vs_z0"] >= 0.8 * mesh.data, row
    print("ROW " + json.dumps(row))
print("HYBRID-BENCH-OK")
"""


def main(specs=None):
    specs = specs or DEFAULT_SPECS
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(repo, "src"))
    from repro.launch.env import subprocess_env
    env = subprocess_env(8)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    res = subprocess.run([sys.executable, "-c", _CHILD] + list(specs),
                         env=env, capture_output=True, text=True,
                         timeout=900)
    if "HYBRID-BENCH-OK" not in res.stdout:
        sys.stderr.write(res.stdout + "\n" + res.stderr[-3000:])
        raise RuntimeError("hybrid bench child failed")
    rows = [json.loads(line[4:]) for line in res.stdout.splitlines()
            if line.startswith("ROW ")]
    assert len(rows) == len(specs), (len(rows), len(specs))
    emit_json(rows)


if __name__ == "__main__":
    main(sys.argv[1:])
