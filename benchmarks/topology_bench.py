"""Survey §3.3.1(2): decentralized allreduce topologies.

Numeric correctness is covered by tests on 8 devices; here we (a) measure
the 8-device wall time of each schedule via subprocess, and (b) report the
analytic per-device traffic at production scale (n=256), which is what the
survey's topology discussion is about (ring's 2(n-1)/n vs fully-connected's
(n-1)).
"""
from __future__ import annotations

import os
import subprocess
import sys

from repro.core.allreduce import per_device_bytes

from benchmarks.common import emit

SIZE_MB = 8   # an 8 MB gradient bucket

_CHILD = r"""
import time, jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.allreduce import TOPOLOGIES
from repro.core.collectives import shard_map
mesh = Mesh(np.array(jax.devices()).reshape(8), ("w",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, %d))
for name, fn in TOPOLOGIES.items():
    f = jax.jit(shard_map(lambda a, _fn=fn: _fn(a[0], "w")[None],
                mesh=mesh, in_specs=P("w", None), out_specs=P("w", None),
                check_vma=False))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        f(x).block_until_ready()
    print(f"TIME {name} {(time.perf_counter()-t0)/5*1e6:.0f}")
""" % (SIZE_MB * 1024 * 1024 // 4 // 8)


def main():
    rows = [("topology.name", "us_per_call_8dev",
             "per_device_MB_at_n256")]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    times = {}
    for line in res.stdout.splitlines():
        if line.startswith("TIME "):
            _, name, us = line.split()
            times[name] = float(us)
    for name in ("ring", "butterfly", "tree", "fully_connected", "psum"):
        analytic = per_device_bytes(name, 256, SIZE_MB * 1e6) / 1e6
        rows.append((f"topology.{name}", round(times.get(name, -1), 0),
                     round(analytic, 1)))
    emit(rows)


if __name__ == "__main__":
    main()
