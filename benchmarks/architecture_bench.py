"""Survey §3.3.1: centralized (parameter server) vs decentralized
(allreduce) architecture, in their TPU-native forms (DESIGN.md §2.2):

  PS          = reduce-scatter grads -> update my 1/n shard -> all-gather
  decentral   = all-reduce grads -> every worker updates the full model

Measured on 8 host devices via subprocess: wall time per step and the
derived update-FLOPs ratio (PS does 1/n of the optimizer work — the ZeRO
observation).
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import time, jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.parameter_server import make_ps_step
from repro.core.collectives import shard_map
N = 1_000_000
mesh = Mesh(np.array(jax.devices()).reshape(8), ("w",))
params = {"w": jax.random.normal(jax.random.PRNGKey(0), (N,))}
grads = {"w": jnp.stack([jnp.full((N,), float(i)) for i in range(8)])}

def update(p, g, o):
    return jax.tree.map(lambda a, b: a - 0.1 * b, p, g), o
ps = make_ps_step(update, "w")
f_ps = jax.jit(shard_map(
    lambda p, g: ps(p, jax.tree.map(lambda a: a[0], g), None)[0],
    mesh=mesh, in_specs=(P(), P("w")), out_specs=P(), check_vma=False))

def dec(p, g):
    gsum = jax.lax.psum(jax.tree.map(lambda a: a[0], g)["w"], "w")
    return {"w": p["w"] - 0.1 * gsum}
f_dec = jax.jit(shard_map(dec, mesh=mesh, in_specs=(P(), P("w")),
                out_specs=P(), check_vma=False))
for name, f in [("ps", f_ps), ("decentralized", f_dec)]:
    jax.block_until_ready(f(params, grads))
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(f(params, grads))
    print(f"TIME {name} {(time.perf_counter()-t0)/10*1e6:.0f}")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    times = {}
    for line in res.stdout.splitlines():
        if line.startswith("TIME "):
            _, name, us = line.split()
            times[name] = float(us)
    rows = [("architecture.variant", "us_per_step_8dev",
             "update_flops_share")]
    rows.append(("architecture.ps_rs_ag", times.get("ps", -1), "1/8"))
    rows.append(("architecture.decentralized_ar",
                 times.get("decentralized", -1), "8/8"))
    emit(rows)


if __name__ == "__main__":
    main()
