"""Serving-plane benchmark: continuous batching vs one-shot static
batching under an open-loop Poisson arrival trace (survey §5: serving as
a first-class workload).

One JSON row per (arch, policy, page_size, tp) cell: throughput and
first-token / per-token latency percentiles on the engine's virtual
iteration clock (deterministic — wall seconds are recorded alongside).
The tp=2 cell re-runs the continuous+paged config under tensor-parallel
decode in a 2-virtual-device subprocess and must reproduce the
single-device token stream.

Asserts the headline claim the gate also checks: continuous batching
beats one-shot on BOTH tokens/s and p99 time-to-first-token for every
arch (iteration-level admission fills freed slots immediately instead of
gating each wave on its slowest member).

  PYTHONPATH=src python -m benchmarks.run serve
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json
from repro.configs import get_config
from repro.models import build_model
from repro.serve.autoscale import poisson_trace
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request

SLOTS = 4
MAX_LEN = 24
PROMPT_LEN = 5
RATE = 0.6          # requests per virtual iteration (open loop)
HORIZON = 30.0
SEED = 0


def make_trace(vocab):
    arrivals = [0.0] + poisson_trace(RATE, HORIZON, seed=SEED)
    rng = np.random.RandomState(SEED)
    prompts = rng.randint(1, vocab, size=(len(arrivals), PROMPT_LEN))
    budgets = rng.choice([3, 6, 10, 14], size=len(arrivals))
    return arrivals, prompts, budgets


def requests(arrivals, prompts, budgets):
    return [Request(rid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=int(budgets[i]), arrival=arrivals[i])
            for i in range(len(arrivals))]


def run_cell(arch, model, params, arrivals, prompts, budgets,
             policy, page_size, tp=1):
    reqs = requests(arrivals, prompts, budgets)
    eng = ServeEngine(model, params, ServeConfig(
        slots=SLOTS, max_len=MAX_LEN, page_size=page_size, policy=policy,
        tp=tp, cache_dtype=jnp.float32, compute_dtype=jnp.float32))
    m = eng.run(reqs)
    row = {"bench": "serve", "arch": arch, "policy": policy,
           "page_size": page_size, "tp": tp, "slots": SLOTS,
           "requests": len(reqs)}
    row.update({k: (round(v, 4) if isinstance(v, float) else v)
                for k, v in m.items()})
    return row, [r.output for r in reqs]


_TP_CHILD = """
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
import benchmarks.serve_bench as S
cfg = get_config("tinyllama-1.1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
arrivals, prompts, budgets = S.make_trace(cfg.vocab_size)
row, outs = S.run_cell("tinyllama-1.1b", model, params, arrivals, prompts,
                       budgets, "continuous", 4, tp=2)
print("ROW " + json.dumps({"row": row, "outputs": outs}))
"""


def tp_cell():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = (root + os.pathsep + os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-c", _TP_CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    if res.returncode != 0:
        raise RuntimeError(f"tp cell failed:\n{res.stderr[-3000:]}")
    line = next(l for l in res.stdout.splitlines() if l.startswith("ROW "))
    payload = json.loads(line[4:])
    return payload["row"], payload["outputs"]


def main() -> None:
    rows = []
    token_streams = {}
    for arch in ("tinyllama-1.1b", "recurrentgemma-9b"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        arrivals, prompts, budgets = make_trace(cfg.vocab_size)
        for page_size in (0, 4):
            per_policy = {}
            for policy in ("oneshot", "continuous"):
                row, outs = run_cell(arch, model, params, arrivals, prompts,
                                     budgets, policy, page_size)
                rows.append(row)
                per_policy[policy] = row
                token_streams[(arch, policy, page_size)] = outs
            c, o = per_policy["continuous"], per_policy["oneshot"]
            assert c["tokens_per_s"] >= o["tokens_per_s"], (arch, page_size)
            assert c["p99_first_token"] < o["p99_first_token"], \
                (arch, page_size)
        # layout must never change tokens
        for policy in ("oneshot", "continuous"):
            assert (token_streams[(arch, policy, 0)]
                    == token_streams[(arch, policy, 4)]), (arch, policy)
        # admission must never change tokens
        assert (token_streams[(arch, "oneshot", 4)]
                == token_streams[(arch, "continuous", 4)]), arch

    row_tp, outs_tp = tp_cell()
    rows.append(row_tp)
    assert outs_tp == token_streams[("tinyllama-1.1b", "continuous", 4)], \
        "tp=2 token stream diverged from single-device"

    emit_json(rows)


if __name__ == "__main__":
    main()
