"""PR 1 tentpole benchmark: device-sharded data parallelism (§3.3).

Reports, for the executable ``DataParallelEngine`` bucket plan on a real
(reduced) transformer:

  * modeled iteration time: no-overlap vs TicTac-ordered bucketed overlap
    (same ``comm_scheduler`` code path the engine executes), and
  * measured wire bytes per step for fp32 vs onebit vs dgc through the
    sharded step, asserted equal to the compressor's ``wire_bytes()``
    accounting.

The 8-device measurement runs in a subprocess with virtual host devices.
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.core import Compressor
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.train import DataParallelConfig, DataParallelEngine

cfg = get_config("tinyllama-1.1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
batches = make_lm_batches(data)
def grad_fn(p, batch):
    (loss, _), g = jax.value_and_grad(
        lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
        has_aux=True)(p)
    return loss, g

for method in ("none", "onebit", "dgc"):
    eng = DataParallelEngine(
        DataParallelConfig(num_workers=8, lr=0.01, bucket_mb=0.25,
                           compressor=Compressor(method, density=0.05)),
        grad_fn)
    _, hist, wire = eng.run(params, batches, 2)
    expect = eng.wire_bytes_per_step(params) * 2
    assert wire == expect, (method, wire, expect)
    tl = eng.modeled_timeline(params)
    print(f"ROW {method} {wire//2} {tl['no_overlap_s']*1e6:.2f} "
          f"{tl['overlap_s']*1e6:.2f} {tl['n_buckets']} "
          f"{hist[-1]['loss']:.4f}")
assert True
print("WIRE-ACCOUNTING-MATCHES")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    if "WIRE-ACCOUNTING-MATCHES" not in res.stdout:
        sys.stderr.write(res.stdout + "\n" + res.stderr[-3000:])
        raise RuntimeError("data_parallel child failed")
    rows = [("data_parallel.method", "wire_bytes_per_step",
             "modeled_no_overlap_us", "modeled_tictac_overlap_us",
             "n_buckets", "loss_after_2")]
    for line in res.stdout.splitlines():
        if line.startswith("ROW "):
            _, method, wire, no_ov, ov, nb, loss = line.split()
            assert float(ov) <= float(no_ov), (method, ov, no_ov)
            rows.append((f"data_parallel.{method}", wire, no_ov, ov, nb,
                         loss))
    rows.append(("data_parallel.wire_accounting", "exact-match", "", "", "",
                 ""))
    emit(rows)


if __name__ == "__main__":
    main()
