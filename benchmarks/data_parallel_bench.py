"""Strategy-matrix benchmark: device-sharded data parallelism (§3.3).

Every cell is a declarative ``Strategy`` spec string and yields one JSON
row, so ``BENCH_*.json`` files track the full sync × arch × compression
matrix rather than just bsp/allreduce:

  PYTHONPATH=src python -m benchmarks.data_parallel_bench            # default matrix
  PYTHONPATH=src python -m benchmarks.data_parallel_bench ssp:2/ps/onebit@8 ...

Per cell, on a real (reduced) transformer on 8 virtual host devices:

  * measured wire bytes (asserted equal to the compressor's own
    ``roundtrip`` accounting — identical for both architectures), and
  * the modeled iteration time for the executed bucket plan: no-overlap
    vs TicTac-ordered bucketed overlap (the same ``comm_scheduler`` code
    path the engine executes).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit_json

DEFAULT_SPECS = [
    "bsp/allreduce/none@8", "bsp/allreduce/onebit@8",
    "bsp/allreduce/dgc:0.05@8",
    "bsp/ps/none@8", "bsp/ps/onebit@8", "bsp/ps/dgc:0.05@8",
    "ssp:3/allreduce/onebit@8", "ssp:3/ps/onebit@8",
    "asp/allreduce/none@8", "asp/ps/none@8",
]

_CHILD = r"""
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.train import Strategy

cfg = get_config("tinyllama-1.1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
batches = make_lm_batches(data)
def grad_fn(p, batch):
    (loss, _), g = jax.value_and_grad(
        lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
        has_aux=True)(p)
    return loss, g

STEPS = 2
for spec in sys.argv[1:]:
    strat = Strategy.parse(spec, lr=0.01, bucket_mb=0.25, backend="device")
    engine = strat.build(grad_fn)
    _, hist, wire = engine.run(params, batches, STEPS)
    dev = engine.inner
    # wire accounting: every event transmits the compressor's static
    # per-worker byte count (bsp: all K workers per step)
    per_event = dev.per_event_wire_bytes(params)
    events = len(hist) * (strat.workers if strat.sync == "bsp" else 1)
    assert wire == per_event * events, (spec, wire, per_event, events)
    row = {
        "bench": "data_parallel",
        "strategy": strat.spec(),
        "sync": strat.sync, "arch": strat.arch,
        "compression": strat.compressor.method,
        "workers": strat.workers,
        "wire_bytes_per_step": wire // STEPS,
        "events": len(hist),
        "loss_last": round(hist[-1]["loss"], 4),
    }
    if strat.sync == "bsp":
        # only BSP executes the fused-bucket plan the timeline models;
        # async pushes are per-event, so the columns would be fiction there
        tl = dev.modeled_timeline(params)
        assert tl["overlap_s"] <= tl["no_overlap_s"], spec
        row.update(
            modeled_no_overlap_us=round(tl["no_overlap_s"] * 1e6, 2),
            modeled_tictac_overlap_us=round(tl["overlap_s"] * 1e6, 2),
            n_buckets=tl["n_buckets"])
    print("ROW " + json.dumps(row))

# trace overhead: the same engine stepped with tracing off vs on — the
# "zero overhead when disabled" claim, quantified (docs/observability.md).
# Both paths are warmed before any timing (the traced path compiles /
# allocates on its first pass too — timing it cold produced the negative
# -4.72% artifact in BENCH_pr8.json), then K interleaved rounds are
# timed per mode and the best round wins: min-of-k discards scheduler
# noise, interleaving keeps cache/allocator drift from favoring a side.
import time
from repro.obs.trace import TraceRecorder, tracing
strat = Strategy.parse("bsp/ring/onebit@8", lr=0.01, bucket_mb=0.25,
                       backend="device")
engine = strat.build(grad_fn)
st = engine.init(params)
t = 0
def steps(n, st, t):
    for _ in range(n):
        st, _ = engine.step(st, batches, t)
        t += 1
    return st, t
st, t = steps(2, st, t)                  # compile + warm untraced
with tracing():
    st, t = steps(2, st, t)              # warm the traced path as well
K, N = 3, 5
best_untraced = best_traced = float("inf")
events_per_step = 0
for _ in range(K):
    t0 = time.perf_counter()
    st, t = steps(N, st, t)
    best_untraced = min(best_untraced,
                        (time.perf_counter() - t0) / N * 1e6)
    recorder = TraceRecorder()
    with tracing(recorder=recorder):
        t0 = time.perf_counter()
        st, t = steps(N, st, t)
        best_traced = min(best_traced,
                          (time.perf_counter() - t0) / N * 1e6)
    events_per_step = len(recorder.events) // N
print("ROW " + json.dumps({
    "bench": "data_parallel",
    "strategy": "trace_overhead/" + strat.spec(),
    "untraced_step_us": round(best_untraced, 1),
    "traced_step_us": round(best_traced, 1),
    "traced_overhead_pct": round(
        (best_traced / best_untraced - 1) * 100, 2),
    "trace_events_per_step": events_per_step,
}))
print("WIRE-ACCOUNTING-MATCHES")
"""


def main(specs=None):
    specs = specs or DEFAULT_SPECS
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    res = subprocess.run([sys.executable, "-c", _CHILD] + list(specs),
                         env=env, capture_output=True, text=True,
                         timeout=900)
    if "WIRE-ACCOUNTING-MATCHES" not in res.stdout:
        sys.stderr.write(res.stdout + "\n" + res.stderr[-3000:])
        raise RuntimeError("data_parallel child failed")
    rows = [json.loads(line[4:]) for line in res.stdout.splitlines()
            if line.startswith("ROW ")]
    # one row per spec + the trace-overhead row the child always appends
    assert len(rows) == len(specs) + 1, (len(rows), len(specs))
    emit_json(rows)


if __name__ == "__main__":
    main(sys.argv[1:])
