"""Kernel microbench: interpret-mode wall time (CPU correctness vehicle) +
the derived TPU-roofline time per call (bytes / HBM bw — these kernels are
bandwidth-bound by construction)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as FA
from repro.kernels import onebit, qsgd, terngrad, topk
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

from benchmarks.common import emit, time_us

R, C = 512, 512      # a 1 MB gradient tile


def main():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    g = jax.random.normal(ks[0], (R, C))
    e = jnp.zeros((R, C))
    u = jax.random.uniform(ks[1], (R, C))
    nbytes = R * C * 4
    rows = [("kernel.name", "us_per_call_interp", "tpu_roofline_us")]

    def roof(read_write_bytes, flops=0.0):
        return round(max(read_write_bytes / HBM_BW,
                         flops / PEAK_FLOPS_BF16) * 1e6, 3)

    rows.append(("kernel.onebit",
                 round(time_us(lambda: onebit.compress(g, e)), 0),
                 roof(3 * nbytes)))
    rows.append(("kernel.terngrad",
                 round(time_us(lambda: terngrad.compress(g, u)), 0),
                 roof(2 * nbytes + R * C)))
    rows.append(("kernel.qsgd",
                 round(time_us(lambda: qsgd.compress(g, u)), 0),
                 roof(2 * nbytes + R * C)))
    th = topk.threshold_for_density(g, e, 0.01)
    rows.append(("kernel.topk",
                 round(time_us(lambda: topk.compress(g, e, th)), 0),
                 roof(4 * nbytes)))

    B, S, H, KV, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    fl = 4.0 * B * H * S * S * hd
    rows.append(("kernel.flash_attention",
                 round(time_us(lambda: FA.attention(
                     q, k, v, block_q=128, block_k=128), iters=2), 0),
                 roof(2 * (q.size + 2 * k.size) * 4, fl)))
    emit(rows)


if __name__ == "__main__":
    main()
