"""Kernel backend-seam microbench: one JSON row per kernel × backend ×
size — interpret-mode wall time on CPU (the correctness vehicle; on TPU
the kernel backend compiles) plus the derived TPU-roofline time per call
(bytes / HBM bw — these kernels are bandwidth-bound by construction).

The ``onebit_encode_ef`` rows are the fused encode+EF cell: one kernel
pass reads the gradient bucket (g, e) once and emits the sign plane, bin
means, reconstruction, and next residual (``bucket_passes=1``), where the
unfused sequence the codecs used to run — encode, decode, subtract —
reads the bucket twice (``bucket_passes=2``).  The roofline column prices
exactly that: the fused cell moves 4 array-widths of HBM traffic, the
unfused 7.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as FA
from repro.kernels import onebit, qsgd, terngrad, topk
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

from benchmarks.common import emit_json, time_us

SIZES = [(256, 256), (512, 512)]     # 256 KB and 1 MB gradient tiles
BACKENDS = ("ref", "kernel")


def _roof(read_write_bytes, flops=0.0):
    return round(max(read_write_bytes / HBM_BW,
                     flops / PEAK_FLOPS_BF16) * 1e6, 3)


def _row(kernel, backend, shape, us, roofline_us, **extra):
    return dict(bench="kernel", kernel=kernel, backend=backend,
                shape=f"{shape[0]}x{shape[1]}",
                us_per_call_interp=round(us, 0),
                tpu_roofline_us=roofline_us, **extra)


def _unfused_onebit(g, e, backend):
    signs, scale, _ = (onebit.compress(g, e) if backend == "kernel"
                       else onebit.onebit_ref(g, e))
    recon = signs.astype(jnp.float32) * scale      # decode pass
    return (g + e) - recon                         # separate EF pass


def _terngrad(g, u, backend):
    if backend == "kernel":
        return terngrad.compress(g, u)
    return terngrad.terngrad_ref(g, u)


def main():
    key = jax.random.PRNGKey(0)
    rows = []
    for R, C in SIZES:
        ks = jax.random.split(key, 3)
        g = jax.random.normal(ks[0], (R, C))
        e = jax.random.normal(ks[1], (R, C)) * 0.3
        u = jax.random.uniform(ks[2], (R, C))
        th = topk.threshold_for_density(g, e, 0.01)
        nbytes = R * C * 4

        for b in BACKENDS:
            # fused encode+EF: single pass over the bucket — read (g, e),
            # write (recon, new_e) + the bit/scale planes
            rows.append(_row(
                "onebit_encode_ef", b, (R, C),
                time_us(lambda b=b: onebit.encode_ef(g, e, backend=b)),
                _roof(4 * nbytes), bucket_passes=1))
            # the unfused sequence the fused kernel replaces (encode then
            # a separate decode + EF residual pass) re-reads the bucket
            rows.append(_row(
                "onebit_encode_ef_unfused", b, (R, C),
                time_us(lambda b=b: _unfused_onebit(g, e, b)),
                _roof(7 * nbytes), bucket_passes=2))
            rows.append(_row(
                "terngrad", b, (R, C),
                time_us(lambda b=b: _terngrad(g, u, b)),
                _roof(2 * nbytes + R * C)))
            rows.append(_row(
                "qsgd", b, (R, C),
                time_us(lambda b=b: qsgd.quantize(g, u, backend=b)),
                _roof(2 * nbytes + R * C)))
            rows.append(_row(
                "topk", b, (R, C),
                time_us(lambda b=b: topk.sparsify(g, e, th, backend=b)),
                _roof(4 * nbytes)))

    B, S, H, KV, hd = 1, 256, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    fl = 4.0 * B * H * S * S * hd
    flash_roof = _roof(2 * (q.size + 2 * k.size) * 4, fl)
    for b, fn in (("ref", lambda: FA.attention_ref(q, k, v)),
                  ("kernel", lambda: FA.attention(q, k, v, block_q=128,
                                                  block_k=128))):
        rows.append(_row("flash_attention", b, (S, hd),
                         time_us(fn, iters=2), flash_roof))

    qd = jax.random.normal(ks[0], (B, 1, H, hd))
    ck = jax.random.normal(ks[1], (B, S, KV, hd))
    cv = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.int32(S - 1)
    dec_roof = _roof(2 * ck.size * 4, 4.0 * B * H * S * hd)
    for b, fn in (("ref", lambda: FA.decode_ref(qd, ck, cv, pos)),
                  ("kernel", lambda: FA.decode(qd, ck, cv, pos))):
        rows.append(_row("flash_decode", b, (S, hd),
                         time_us(fn, iters=2), dec_roof))
    emit_json(rows)


if __name__ == "__main__":
    main()
