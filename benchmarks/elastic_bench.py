"""Elasticity benchmark: goodput vs failure rate, recovery time.

One JSON row per scenario (``benchmarks/common.emit_json``), on the tiny
deterministic regression problem the elastic tests use (the point is the
recovery machinery, not the model):

  * ``goodput`` — committed steps / executed steps (rollbacks redo work)
  * ``recovery_s`` — mean wall-clock of a restore+reshard cycle
  * ``failure_rate`` — crashes per 100 steps injected by the plan
  * ``final_loss`` vs the uninterrupted baseline

  PYTHONPATH=src python -m benchmarks.elastic_bench
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit_json
from repro.elastic import EventPlan
from repro.train import Strategy, Trainer

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))
STEPS = 20
SPEC = "ssp:2/allreduce/onebit@4"


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


P0 = {"W": jnp.zeros((8, 1)), "b": jnp.zeros((130,))}

SCENARIOS = [
    ("baseline", ""),
    ("crash_x1", "crash:w1@7"),
    ("crash_x2", "crash:w1@7,crash:w2@14"),
    ("resize_down_up", "resize:2@7,resize:4@14"),
    ("backup_straggler", None),          # handled below (spec change)
    ("restart", "restart@10"),
]


def run_one(name: str, spec: str, plan: str):
    strat = Strategy.parse(spec, lr=0.05, backend="sim")
    t0 = time.time()
    with tempfile.TemporaryDirectory() as d:
        params, hist, mets = Trainer(strat).fit(
            grad_fn, P0, make_batch, STEPS,
            plan=EventPlan.parse(plan), checkpoint_dir=d,
            checkpoint_every=5)
    wall = time.time() - t0
    n_crash = plan.count("crash")
    recov = mets["recoveries"]
    return dict(
        scenario=name, spec=mets["spec"], steps=STEPS,
        executed_steps=mets["executed_steps"],
        goodput=STEPS / max(1, mets["executed_steps"]),
        failure_rate=100.0 * n_crash / STEPS,
        recoveries=len(recov),
        recovery_s=(sum(r["wall_s"] for r in recov) / len(recov)
                    if recov else 0.0),
        lost_steps=sum(r["lost_steps"] for r in recov),
        dropped_updates=mets["dropped_updates"],
        resizes=mets["resizes"], final_workers=mets["final_workers"],
        wire_bytes=mets["wire_bytes"], final_loss=hist[-1]["loss"],
        wall_s=wall)


def main():
    rows = []
    for name, plan in SCENARIOS:
        if name == "backup_straggler":
            rows.append(run_one(name, "bsp+backup:1/allreduce/onebit@4",
                                "slow:w0x4@5"))
        else:
            rows.append(run_one(name, SPEC, plan))
    emit_json(rows)


if __name__ == "__main__":
    main()
