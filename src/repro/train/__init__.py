from repro.train.train_loop import TrainState, make_train_step, train_loop

__all__ = ["TrainState", "make_train_step", "train_loop"]
