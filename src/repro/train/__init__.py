from repro.train.data_parallel import (DataParallelConfig,
                                       DataParallelEngine,
                                       make_bucketed_allreduce,
                                       make_sharded_train_step)
from repro.train.train_loop import TrainState, make_train_step, train_loop

__all__ = ["TrainState", "make_train_step", "train_loop",
           "DataParallelConfig", "DataParallelEngine",
           "make_bucketed_allreduce", "make_sharded_train_step"]
