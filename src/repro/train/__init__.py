from repro.train.data_parallel import (DataParallelConfig,
                                       DataParallelEngine, DeviceEngine,
                                       make_bucketed_allreduce,
                                       make_bucketed_ps_update,
                                       make_sharded_train_step)
from repro.train.strategy import (BACKENDS, Cell, DeviceBackend, Engine,
                                  SimBackend, Strategy, Trainer,
                                  registered_cells)
from repro.train.train_loop import TrainState, make_train_step, train_loop

__all__ = ["TrainState", "make_train_step", "train_loop",
           # declarative front-end (the one Strategy API)
           "Strategy", "Trainer", "Engine", "SimBackend", "DeviceBackend",
           "BACKENDS", "Cell", "registered_cells",
           # device engine + shard_map helpers
           "DeviceEngine", "make_bucketed_allreduce",
           "make_bucketed_ps_update", "make_sharded_train_step",
           # deprecated aliases (warn once; use Strategy.build)
           "DataParallelConfig", "DataParallelEngine"]
