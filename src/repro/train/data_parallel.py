"""Device-sharded BSP data parallelism with compressed, bucketed,
topology-explicit allreduce (survey §3.3).

``SyncEngine`` (core/sync.py) *simulates* K workers on one device; this
module is the executable counterpart: N real (virtual-host) devices under
``shard_map``, where each step

  1. computes per-worker gradients on the worker's batch shard,
  2. compresses each gradient bucket with per-worker error-feedback state
     (the EF state lives in the training state, sharded over the worker
     axis),
  3. reduces the decompressed buckets with a topology-explicit schedule
     from ``core.allreduce.TOPOLOGIES`` (ring / tree / butterfly / ...),
     issuing buckets in the order chosen by ``core.comm_scheduler`` —
     the same ``bucketize`` + ``tictac_order`` code path the analytic
     timeline model uses, so the modeled schedule and the executed
     schedule cannot drift apart.

Wire-byte accounting comes from the compressor's own ``roundtrip``
(what each worker would transmit per step); the modeled iteration
timeline comes from ``comm_scheduler.schedule_overlap`` over the very
bucket list executed in 3.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.allreduce import TOPOLOGIES
from repro.core.collectives import axis_size, shard_map
from repro.core.comm_scheduler import (LayerCost, LinkModel, bucketize,
                                       random_order, schedule_no_overlap,
                                       schedule_overlap, tictac_order)
from repro.core.compression import Compressor

AXIS = "workers"


@dataclasses.dataclass(frozen=True)
class DataParallelConfig:
    num_workers: int = 8
    lr: float = 0.1
    topology: str = "ring"           # key into TOPOLOGIES
    compressor: Compressor = Compressor("none")
    bucket_mb: float = 4.0           # gradient bucket fusion size
    order: str = "tictac"            # "tictac" | "random" | "layer"
    link: LinkModel = LinkModel()
    # modeled backward-compute seconds per gradient byte (timeline model)
    back_s_per_byte: float = 2e-12
    seed: int = 0


def _bucket_order(n: int, order: str, layers: Sequence[LayerCost],
                  seed: int) -> List[int]:
    if order == "tictac":
        return tictac_order(layers)
    if order == "random":
        return random_order(layers, seed)
    if order == "layer":
        return list(range(n))
    raise ValueError(order)


def _plan_buckets(params_example, bucket_mb: float, order: str,
                  back_s_per_byte: float, seed: int
                  ) -> Tuple[List[List[int]], List[int], List[LayerCost]]:
    """Fuse gradient leaves (backward = reverse-pytree order) into buckets
    of ~bucket_mb and choose the transfer issue order.  This single plan is
    shared by the executed schedule and the analytic timeline model."""
    leaves = jax.tree.leaves(params_example)
    layers = [LayerCost(f"g{i}", back_s_per_byte * x.size * 4, x.size * 4)
              for i, x in enumerate(leaves)]
    fused = bucketize(layers, bucket_mb * 1e6)
    buckets = [[int(nm[1:]) for nm in b.name.split("+")] for b in fused]
    order_idx = _bucket_order(len(fused), order, fused, seed)
    return buckets, order_idx, fused


def make_bucketed_allreduce(params_example, topology: str = "ring",
                            bucket_mb: float = 4.0, order: str = "tictac",
                            back_s_per_byte: float = 2e-12,
                            seed: int = 0, axis: str = AXIS):
    """Standalone grads->grads mean-allreduce for use inside ``shard_map``
    (e.g. as ``make_train_step(..., reduce_fn=...)``): leaves fused into
    ~bucket_mb buckets (backward order), issued in the chosen transfer
    order, each reduced with the topology-explicit schedule."""
    reduce_leaf = TOPOLOGIES[topology]
    buckets, order_idx, fused = _plan_buckets(
        params_example, bucket_mb, order, back_s_per_byte, seed)
    treedef = jax.tree.structure(params_example)
    leaf_shapes = [(x.shape, x.dtype)
                   for x in jax.tree.leaves(params_example)]

    def reduce_grads(grads):
        leaves = jax.tree.leaves(grads)
        n = axis_size(axis)
        out: List[Any] = [None] * len(leaves)
        for b in order_idx:                   # the executed schedule
            idxs = buckets[b]
            flat = jnp.concatenate(
                [leaves[i].astype(jnp.float32).reshape(-1) for i in idxs])
            red = reduce_leaf(flat, axis) / n
            off = 0
            for i in idxs:
                shape, dtype = leaf_shapes[i]
                size = int(np.prod(shape)) if shape else 1
                out[i] = red[off:off + size].reshape(shape).astype(dtype)
                off += size
        return jax.tree.unflatten(treedef, out)

    reduce_grads.fused_layers = fused
    reduce_grads.order = order_idx
    return reduce_grads


def make_sharded_train_step(train_step: Callable, mesh: Mesh,
                            compressed: bool):
    """Lift a ``make_train_step`` step (whose ``reduce_fn`` already
    all-reduces over ``AXIS``) into a jitted shard_map over the worker
    axis: batch is sharded, EF state (when compressing) stays per-worker,
    params/optimizer state are replicated, metrics come back worker-meaned.

    The returned function has the ``train_loop`` contract
    ``step(state, stacked_batch, rng) -> (state, metrics)`` — pass
    ``jit=False`` to ``train_loop`` since it is already compiled."""

    def body(state, batch, rng):
        batch = jax.tree.map(lambda x: x[0], batch)
        rng = jax.random.fold_in(rng, jax.lax.axis_index(AXIS))
        if compressed:
            state = dict(state,
                         ef=jax.tree.map(lambda x: x[0], state["ef"]))
        new_state, mets = train_step(state, batch, rng)
        if compressed:
            new_state = dict(
                new_state,
                ef=jax.tree.map(lambda x: x[None], new_state["ef"]))
        mets = {k: jax.lax.pmean(jnp.asarray(v, jnp.float32), AXIS)
                for k, v in mets.items()}
        return new_state, mets

    ef_spec = P(AXIS) if compressed else P()
    state_spec = {"params": P(), "opt_state": P(), "step": P(),
                  "ef": ef_spec}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(state_spec, P(AXIS), P()),
                   out_specs=(state_spec, P()),
                   check_vma=False)
    return jax.jit(fn)


class DataParallelEngine:
    """BSP over N host devices; drop-in comparable with
    ``SyncEngine(mode="bsp")``: ``run`` has the same signature and returns
    the same ``(params, history, wire_bytes)`` triple."""

    def __init__(self, cfg: DataParallelConfig, grad_fn: Callable,
                 devices: Optional[Sequence] = None):
        self.cfg = cfg
        self.grad_fn = grad_fn
        devs = list(devices or jax.devices())
        if len(devs) < cfg.num_workers:
            raise ValueError(
                f"need {cfg.num_workers} devices, have {len(devs)} "
                "(run under XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        self.mesh = Mesh(np.array(devs[:cfg.num_workers]), (AXIS,))
        self._step_fn = None
        self._wire_cell: List[int] = []

    # ------------------------------------------------------------- planning
    def _bucket_plan(self, params) -> Tuple[List[List[int]], List[int],
                                            List[LayerCost]]:
        return _plan_buckets(params, self.cfg.bucket_mb, self.cfg.order,
                             self.cfg.back_s_per_byte, self.cfg.seed)

    def modeled_timeline(self, params) -> Dict[str, float]:
        """Iteration-time projections for the exact bucket plan this engine
        executes — the benchmark's no-overlap vs overlap comparison."""
        _, order, fused = self._bucket_plan(params)
        return {
            "no_overlap_s": schedule_no_overlap(fused, self.cfg.link),
            "overlap_s": schedule_overlap(fused, self.cfg.link, order),
            "n_buckets": len(fused),
        }

    def wire_bytes_per_step(self, params) -> int:
        """Bytes each worker puts on the wire per step (compressor
        accounting), summed over workers like ``SyncEngine`` does."""
        comp = self.cfg.compressor
        state = comp.init_state(params)
        zeros = jax.tree.map(jnp.zeros_like, params)
        _, _, wb = comp.roundtrip(zeros, state, jax.random.PRNGKey(0))
        return int(wb) * self.cfg.num_workers

    # ------------------------------------------------------------- stepping
    def _build_step(self, params_example):
        cfg = self.cfg
        comp = cfg.compressor
        bucketed_allreduce = make_bucketed_allreduce(
            params_example, topology=cfg.topology, bucket_mb=cfg.bucket_mb,
            order=cfg.order, back_s_per_byte=cfg.back_s_per_byte,
            seed=cfg.seed)
        # compressor wire counts are shape-static Python ints at trace
        # time; capture them host-side rather than threading them through
        # the device as int32 (which overflows past 2 GiB/step)
        wire_cell: List[int] = []

        def sharded_step(params, ef, batch, rng):
            # params replicated; ef/batch/rng carry a leading worker axis
            batch = jax.tree.map(lambda x: x[0], batch)
            ef = jax.tree.map(lambda x: x[0], ef) if ef is not None else None
            rng = rng[0]
            loss, grads = self.grad_fn(params, batch)
            if comp.method != "none":
                grads, ef, wb = comp.roundtrip(grads, ef, rng)
            else:
                wb = sum(int(x.size) * 4 for x in jax.tree.leaves(grads))
            if not wire_cell:
                wire_cell.append(int(wb) * cfg.num_workers)
            avg = bucketed_allreduce(grads)
            new_params = jax.tree.map(lambda p, g: p - cfg.lr * g,
                                      params, avg)
            ef_out = (jax.tree.map(lambda x: x[None], ef)
                      if ef is not None else None)
            return (new_params, ef_out, loss[None])

        ef_spec = P(AXIS) if comp.method in ("onebit", "dgc") else P()
        fn = shard_map(sharded_step, mesh=self.mesh,
                       in_specs=(P(), ef_spec, P(AXIS), P(AXIS)),
                       out_specs=(P(), ef_spec, P(AXIS)),
                       check_vma=False)
        return jax.jit(fn), wire_cell

    # ------------------------------------------------------------------ run
    def run(self, params, batches: Callable[[int, int], Any], steps: int):
        """batches(t, worker) -> batch pytree (same contract as
        ``SyncEngine.run``).  Returns (params, history, wire_bytes)."""
        K = self.cfg.num_workers
        comp = self.cfg.compressor
        if self._step_fn is None:
            self._step_fn, self._wire_cell = self._build_step(params)
        ef = (jax.tree.map(
            lambda x: jnp.zeros((K,) + x.shape, jnp.float32), params)
            if comp.method in ("onebit", "dgc") else None)
        rng = jax.random.PRNGKey(self.cfg.seed)
        hist = []
        wire_total = 0
        for t in range(steps):
            per_worker = [batches(t, w) for w in range(K)]
            batch = jax.tree.map(lambda *xs: jnp.stack(xs), *per_worker)
            rng, *subs = jax.random.split(rng, K + 1)
            params, ef, losses = self._step_fn(
                params, ef, batch, jnp.stack(subs))
            wire_total += self._wire_cell[0]
            hist.append(dict(step=t, loss=float(jnp.mean(losses)),
                             max_staleness=0))
        return params, hist, wire_total
