"""Device-sharded data parallelism with compressed, bucketed,
topology-explicit communication (survey §3.3).

``SimSyncEngine`` (core/sync.py) *simulates* K workers on one device; this
module is the executable counterpart: N real (virtual-host) devices under
``shard_map``.  ``DeviceEngine`` executes the full synchronization ×
architecture cross-product of the survey's Table 1:

  sync=bsp        every step: per-worker gradients on the worker's batch
                  shard, compressed with per-worker error-feedback state,
                  exchanged bucket-by-bucket in ``CommPlan`` issue order —
                  one plan shared by the executed schedule and the
                  analytic timeline, so they cannot drift apart.
  sync=ssp | asp  the *simulator's own deterministic staleness schedule*
                  replayed on devices: each tick, every worker computes its
                  gradient against its stale pulled parameters in parallel
                  under shard_map; the host then applies the tick's firing
                  events in the simulator's event order (worker w fires
                  every periods[w] ticks; SSP blocks a worker more than
                  ``staleness`` clocks ahead).  Losses cross-validate
                  against ``SimSyncEngine`` on identical batch streams.
  sync=sma        CROSSBOW synchronous model averaging: per-worker
                  replicas live sharded, the center is a ``CommPlan``
                  exchange of the replicas themselves, and each replica
                  is pulled toward it (cross-validated vs the simulator).
  arch=allreduce  decentralized: bucketed topology-explicit exchange
                  (``repro.comm``), update replicated.
  arch=ps         centralized: the ZeRO-style reduce-scatter / shard-update
                  / all-gather path of ``core.parameter_server`` — each
                  worker plays parameter server for its 1/n shard.  Under
                  BSP it runs over the *same* fused-bucket plan and issue
                  order as allreduce; under SSP/ASP each firing worker's
                  push is a per-event reduce-scatter (no bucketing — one
                  gradient per event).

Wire accounting follows the config's ``wire`` mode (docs/comm.md):

  wire=modeled    compression is a per-worker ``roundtrip`` before a
                  full-precision exchange, and bytes are the compressor's
                  analytic accounting — identical to the simulator's, so
                  the two backends stay cross-validatable.
  wire=measured   the ``CommPlan`` schedule itself carries the encoded
                  segment payloads (encode → ppermute the planes →
                  decode-accumulate, per-worker EF inside the schedule)
                  and bytes are counted from those planes — recomputed
                  per bucket per step, so dgc's moving threshold shows up
                  in the accounting instead of a cached step-0 value.

``bsp/*/none`` is bit-identical under both modes (nothing to encode).
The modeled iteration timeline comes from the very bucket list executed
on device (``CommPlan.modeled_timeline``).

``DataParallelEngine`` is the deprecated PR-1 alias (BSP/allreduce only by
contract, though it accepts the extended config); construct engines via
``repro.train.Strategy(...).build(grad_fn)`` instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.comm.codecs import SPARSE_ELEM_BYTES
from repro.comm.plan import CommPlan, plan_buckets, scatter_flat
from repro.core.collectives import axis_size, shard_map
from repro.core.comm_scheduler import LayerCost, LinkModel
from repro.core.compression import Compressor, EF_METHODS
from repro.core.parameter_server import make_ps_step, sgd_update_fn
from repro.core.sync import (ElasticWorkerSet, default_periods,
                             firing_schedule, warn_deprecated)
from repro.elastic.backup import participation_weights
from repro.obs.trace import get_recorder

AXIS = "workers"

DEVICE_SYNCS = ("bsp", "ssp", "asp", "sma")   # device-executable sync models
ARCHS = ("allreduce", "ps")            # §3.3.1 architectures
WIRE_MODES = ("modeled", "measured")   # wire-byte accounting (docs/comm.md)

# the shared plan keyword set every engine forwards to CommPlan.plan
_plan_buckets = plan_buckets           # back-compat alias (pre-refactor name)
_scatter_flat = scatter_flat           # back-compat alias


@dataclasses.dataclass(frozen=True)
class DataParallelConfig:
    num_workers: int = 8
    lr: float = 0.1
    sync: str = "bsp"                # bsp | ssp | asp | sma
    arch: str = "allreduce"          # allreduce | ps
    staleness: int = 3               # SSP bound s
    # deterministic worker speeds: worker i finishes every periods[i] ticks
    periods: Optional[Tuple[int, ...]] = None
    topology: str = "ring"           # key into TOPOLOGIES
    compressor: Compressor = Compressor("none")
    backup: int = 0                  # BSP backup workers: drop the k slowest
    # measured straggler detection: per-worker step-time EMA replaces the
    # scheduled ranking in the backup drop set (elastic/detector.py)
    detect: bool = False
    bucket_mb: float = 4.0           # gradient bucket fusion size
    order: str = "tictac"            # "tictac" | "random" | "layer"
    link: LinkModel = LinkModel()
    # modeled backward-compute seconds per gradient byte (timeline model)
    back_s_per_byte: float = 2e-12
    wire: str = "modeled"            # modeled | measured (docs/comm.md)
    sma_mu: float = 0.1              # SMA correction strength
    seed: int = 0


def make_bucketed_allreduce(params_example, topology: str = "ring",
                            bucket_mb: float = 4.0, order: str = "tictac",
                            back_s_per_byte: float = 2e-12,
                            seed: int = 0, axis: str = AXIS):
    """Standalone grads->grads mean-allreduce for use inside ``shard_map``
    (e.g. as ``make_train_step(..., reduce_fn=...)``): leaves fused into
    ~bucket_mb buckets (backward order), issued in the chosen transfer
    order, each reduced with the topology-explicit schedule.  Thin
    wrapper over ``CommPlan`` (exact full-precision path)."""
    plan = CommPlan.plan(params_example, axis=axis, n=1, topology=topology,
                         bucket_mb=bucket_mb, order=order,
                         back_s_per_byte=back_s_per_byte, seed=seed)

    def reduce_grads(grads):
        return plan.reduce_grads(grads)

    reduce_grads.fused_layers = plan.fused
    reduce_grads.order = plan.order
    reduce_grads.plan = plan
    return reduce_grads


def make_bucketed_ps_update(params_example, lr: float,
                            bucket_mb: float = 4.0, order: str = "tictac",
                            back_s_per_byte: float = 2e-12,
                            seed: int = 0, axis: str = AXIS):
    """Centralized (params, grads) -> new params for use inside
    ``shard_map``: the same fused-bucket plan and issue order as
    ``make_bucketed_allreduce``, but each bucket takes the parameter-server
    path of ``core.parameter_server`` — reduce-scatter the bucket's summed
    gradient, SGD-update only my 1/n shard (the "server" work, ZeRO-style),
    and all-gather the updated shard back.  Traffic per device equals the
    ring allreduce; update FLOPs drop by n."""
    buckets, order_idx, fused = plan_buckets(
        params_example, bucket_mb, order, back_s_per_byte, seed)
    treedef = jax.tree.structure(params_example)
    leaf_shapes = [(tuple(x.shape), x.dtype)
                   for x in jax.tree.leaves(params_example)]

    def ps_update(params, grads):
        n = axis_size(axis)
        p_leaves = jax.tree.leaves(params)
        g_leaves = jax.tree.leaves(grads)
        # lists, NOT dicts: jax flattens dict keys in sorted order, which
        # would silently retrace the collectives in lexicographic bucket
        # order; list position preserves the planned issue order
        pb = [jnp.concatenate([p_leaves[i].astype(jnp.float32).reshape(-1)
                               for i in buckets[b]]) for b in order_idx]
        gb = [jnp.concatenate([g_leaves[i].astype(jnp.float32).reshape(-1)
                               for i in buckets[b]]) for b in order_idx]
        step = make_ps_step(sgd_update_fn(lr, mean_over=n), axis)
        new_pb, _ = step(pb, gb, None)
        out: List[Any] = [None] * len(p_leaves)
        for flat, b in zip(new_pb, order_idx):
            scatter_flat(flat, buckets[b], leaf_shapes, out)
        return jax.tree.unflatten(treedef, out)

    ps_update.fused_layers = fused
    ps_update.order = order_idx
    return ps_update


def make_sharded_train_step(train_step: Callable, mesh: Mesh,
                            compressed: bool):
    """Lift a ``make_train_step`` step (whose ``reduce_fn`` already
    all-reduces over ``AXIS``) into a jitted shard_map over the worker
    axis: batch is sharded, EF state (when compressing) stays per-worker,
    params/optimizer state are replicated, metrics come back worker-meaned.

    The returned function has the ``train_loop`` contract
    ``step(state, stacked_batch, rng) -> (state, metrics)`` — pass
    ``jit=False`` to ``train_loop`` since it is already compiled."""

    def body(state, batch, rng):
        batch = jax.tree.map(lambda x: x[0], batch)
        rng = jax.random.fold_in(rng, jax.lax.axis_index(AXIS))
        if compressed:
            state = dict(state,
                         ef=jax.tree.map(lambda x: x[0], state["ef"]))
        new_state, mets = train_step(state, batch, rng)
        if compressed:
            new_state = dict(
                new_state,
                ef=jax.tree.map(lambda x: x[None], new_state["ef"]))
        mets = {k: jax.lax.pmean(jnp.asarray(v, jnp.float32), AXIS)
                for k, v in mets.items()}
        return new_state, mets

    ef_spec = P(AXIS) if compressed else P()
    state_spec = {"params": P(), "opt_state": P(), "step": P(),
                  "ef": ef_spec}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(state_spec, P(AXIS), P()),
                   out_specs=(state_spec, P()),
                   check_vma=False)
    return jax.jit(fn)


def async_replay_step(st, batches, t, bound: Optional[int], *, K: int,
                      compressor: Compressor, grad_fn: Callable,
                      apply_fn: Callable, ps_apply: Optional[Callable],
                      lr: float, event_wire: int,
                      eff_periods: Tuple[int, ...]):
    """Replay the simulator's deterministic tick schedule on devices —
    shared by ``DeviceEngine`` (flat worker axis) and ``HybridEngine``
    (the data axis of a mesh).  Gradient compute for the whole worker set
    runs data-parallel via ``grad_fn(pulled_stack, ef, batch, keys,
    fire)``; the tick's firing events then apply in the simulator's
    worker order (each pushing through the configured architecture)."""
    events = []
    while st["updates"] - st["updates_base"] < \
            (t + 1 - st["step_base"]) * K:
        st["tick"] += 1
        # the same deterministic schedule the simulator executes
        firing = firing_schedule(st["tick"], eff_periods,
                                 st["batch_idx"], bound)
        if not firing:
            continue
        fire = np.zeros((K,), np.float32)
        fire[firing] = 1.0
        # a worker's batch index only advances at its own events, so
        # its batch is cached until it fires (invalidated below)
        for w in range(K):
            if st["batch_cache"][w] is None:
                st["batch_cache"][w] = batches(st["batch_idx"][w], w)
        batch = jax.tree.map(lambda *xs: jnp.stack(xs),
                             *st["batch_cache"])
        # mirror the simulator's rng stream: one split per firing event
        keys = [jax.random.PRNGKey(0)] * K
        if compressor.method != "none":
            for w in firing:
                st["rng"], sub = jax.random.split(st["rng"])
                keys[w] = sub
        pulled_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                                    *st["pulled"])
        losses, grads, st["ef"] = grad_fn(
            pulled_stack, st["ef"], batch, jnp.stack(keys),
            jnp.asarray(fire))
        for w in firing:
            staleness = st["server_ver"] - st["pulled_ver"][w]
            if ps_apply is not None:
                onehot = np.zeros((K,), np.float32)
                onehot[w] = 1.0
                st["params"] = ps_apply(st["params"], grads,
                                        jnp.asarray(onehot))
            else:
                g_w = jax.tree.map(lambda x: x[w], grads)
                st["params"] = apply_fn(st["params"], g_w, lr)
            st["server_ver"] += 1
            st["updates"] += 1
            st["pulled"][w] = st["params"]   # pull = reference rebind
            st["pulled_ver"][w] = st["server_ver"]
            st["batch_idx"][w] += 1
            st["batch_cache"][w] = None
            st["wire"] += event_wire
            events.append(dict(step=st["updates"],
                               loss=float(losses[w]),
                               max_staleness=staleness, worker=w))
    return st, events


class DeviceEngine(ElasticWorkerSet):
    """Executable {bsp,ssp,asp,sma} × {allreduce,ps} over N host devices;
    drop-in comparable with ``SimSyncEngine``: ``init / step / finalize``
    plus a composed ``run`` with the same signature and the same
    ``(params, history, wire_bytes)`` triple."""

    def __init__(self, cfg: DataParallelConfig, grad_fn: Callable,
                 devices: Optional[Sequence] = None):
        if cfg.sync not in DEVICE_SYNCS:
            raise ValueError(
                f"sync={cfg.sync!r} is not device-executable "
                f"(supported: {DEVICE_SYNCS})")
        if cfg.arch not in ARCHS:
            raise ValueError(f"arch={cfg.arch!r} (supported: {ARCHS})")
        if cfg.wire not in WIRE_MODES:
            raise ValueError(f"wire={cfg.wire!r} (supported: {WIRE_MODES})")
        if cfg.sync == "sma":
            if cfg.compressor.method != "none":
                raise ValueError("sma exchanges replicas, not gradients — "
                                 "it has no compression path")
            if cfg.arch != "allreduce":
                raise ValueError("sma is a decentralized exchange; use "
                                 "arch='allreduce'")
        if cfg.backup and cfg.sync != "bsp":
            raise ValueError("backup workers compose with bsp only "
                             "(async modes have no round to drop from)")
        if cfg.backup >= cfg.num_workers:
            raise ValueError("backup k must leave at least one worker")
        self.cfg = cfg
        self.grad_fn = grad_fn
        self._devs = list(devices or jax.devices())
        if len(self._devs) < cfg.num_workers:
            raise ValueError(
                f"need {cfg.num_workers} devices, have {len(self._devs)} "
                "(run under XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        self.mesh = Mesh(np.array(self._devs[:cfg.num_workers]), (AXIS,))
        self.periods = cfg.periods or default_periods(cfg.num_workers)
        assert len(self.periods) == cfg.num_workers
        self.slowdowns: List[float] = [1.0] * cfg.num_workers
        self._dropped = 0
        self._init_detector(cfg.detect, cfg.num_workers)
        self._step_fn = None
        self._sma_fn = None
        self._plan: Optional[CommPlan] = None
        self._event_wire_cache: Optional[int] = None
        self._async_fns = None
        self._wire_total = 0
        # same replicated apply as the simulator uses (allreduce arch)
        self._apply = jax.jit(
            lambda p, g, lr: jax.tree.map(lambda a, b: a - lr * b, p, g))

    @property
    def _ef_active(self) -> bool:
        return self.cfg.compressor.method in EF_METHODS

    # ------------------------------------------------------------- planning
    def _ensure_plan(self, params_example) -> CommPlan:
        """The engine's single ``CommPlan`` — built once per (params ×
        worker-count) and shared by the executed schedule, the timeline
        model, and both wire-accounting modes.  Invalidated on reshard."""
        if self._plan is None:
            cfg = self.cfg
            self._plan = CommPlan.plan(
                params_example, axis=AXIS, n=cfg.num_workers,
                topology=cfg.topology, compressor=cfg.compressor,
                wire=cfg.wire, bucket_mb=cfg.bucket_mb, order=cfg.order,
                back_s_per_byte=cfg.back_s_per_byte, seed=cfg.seed,
                link=cfg.link)
        return self._plan

    def _bucket_plan(self, params) -> Tuple[List[List[int]], List[int],
                                            List[LayerCost]]:
        plan = self._ensure_plan(params)
        return plan.buckets, plan.order, plan.fused

    def modeled_timeline(self, params) -> Dict[str, float]:
        """Iteration-time projections for the exact bucket plan this engine
        executes — the benchmark's no-overlap vs overlap comparison."""
        return self._ensure_plan(params).modeled_timeline()

    def per_event_wire_bytes(self, params) -> int:
        """Modeled bytes one worker puts on the wire per gradient push
        (compressor accounting; shape-static).  Identical for both
        architectures and to the simulator's accounting."""
        return self._ensure_plan(params).modeled_event_bytes(params)

    def wire_bytes_per_step(self, params) -> int:
        """Modeled bytes per BSP step summed over workers, like the
        simulator."""
        return self.per_event_wire_bytes(params) * self.cfg.num_workers

    # --------------------------------------------------------- bsp stepping
    def _build_step(self, params_example):
        cfg = self.cfg
        comp = cfg.compressor
        plan = self._ensure_plan(params_example)
        in_schedule = plan.in_schedule
        bucketed_ps = (make_bucketed_ps_update(
            params_example, cfg.lr, bucket_mb=cfg.bucket_mb,
            order=cfg.order, back_s_per_byte=cfg.back_s_per_byte,
            seed=cfg.seed) if cfg.arch == "ps" and not in_schedule
            else None)

        def sharded_step(params, ef, batch, rng, weight):
            # params replicated; ef/batch/rng/weight carry a worker axis.
            # weight is this worker's aggregation weight: 1 normally,
            # K/(K-k) for backup-round participants, 0 for dropped
            # stragglers (whose push never reaches the server and whose
            # EF state is therefore not consumed).
            batch = jax.tree.map(lambda x: x[0], batch)
            ef_in = (jax.tree.map(lambda x: x[0], ef)
                     if ef is not None else None)
            rng = rng[0]
            wt = weight[0]
            loss, grads = self.grad_fn(params, batch)
            sent = jnp.zeros((), jnp.int32)
            if in_schedule:
                # compressed payloads ride *inside* the schedule: the
                # CommPlan encodes each bucket's compensated gradient,
                # permutes the planes, and returns the per-worker hop
                # residuals as the new EF contribution (docs/comm.md)
                g_in = jax.tree.map(lambda x: x * wt, grads)
                if cfg.arch == "ps":
                    new_params, ef_new, sent = plan.ps_exchange(
                        params, g_in, ef_in, rng, cfg.lr)
                else:
                    avg, ef_new, sent = plan.exchange(g_in, ef_in, rng)
                    new_params = jax.tree.map(
                        lambda p, g: p - cfg.lr * g, params, avg)
            else:
                if comp.method != "none":
                    grads, ef_new, _wb = comp.roundtrip(grads, ef_in, rng)
                else:
                    ef_new = ef_in
                grads = jax.tree.map(lambda x: x * wt, grads)
                if cfg.arch == "ps":
                    new_params = bucketed_ps(params, grads)
                else:
                    avg = plan.reduce_grads(grads)
                    new_params = jax.tree.map(lambda p, g: p - cfg.lr * g,
                                              params, avg)
            if ef_new is not None:
                ef_out = jax.tree.map(
                    lambda new, old: jnp.where(wt > 0, new, old),
                    ef_new, ef_in)
                ef_out = jax.tree.map(lambda x: x[None], ef_out)
            else:
                ef_out = ef
            return (new_params, ef_out, loss[None], sent[None])

        ef_spec = P(AXIS) if self._ef_active else P()
        fn = shard_map(sharded_step, mesh=self.mesh,
                       in_specs=(P(), ef_spec, P(AXIS), P(AXIS), P(AXIS)),
                       out_specs=(P(), ef_spec, P(AXIS), P(AXIS)),
                       check_vma=False)
        return jax.jit(fn)

    def _event_wire_bytes(self, params) -> int:
        if self._event_wire_cache is None:
            self._event_wire_cache = self.per_event_wire_bytes(params)
        return self._event_wire_cache

    def _step_bsp(self, st, batches, t):
        cfg = self.cfg
        K = cfg.num_workers
        if self._step_fn is None:
            self._step_fn = self._build_step(st["params"])
        plan = self._plan
        # backup workers: drop the k slowest — scheduled ranking, or the
        # measured step-time EMA once detection warms up (the same shared
        # backup_drop rule the simulator applies)
        drop = self.backup_drop(cfg.backup)
        weights = participation_weights(K, drop)
        if self.detector is not None:
            # per-worker batch fetch is the only per-worker host work in
            # the fused device step — measure it (a straggling input
            # pipeline is the detectable straggler here)
            per_worker = []
            for w in range(K):
                t0 = time.perf_counter()
                per_worker.append(batches(t, w))
                self.detector.observe(w, time.perf_counter() - t0)
        else:
            per_worker = [batches(t, w) for w in range(K)]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *per_worker)
        st["rng"], *subs = jax.random.split(st["rng"], K + 1)
        rec = get_recorder()
        if rec.enabled:
            # the fused shard_map step cannot be split at runtime, so the
            # compute span covers the whole dispatch (blocked for an
            # honest wall_s) and the exchange structure below is the
            # plan's deterministic model of what ran inside it
            with rec.span("compute", pid="train", tid="loop", cat="train",
                          clock=("train_step", t), workers=K, fused=True):
                params, ef, losses, sent = self._step_fn(
                    st["params"], st["ef"], batch, jnp.stack(subs),
                    jnp.asarray(weights))
                jax.block_until_ready(losses)
        else:
            params, ef, losses, sent = self._step_fn(
                st["params"], st["ef"], batch, jnp.stack(subs),
                jnp.asarray(weights))
        st.update(params=params, ef=ef)
        if cfg.wire == "measured":
            # recomputed per bucket from the plan, every step: the
            # shape-static plane bytes of the whole schedule plus dgc's
            # per-step sparse payload (traced sent_elems, all workers)
            wire_inc = plan.measured_step_tx_bytes(cfg.arch) * K \
                + SPARSE_ELEM_BYTES * int(np.sum(np.asarray(sent)))
        else:
            wire_inc = self._event_wire_bytes(st["params"]) \
                * (K - len(drop))
        st["wire"] += wire_inc
        if rec.enabled:
            plan.emit_trace(rec, arch=cfg.arch, clock=("train_step", t))
            rec.counter("wire_bytes", {"cumulative": int(st["wire"])},
                        pid="train", cat="comm", clock=("train_step", t))
        self._dropped += len(drop)
        # participant-mean loss, float64 like the simulator's accounting
        part_losses = [float(losses[w]) for w in range(K) if w not in drop]
        ev = dict(step=t, loss=float(np.mean(part_losses)), max_staleness=0)
        if drop:
            ev["dropped"] = sorted(drop)
        return st, [ev]

    # ------------------------------------------------------------------ sma
    def _build_sma(self, params_example):
        cfg = self.cfg
        plan = self._ensure_plan(params_example)

        def sma_body(replicas, batch):
            r = jax.tree.map(lambda x: x[0], replicas)
            batch = jax.tree.map(lambda x: x[0], batch)
            loss, g = self.grad_fn(r, batch)
            # the center is a CommPlan exchange of the replicas themselves
            # (same bucket fusion + issue order as the gradient paths)
            center = plan.reduce_grads(r)
            mu = cfg.sma_mu
            new_r = jax.tree.map(
                lambda rr, zz, gg: rr - cfg.lr * gg - mu * (rr - zz),
                r, center, g)
            return (jax.tree.map(lambda x: x[None], new_r), loss[None])

        fn = shard_map(sma_body, mesh=self.mesh,
                       in_specs=(P(AXIS), P(AXIS)),
                       out_specs=(P(AXIS), P(AXIS)),
                       check_vma=False)
        return jax.jit(fn)

    def _param_bytes(self, params_like) -> int:
        return sum(int(np.prod(s) or 1) * 4
                   for s, _ in self._ensure_plan(params_like).leaf_shapes)

    def _step_sma(self, st, batches, t):
        cfg = self.cfg
        K = cfg.num_workers
        if self._sma_fn is None:
            self._sma_fn = self._build_sma(
                jax.tree.map(lambda x: x[0], st["replicas"]))
        per_worker = [batches(t, w) for w in range(K)]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *per_worker)
        st["replicas"], losses = self._sma_fn(st["replicas"], batch)
        if cfg.wire == "measured":
            st["wire"] += self._plan.measured_step_tx_bytes("allreduce") * K
        else:
            # the simulator's accounting: one replica-sized push per worker
            st["wire"] += self._param_bytes(
                jax.tree.map(lambda x: x[0], st["replicas"])) * K
        ev = dict(step=t, loss=float(np.mean(np.asarray(losses))),
                  max_staleness=0)
        return st, [ev]

    # --------------------------------------------------- ssp / asp stepping
    def _build_async_fns(self, params_example):
        cfg = self.cfg
        comp = cfg.compressor

        def grad_body(pulled, ef, batch, key, fire):
            # every input carries a leading worker axis; each worker sees
            # its own row and computes against its *stale* pulled params
            pulled = jax.tree.map(lambda x: x[0], pulled)
            batch = jax.tree.map(lambda x: x[0], batch)
            key = key[0]
            fire = fire[0]
            loss, g = self.grad_fn(pulled, batch)
            if comp.method != "none":
                ef_w = (jax.tree.map(lambda x: x[0], ef)
                        if ef is not None else None)
                g, ef_new, _wb = comp.roundtrip(g, ef_w, key)
                if ef_new is not None:
                    # only firing workers consume their error-feedback state
                    ef_out = jax.tree.map(
                        lambda new, old: jnp.where(fire > 0, new, old),
                        ef_new, ef_w)
                    ef_out = jax.tree.map(lambda x: x[None], ef_out)
                else:
                    ef_out = ef
            else:
                ef_out = ef
            g = jax.tree.map(lambda x: x[None], g)
            return loss[None], g, ef_out

        ef_spec = P(AXIS) if self._ef_active else P()
        grad_fn = jax.jit(shard_map(
            grad_body, mesh=self.mesh,
            in_specs=(P(AXIS), ef_spec, P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(AXIS), P(AXIS), ef_spec),
            check_vma=False))

        ps_apply = None
        if cfg.arch == "ps":
            step = make_ps_step(sgd_update_fn(cfg.lr), AXIS)

            def ps_body(params, g_stack, onehot):
                # the firing worker pushes its gradient; everyone else
                # contributes exact zeros, so the reduce-scatter delivers
                # the push to each shard's owner, which updates and
                # all-gathers back — a literal single-worker PS push
                g_mine = jax.tree.map(lambda x: x[0], g_stack)
                o = onehot[0]
                contrib = jax.tree.map(lambda x: x * o, g_mine)
                new_params, _ = step(params, contrib, None)
                return new_params

            ps_apply = jax.jit(shard_map(
                ps_body, mesh=self.mesh,
                in_specs=(P(), P(AXIS), P(AXIS)),
                out_specs=P(),
                check_vma=False))
        return grad_fn, ps_apply

    def _step_async(self, st, batches, t, bound: Optional[int]):
        cfg = self.cfg
        if self._async_fns is None:
            self._async_fns = self._build_async_fns(st["params"])
            self._event_wire = self.per_event_wire_bytes(st["params"])
        grad_fn, ps_apply = self._async_fns
        return async_replay_step(
            st, batches, t, bound, K=cfg.num_workers,
            compressor=cfg.compressor, grad_fn=grad_fn,
            apply_fn=self._apply, ps_apply=ps_apply, lr=cfg.lr,
            event_wire=self._event_wire,
            eff_periods=self.effective_periods())

    # -------------------------------------------------- engine protocol
    def init(self, params) -> Dict[str, Any]:
        cfg = self.cfg
        K = cfg.num_workers
        ef = (jax.tree.map(
            lambda x: jnp.zeros((K,) + x.shape, jnp.float32), params)
            if self._ef_active else None)
        st: Dict[str, Any] = dict(
            params=params, ef=ef, rng=jax.random.PRNGKey(cfg.seed), wire=0)
        if cfg.sync in ("ssp", "asp"):
            st.update(
                # per-worker pulled copies are reference rebinds (like the
                # simulator); they are stacked once per tick for shard_map
                pulled=[params] * K,
                pulled_ver=[0] * K,
                server_ver=0,
                tick=0,
                updates=0,
                batch_idx=[0] * K,
                batch_cache=[None] * K,
                # reshard rebases the step↔update accounting here (one
                # global step = K updates at the *current* K)
                updates_base=0,
                step_base=0,
            )
        elif cfg.sync == "sma":
            del st["params"]
            st["replicas"] = jax.tree.map(
                lambda x: jnp.stack([x] * K), params)
        return st

    def step(self, st, batches: Callable[[int, int], Any], t: int):
        sync = self.cfg.sync
        if sync == "bsp":
            st, ev = self._step_bsp(st, batches, t)
        elif sync == "ssp":
            st, ev = self._step_async(st, batches, t, self.cfg.staleness)
        elif sync == "sma":
            st, ev = self._step_sma(st, batches, t)
        else:
            st, ev = self._step_async(st, batches, t, None)
        self._wire_total = st["wire"]
        return st, ev

    def finalize(self, st):
        if self.cfg.sync == "sma":
            # replica average, like the simulator
            return jax.tree.map(lambda x: jnp.mean(x, axis=0),
                                st["replicas"])
        return st["params"]

    def wire_bytes(self) -> int:
        return self._wire_total

    def extra_metrics(self) -> Dict[str, Any]:
        m: Dict[str, Any] = {"wire_mode": self.cfg.wire}
        if self._plan is not None:
            m["measured_step_tx_bytes"] = \
                self._plan.measured_step_tx_bytes(self.cfg.arch)
            m["fp32_step_tx_bytes"] = self._plan.fp32_step_tx_bytes()
        return m

    def per_device_state_bytes(self, st) -> Dict[str, int]:
        """Measured persistent bytes per device — comparable with the
        hybrid engine's accounting (benchmarks/hybrid_bench.py).  Plain
        SGD carries no optimizer state; params are replicated, EF
        residuals are per-worker."""
        K = self.cfg.num_workers
        params_like = (jax.tree.map(lambda x: x[0], st["replicas"])
                       if self.cfg.sync == "sma" else st["params"])
        params = sum(np.asarray(x).nbytes
                     for x in jax.tree.leaves(params_like))
        ef = (sum(np.asarray(x).nbytes
                  for x in jax.tree.leaves(st["ef"])) // K
              if st.get("ef") is not None else 0)
        return {"params": params, "opt": 0, "ef": ef, "total": params}

    # --------------------------------------------------- elastic interface
    # (set_slowdown / effective_periods / dropped_updates come from the
    # shared ElasticWorkerSet, so the schedule rule cannot diverge from
    # the simulator's)
    def reshard(self, st, new_workers: int, step: int = 0,
                lost: Tuple[int, ...] = ()):
        """Re-size the worker set N→M *in the same process*: rebuild the
        mesh over the first M live devices, invalidate the compiled step
        functions (the comm plan is re-planned for the new mesh on the
        next step), and remap per-worker state — survivors (old slots
        minus ``lost``, in order) keep their EF residuals and batch
        clocks, grown slots start with zero residuals at the batch
        frontier.  A reshard is a synchronization barrier: every async
        worker re-pulls the current params, and the step↔update
        accounting rebases at global step ``step``."""
        cfg = self.cfg
        if new_workers < 1:
            raise ValueError("new_workers must be >= 1")
        if cfg.backup >= new_workers:
            raise ValueError(f"backup k={cfg.backup} needs > k workers")
        if new_workers > len(self._devs):
            raise ValueError(
                f"resize to {new_workers} workers needs {new_workers} "
                f"devices, have {len(self._devs)}")
        bad = [w for w in lost if w < 0 or w >= cfg.num_workers]
        if bad:
            raise ValueError(f"lost workers {bad} out of range for "
                             f"{cfg.num_workers} workers")
        survivors = [w for w in range(cfg.num_workers) if w not in set(lost)]
        slots = survivors[:new_workers]
        grown = new_workers - len(slots)
        # survivors keep their speed identity (like their slowdowns and
        # EF state); grown slots take the default-schedule tail
        periods = tuple([self.periods[s] for s in slots]
                        + list(default_periods(new_workers))[len(slots):])
        self.cfg = cfg = dataclasses.replace(
            cfg, num_workers=new_workers, periods=periods)
        self.mesh = Mesh(np.array(self._devs[:new_workers]), (AXIS,))
        self.periods = periods
        self.slowdowns = [self.slowdowns[s] for s in slots] + [1.0] * grown
        if self.detector is not None:
            self.detector.reshard(slots, new_workers)
        self._step_fn, self._sma_fn = None, None
        self._plan, self._event_wire_cache = None, None
        self._async_fns = None
        if st.get("ef") is not None:
            def remap_rows(x):     # (K_old,)+s -> (M,)+s
                rows = ([x[s] for s in slots]
                        + [jnp.zeros_like(x[0])] * grown)
                return jnp.stack(rows)
            st["ef"] = jax.tree.map(remap_rows, st["ef"])
        if cfg.sync in ("ssp", "asp"):
            frontier = max([st["batch_idx"][s] for s in slots] or [0])
            st["pulled"] = [st["params"]] * new_workers
            st["pulled_ver"] = [st["server_ver"]] * new_workers
            st["batch_idx"] = ([st["batch_idx"][s] for s in slots]
                               + [frontier] * grown)
            st["batch_cache"] = [None] * new_workers
            st["updates_base"] = st["updates"]
            st["step_base"] = step
        elif cfg.sync == "sma":
            # survivors keep their replicas; grown slots start at the
            # pre-reshard center, exactly like the simulator
            def remap_replicas(x):
                center = jnp.mean(x, axis=0)
                rows = [x[s] for s in slots] + [center] * grown
                return jnp.stack(rows)
            st["replicas"] = jax.tree.map(remap_replicas, st["replicas"])
        # arrays committed to the old mesh's devices would clash with the
        # new mesh inside jit — pull them to host; the next step re-places
        # them on the resized mesh
        for key in ("params", "ef", "pulled", "rng", "replicas"):
            if st.get(key) is not None:
                st[key] = jax.device_get(st[key])
        return st

    def export_state(self, st) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Split the run-state into (array pytree, JSON-able meta) for
        ``repro.checkpoint`` — the inverse of ``import_state``.  The
        per-worker batch cache is dropped: batches are a pure function of
        (batch_idx, worker), so resume re-fetches identical tensors."""
        cfg = self.cfg
        arrays: Dict[str, Any] = {"ef": st["ef"], "rng": st["rng"]}
        if cfg.sync == "sma":
            arrays["replicas"] = st["replicas"]
        else:
            arrays["params"] = st["params"]
        meta: Dict[str, Any] = dict(
            backend="device", mode=cfg.sync, num_workers=cfg.num_workers,
            wire=int(st["wire"]), periods=list(self.periods),
            slowdowns=list(self.slowdowns), dropped=self._dropped,
            detector=(self.detector.state() if self.detector is not None
                      else None))
        if cfg.sync in ("ssp", "asp"):
            arrays["pulled"] = st["pulled"]
            meta.update(pulled_ver=list(st["pulled_ver"]),
                        server_ver=int(st["server_ver"]),
                        tick=int(st["tick"]), updates=int(st["updates"]),
                        batch_idx=list(st["batch_idx"]),
                        updates_base=int(st["updates_base"]),
                        step_base=int(st["step_base"]))
        return arrays, meta

    def import_state(self, arrays: Dict[str, Any], meta: Dict[str, Any]):
        """Rebuild the run-state from an ``export_state`` snapshot.  The
        engine must already be configured at ``meta['num_workers']``."""
        cfg = self.cfg
        if meta["num_workers"] != cfg.num_workers:
            raise ValueError(
                f"snapshot has {meta['num_workers']} workers, engine has "
                f"{cfg.num_workers}; reshard the engine first")
        # the worker speed schedule travels with the snapshot: a resharded
        # run's remapped periods must survive a cross-process restore
        self.periods = tuple(int(p) for p in meta["periods"])
        self.cfg = cfg = dataclasses.replace(cfg, periods=self.periods)
        self.slowdowns = [float(s) for s in meta["slowdowns"]]
        self._dropped = int(meta["dropped"])
        if self.detector is not None:
            self.detector.load_state(meta.get("detector"))
        st: Dict[str, Any] = dict(
            ef=arrays["ef"], rng=jnp.asarray(arrays["rng"]),
            wire=int(meta["wire"]))
        if cfg.sync == "sma":
            st["replicas"] = arrays["replicas"]
        else:
            st["params"] = arrays["params"]
        if cfg.sync in ("ssp", "asp"):
            st.update(pulled=arrays["pulled"],
                      pulled_ver=list(meta["pulled_ver"]),
                      server_ver=int(meta["server_ver"]),
                      tick=int(meta["tick"]), updates=int(meta["updates"]),
                      batch_idx=list(meta["batch_idx"]),
                      batch_cache=[None] * cfg.num_workers,
                      updates_base=int(meta["updates_base"]),
                      step_base=int(meta["step_base"]))
        self._wire_total = st["wire"]
        return st

    # ------------------------------------------------------------------ run
    def run(self, params, batches: Callable[[int, int], Any], steps: int):
        """batches(t, worker) -> batch pytree (same contract as
        ``SimSyncEngine.run``).  Returns (params, history, wire_bytes)."""
        st = self.init(params)
        hist: List[dict] = []
        for t in range(steps):
            st, ev = self.step(st, batches, t)
            hist.extend(ev)
        return self.finalize(st), hist, st["wire"]


class DataParallelEngine(DeviceEngine):
    """Deprecated PR-1 alias for ``DeviceEngine`` — kept so existing call
    sites keep working.  Use ``repro.train.Strategy(sync=..., arch=...,
    backend='device').build(grad_fn)`` which wraps the same engine
    (bitwise-identical results)."""

    def __init__(self, cfg: DataParallelConfig, grad_fn: Callable,
                 devices: Optional[Sequence] = None):
        warn_deprecated("DataParallelEngine",
                        "repro.train.Strategy(...).build(grad_fn)")
        super().__init__(cfg, grad_fn, devices)
