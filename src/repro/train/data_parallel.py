"""Device-sharded data parallelism with compressed, bucketed,
topology-explicit communication (survey §3.3).

``SimSyncEngine`` (core/sync.py) *simulates* K workers on one device; this
module is the executable counterpart: N real (virtual-host) devices under
``shard_map``.  ``DeviceEngine`` executes the full synchronization ×
architecture cross-product of the survey's Table 1:

  sync=bsp        every step: per-worker gradients on the worker's batch
                  shard, compressed with per-worker error-feedback state,
                  then reduced bucket-by-bucket in ``comm_scheduler``
                  TicTac order — one plan shared by the executed schedule
                  and the analytic timeline, so they cannot drift apart.
  sync=ssp | asp  the *simulator's own deterministic staleness schedule*
                  replayed on devices: each tick, every worker computes its
                  gradient against its stale pulled parameters in parallel
                  under shard_map; the host then applies the tick's firing
                  events in the simulator's event order (worker w fires
                  every periods[w] ticks; SSP blocks a worker more than
                  ``staleness`` clocks ahead).  Losses cross-validate
                  against ``SimSyncEngine`` on identical batch streams.
  arch=allreduce  decentralized: bucketed topology-explicit allreduce
                  (``core.allreduce.TOPOLOGIES``), update replicated.
  arch=ps         centralized: the ZeRO-style reduce-scatter / shard-update
                  / all-gather path of ``core.parameter_server`` — each
                  worker plays parameter server for its 1/n shard.  Under
                  BSP it runs over the *same* fused-bucket plan and issue
                  order as allreduce; under SSP/ASP each firing worker's
                  push is a per-event reduce-scatter (no bucketing — one
                  gradient per event).

Wire-byte accounting comes from the compressor's own ``roundtrip`` (what
each worker would transmit per event) and is by construction identical for
both architectures (RS + AG moves the same bytes as a ring allreduce);
the modeled iteration timeline comes from ``comm_scheduler
.schedule_overlap`` over the very bucket list executed on device.

``DataParallelEngine`` is the deprecated PR-1 alias (BSP/allreduce only by
contract, though it accepts the extended config); construct engines via
``repro.train.Strategy(...).build(grad_fn)`` instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.allreduce import TOPOLOGIES
from repro.core.collectives import axis_size, shard_map
from repro.core.comm_scheduler import (LayerCost, LinkModel, bucketize,
                                       random_order, schedule_no_overlap,
                                       schedule_overlap, tictac_order)
from repro.core.compression import Compressor, EF_METHODS
from repro.core.parameter_server import make_ps_step, sgd_update_fn
from repro.core.sync import (ElasticWorkerSet, default_periods,
                             firing_schedule, warn_deprecated)
from repro.elastic.backup import participation_weights

AXIS = "workers"

DEVICE_SYNCS = ("bsp", "ssp", "asp")   # device-executable sync models
ARCHS = ("allreduce", "ps")            # §3.3.1 architectures


@dataclasses.dataclass(frozen=True)
class DataParallelConfig:
    num_workers: int = 8
    lr: float = 0.1
    sync: str = "bsp"                # bsp | ssp | asp (sma is sim-only)
    arch: str = "allreduce"          # allreduce | ps
    staleness: int = 3               # SSP bound s
    # deterministic worker speeds: worker i finishes every periods[i] ticks
    periods: Optional[Tuple[int, ...]] = None
    topology: str = "ring"           # key into TOPOLOGIES
    compressor: Compressor = Compressor("none")
    backup: int = 0                  # BSP backup workers: drop the k slowest
    # measured straggler detection: per-worker step-time EMA replaces the
    # scheduled ranking in the backup drop set (elastic/detector.py)
    detect: bool = False
    bucket_mb: float = 4.0           # gradient bucket fusion size
    order: str = "tictac"            # "tictac" | "random" | "layer"
    link: LinkModel = LinkModel()
    # modeled backward-compute seconds per gradient byte (timeline model)
    back_s_per_byte: float = 2e-12
    seed: int = 0


def _bucket_order(n: int, order: str, layers: Sequence[LayerCost],
                  seed: int) -> List[int]:
    if order == "tictac":
        return tictac_order(layers)
    if order == "random":
        return random_order(layers, seed)
    if order == "layer":
        return list(range(n))
    raise ValueError(order)


def _plan_buckets(params_example, bucket_mb: float, order: str,
                  back_s_per_byte: float, seed: int
                  ) -> Tuple[List[List[int]], List[int], List[LayerCost]]:
    """Fuse gradient leaves (backward = reverse-pytree order) into buckets
    of ~bucket_mb and choose the transfer issue order.  This single plan is
    shared by the executed schedule (both architectures) and the analytic
    timeline model."""
    leaves = jax.tree.leaves(params_example)
    layers = [LayerCost(f"g{i}", back_s_per_byte * x.size * 4, x.size * 4)
              for i, x in enumerate(leaves)]
    fused = bucketize(layers, bucket_mb * 1e6)
    buckets = [[int(nm[1:]) for nm in b.name.split("+")] for b in fused]
    order_idx = _bucket_order(len(fused), order, fused, seed)
    return buckets, order_idx, fused


def _leaf_meta(params_example):
    return (jax.tree.structure(params_example),
            [(x.shape, x.dtype) for x in jax.tree.leaves(params_example)])


def _scatter_flat(flat, idxs, leaf_shapes, out):
    """Split a fused bucket vector back into its leaves (into ``out``)."""
    off = 0
    for i in idxs:
        shape, dtype = leaf_shapes[i]
        size = int(np.prod(shape)) if shape else 1
        out[i] = flat[off:off + size].reshape(shape).astype(dtype)
        off += size
    return out


def make_bucketed_allreduce(params_example, topology: str = "ring",
                            bucket_mb: float = 4.0, order: str = "tictac",
                            back_s_per_byte: float = 2e-12,
                            seed: int = 0, axis: str = AXIS):
    """Standalone grads->grads mean-allreduce for use inside ``shard_map``
    (e.g. as ``make_train_step(..., reduce_fn=...)``): leaves fused into
    ~bucket_mb buckets (backward order), issued in the chosen transfer
    order, each reduced with the topology-explicit schedule."""
    reduce_leaf = TOPOLOGIES[topology]
    buckets, order_idx, fused = _plan_buckets(
        params_example, bucket_mb, order, back_s_per_byte, seed)
    treedef, leaf_shapes = _leaf_meta(params_example)

    def reduce_grads(grads):
        leaves = jax.tree.leaves(grads)
        n = axis_size(axis)
        out: List[Any] = [None] * len(leaves)
        for b in order_idx:                   # the executed schedule
            idxs = buckets[b]
            flat = jnp.concatenate(
                [leaves[i].astype(jnp.float32).reshape(-1) for i in idxs])
            red = reduce_leaf(flat, axis) / n
            _scatter_flat(red, idxs, leaf_shapes, out)
        return jax.tree.unflatten(treedef, out)

    reduce_grads.fused_layers = fused
    reduce_grads.order = order_idx
    return reduce_grads


def make_bucketed_ps_update(params_example, lr: float,
                            bucket_mb: float = 4.0, order: str = "tictac",
                            back_s_per_byte: float = 2e-12,
                            seed: int = 0, axis: str = AXIS):
    """Centralized (params, grads) -> new params for use inside
    ``shard_map``: the same fused-bucket plan and issue order as
    ``make_bucketed_allreduce``, but each bucket takes the parameter-server
    path of ``core.parameter_server`` — reduce-scatter the bucket's summed
    gradient, SGD-update only my 1/n shard (the "server" work, ZeRO-style),
    and all-gather the updated shard back.  Traffic per device equals the
    ring allreduce; update FLOPs drop by n."""
    buckets, order_idx, fused = _plan_buckets(
        params_example, bucket_mb, order, back_s_per_byte, seed)
    treedef, leaf_shapes = _leaf_meta(params_example)

    def ps_update(params, grads):
        n = axis_size(axis)
        p_leaves = jax.tree.leaves(params)
        g_leaves = jax.tree.leaves(grads)
        # lists, NOT dicts: jax flattens dict keys in sorted order, which
        # would silently retrace the collectives in lexicographic bucket
        # order; list position preserves the planned issue order
        pb = [jnp.concatenate([p_leaves[i].astype(jnp.float32).reshape(-1)
                               for i in buckets[b]]) for b in order_idx]
        gb = [jnp.concatenate([g_leaves[i].astype(jnp.float32).reshape(-1)
                               for i in buckets[b]]) for b in order_idx]
        step = make_ps_step(sgd_update_fn(lr, mean_over=n), axis)
        new_pb, _ = step(pb, gb, None)
        out: List[Any] = [None] * len(p_leaves)
        for flat, b in zip(new_pb, order_idx):
            _scatter_flat(flat, buckets[b], leaf_shapes, out)
        return jax.tree.unflatten(treedef, out)

    ps_update.fused_layers = fused
    ps_update.order = order_idx
    return ps_update


def make_sharded_train_step(train_step: Callable, mesh: Mesh,
                            compressed: bool):
    """Lift a ``make_train_step`` step (whose ``reduce_fn`` already
    all-reduces over ``AXIS``) into a jitted shard_map over the worker
    axis: batch is sharded, EF state (when compressing) stays per-worker,
    params/optimizer state are replicated, metrics come back worker-meaned.

    The returned function has the ``train_loop`` contract
    ``step(state, stacked_batch, rng) -> (state, metrics)`` — pass
    ``jit=False`` to ``train_loop`` since it is already compiled."""

    def body(state, batch, rng):
        batch = jax.tree.map(lambda x: x[0], batch)
        rng = jax.random.fold_in(rng, jax.lax.axis_index(AXIS))
        if compressed:
            state = dict(state,
                         ef=jax.tree.map(lambda x: x[0], state["ef"]))
        new_state, mets = train_step(state, batch, rng)
        if compressed:
            new_state = dict(
                new_state,
                ef=jax.tree.map(lambda x: x[None], new_state["ef"]))
        mets = {k: jax.lax.pmean(jnp.asarray(v, jnp.float32), AXIS)
                for k, v in mets.items()}
        return new_state, mets

    ef_spec = P(AXIS) if compressed else P()
    state_spec = {"params": P(), "opt_state": P(), "step": P(),
                  "ef": ef_spec}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(state_spec, P(AXIS), P()),
                   out_specs=(state_spec, P()),
                   check_vma=False)
    return jax.jit(fn)


class DeviceEngine(ElasticWorkerSet):
    """Executable {bsp,ssp,asp} × {allreduce,ps} over N host devices;
    drop-in comparable with ``SimSyncEngine``: ``init / step / finalize``
    plus a composed ``run`` with the same signature and the same
    ``(params, history, wire_bytes)`` triple."""

    def __init__(self, cfg: DataParallelConfig, grad_fn: Callable,
                 devices: Optional[Sequence] = None):
        if cfg.sync not in DEVICE_SYNCS:
            raise ValueError(
                f"sync={cfg.sync!r} is not device-executable "
                f"(supported: {DEVICE_SYNCS}; sma is simulated-only)")
        if cfg.arch not in ARCHS:
            raise ValueError(f"arch={cfg.arch!r} (supported: {ARCHS})")
        if cfg.backup and cfg.sync != "bsp":
            raise ValueError("backup workers compose with bsp only "
                             "(async modes have no round to drop from)")
        if cfg.backup >= cfg.num_workers:
            raise ValueError("backup k must leave at least one worker")
        self.cfg = cfg
        self.grad_fn = grad_fn
        self._devs = list(devices or jax.devices())
        if len(self._devs) < cfg.num_workers:
            raise ValueError(
                f"need {cfg.num_workers} devices, have {len(self._devs)} "
                "(run under XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        self.mesh = Mesh(np.array(self._devs[:cfg.num_workers]), (AXIS,))
        self.periods = cfg.periods or default_periods(cfg.num_workers)
        assert len(self.periods) == cfg.num_workers
        self.slowdowns: List[float] = [1.0] * cfg.num_workers
        self._dropped = 0
        self._init_detector(cfg.detect, cfg.num_workers)
        self._step_fn = None
        self._wire_cell: List[int] = []
        self._async_fns = None
        self._wire_total = 0
        # same replicated apply as the simulator uses (allreduce arch)
        self._apply = jax.jit(
            lambda p, g, lr: jax.tree.map(lambda a, b: a - lr * b, p, g))

    @property
    def _ef_active(self) -> bool:
        return self.cfg.compressor.method in EF_METHODS

    # ------------------------------------------------------------- planning
    def _bucket_plan(self, params) -> Tuple[List[List[int]], List[int],
                                            List[LayerCost]]:
        return _plan_buckets(params, self.cfg.bucket_mb, self.cfg.order,
                             self.cfg.back_s_per_byte, self.cfg.seed)

    def modeled_timeline(self, params) -> Dict[str, float]:
        """Iteration-time projections for the exact bucket plan this engine
        executes — the benchmark's no-overlap vs overlap comparison."""
        _, order, fused = self._bucket_plan(params)
        return {
            "no_overlap_s": schedule_no_overlap(fused, self.cfg.link),
            "overlap_s": schedule_overlap(fused, self.cfg.link, order),
            "n_buckets": len(fused),
        }

    def per_event_wire_bytes(self, params) -> int:
        """Bytes one worker puts on the wire per gradient push (compressor
        accounting; shape-static).  Identical for both architectures."""
        comp = self.cfg.compressor
        state = comp.init_state(params)
        zeros = jax.tree.map(jnp.zeros_like, params)
        _, _, wb = comp.roundtrip(zeros, state, jax.random.PRNGKey(0))
        return int(wb)

    def wire_bytes_per_step(self, params) -> int:
        """Bytes per BSP step summed over workers, like the simulator."""
        return self.per_event_wire_bytes(params) * self.cfg.num_workers

    # --------------------------------------------------------- bsp stepping
    def _build_step(self, params_example):
        cfg = self.cfg
        comp = cfg.compressor
        bucketed_ps = (make_bucketed_ps_update(
            params_example, cfg.lr, bucket_mb=cfg.bucket_mb,
            order=cfg.order, back_s_per_byte=cfg.back_s_per_byte,
            seed=cfg.seed) if cfg.arch == "ps" else None)
        bucketed_allreduce = (make_bucketed_allreduce(
            params_example, topology=cfg.topology, bucket_mb=cfg.bucket_mb,
            order=cfg.order, back_s_per_byte=cfg.back_s_per_byte,
            seed=cfg.seed) if cfg.arch != "ps" else None)
        # compressor wire counts are shape-static Python ints at trace
        # time; capture them host-side rather than threading them through
        # the device as int32 (which overflows past 2 GiB/step); the entry
        # is per worker-event — the host multiplies by the participant
        # count (all K, or K-k under backup)
        wire_cell: List[int] = []

        def sharded_step(params, ef, batch, rng, weight):
            # params replicated; ef/batch/rng/weight carry a worker axis.
            # weight is this worker's aggregation weight: 1 normally,
            # K/(K-k) for backup-round participants, 0 for dropped
            # stragglers (whose push never reaches the server and whose
            # EF state is therefore not consumed).
            batch = jax.tree.map(lambda x: x[0], batch)
            ef_in = (jax.tree.map(lambda x: x[0], ef)
                     if ef is not None else None)
            rng = rng[0]
            wt = weight[0]
            loss, grads = self.grad_fn(params, batch)
            if comp.method != "none":
                grads, ef_new, wb = comp.roundtrip(grads, ef_in, rng)
            else:
                ef_new = ef_in
                wb = sum(int(x.size) * 4 for x in jax.tree.leaves(grads))
            if not wire_cell:
                wire_cell.append(int(wb))
            grads = jax.tree.map(lambda x: x * wt, grads)
            if cfg.arch == "ps":
                new_params = bucketed_ps(params, grads)
            else:
                avg = bucketed_allreduce(grads)
                new_params = jax.tree.map(lambda p, g: p - cfg.lr * g,
                                          params, avg)
            if ef_new is not None:
                ef_out = jax.tree.map(
                    lambda new, old: jnp.where(wt > 0, new, old),
                    ef_new, ef_in)
                ef_out = jax.tree.map(lambda x: x[None], ef_out)
            else:
                ef_out = ef
            return (new_params, ef_out, loss[None])

        ef_spec = P(AXIS) if self._ef_active else P()
        fn = shard_map(sharded_step, mesh=self.mesh,
                       in_specs=(P(), ef_spec, P(AXIS), P(AXIS), P(AXIS)),
                       out_specs=(P(), ef_spec, P(AXIS)),
                       check_vma=False)
        return jax.jit(fn), wire_cell

    def _step_bsp(self, st, batches, t):
        K = self.cfg.num_workers
        if self._step_fn is None:
            self._step_fn, self._wire_cell = self._build_step(st["params"])
        # backup workers: drop the k slowest — scheduled ranking, or the
        # measured step-time EMA once detection warms up (the same shared
        # backup_drop rule the simulator applies)
        drop = self.backup_drop(self.cfg.backup)
        weights = participation_weights(K, drop)
        if self.detector is not None:
            # per-worker batch fetch is the only per-worker host work in
            # the fused device step — measure it (a straggling input
            # pipeline is the detectable straggler here)
            per_worker = []
            for w in range(K):
                t0 = time.perf_counter()
                per_worker.append(batches(t, w))
                self.detector.observe(w, time.perf_counter() - t0)
        else:
            per_worker = [batches(t, w) for w in range(K)]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *per_worker)
        st["rng"], *subs = jax.random.split(st["rng"], K + 1)
        params, ef, losses = self._step_fn(
            st["params"], st["ef"], batch, jnp.stack(subs),
            jnp.asarray(weights))
        st.update(params=params, ef=ef)
        st["wire"] += self._wire_cell[0] * (K - len(drop))
        self._dropped += len(drop)
        # participant-mean loss, float64 like the simulator's accounting
        part_losses = [float(losses[w]) for w in range(K) if w not in drop]
        ev = dict(step=t, loss=float(np.mean(part_losses)), max_staleness=0)
        if drop:
            ev["dropped"] = sorted(drop)
        return st, [ev]

    # --------------------------------------------------- ssp / asp stepping
    def _build_async_fns(self, params_example):
        cfg = self.cfg
        comp = cfg.compressor

        def grad_body(pulled, ef, batch, key, fire):
            # every input carries a leading worker axis; each worker sees
            # its own row and computes against its *stale* pulled params
            pulled = jax.tree.map(lambda x: x[0], pulled)
            batch = jax.tree.map(lambda x: x[0], batch)
            key = key[0]
            fire = fire[0]
            loss, g = self.grad_fn(pulled, batch)
            if comp.method != "none":
                ef_w = (jax.tree.map(lambda x: x[0], ef)
                        if ef is not None else None)
                g, ef_new, _wb = comp.roundtrip(g, ef_w, key)
                if ef_new is not None:
                    # only firing workers consume their error-feedback state
                    ef_out = jax.tree.map(
                        lambda new, old: jnp.where(fire > 0, new, old),
                        ef_new, ef_w)
                    ef_out = jax.tree.map(lambda x: x[None], ef_out)
                else:
                    ef_out = ef
            else:
                ef_out = ef
            g = jax.tree.map(lambda x: x[None], g)
            return loss[None], g, ef_out

        ef_spec = P(AXIS) if self._ef_active else P()
        grad_fn = jax.jit(shard_map(
            grad_body, mesh=self.mesh,
            in_specs=(P(AXIS), ef_spec, P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(AXIS), P(AXIS), ef_spec),
            check_vma=False))

        ps_apply = None
        if cfg.arch == "ps":
            step = make_ps_step(sgd_update_fn(cfg.lr), AXIS)

            def ps_body(params, g_stack, onehot):
                # the firing worker pushes its gradient; everyone else
                # contributes exact zeros, so the reduce-scatter delivers
                # the push to each shard's owner, which updates and
                # all-gathers back — a literal single-worker PS push
                g_mine = jax.tree.map(lambda x: x[0], g_stack)
                o = onehot[0]
                contrib = jax.tree.map(lambda x: x * o, g_mine)
                new_params, _ = step(params, contrib, None)
                return new_params

            ps_apply = jax.jit(shard_map(
                ps_body, mesh=self.mesh,
                in_specs=(P(), P(AXIS), P(AXIS)),
                out_specs=P(),
                check_vma=False))
        return grad_fn, ps_apply

    def _step_async(self, st, batches, t, bound: Optional[int]):
        """Replay the simulator's deterministic tick schedule: gradient
        compute for the whole worker set runs data-parallel on devices;
        the tick's firing events then apply in the simulator's worker
        order (each pushing through the configured architecture)."""
        cfg = self.cfg
        K = cfg.num_workers
        comp = cfg.compressor
        if self._async_fns is None:
            self._async_fns = self._build_async_fns(st["params"])
            self._event_wire = self.per_event_wire_bytes(st["params"])
        grad_fn, ps_apply = self._async_fns
        events = []
        eff_periods = self.effective_periods()   # invariant within a step
        while st["updates"] - st["updates_base"] < \
                (t + 1 - st["step_base"]) * K:
            st["tick"] += 1
            # the same deterministic schedule the simulator executes
            firing = firing_schedule(st["tick"], eff_periods,
                                     st["batch_idx"], bound)
            if not firing:
                continue
            fire = np.zeros((K,), np.float32)
            fire[firing] = 1.0
            # a worker's batch index only advances at its own events, so
            # its batch is cached until it fires (invalidated below)
            for w in range(K):
                if st["batch_cache"][w] is None:
                    st["batch_cache"][w] = batches(st["batch_idx"][w], w)
            batch = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *st["batch_cache"])
            # mirror the simulator's rng stream: one split per firing event
            keys = [jax.random.PRNGKey(0)] * K
            if comp.method != "none":
                for w in firing:
                    st["rng"], sub = jax.random.split(st["rng"])
                    keys[w] = sub
            pulled_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *st["pulled"])
            losses, grads, st["ef"] = grad_fn(
                pulled_stack, st["ef"], batch, jnp.stack(keys),
                jnp.asarray(fire))
            for w in firing:
                staleness = st["server_ver"] - st["pulled_ver"][w]
                if cfg.arch == "ps":
                    onehot = np.zeros((K,), np.float32)
                    onehot[w] = 1.0
                    st["params"] = ps_apply(st["params"], grads,
                                            jnp.asarray(onehot))
                else:
                    g_w = jax.tree.map(lambda x: x[w], grads)
                    st["params"] = self._apply(st["params"], g_w, cfg.lr)
                st["server_ver"] += 1
                st["updates"] += 1
                st["pulled"][w] = st["params"]   # pull = reference rebind
                st["pulled_ver"][w] = st["server_ver"]
                st["batch_idx"][w] += 1
                st["batch_cache"][w] = None
                st["wire"] += self._event_wire
                events.append(dict(step=st["updates"],
                                   loss=float(losses[w]),
                                   max_staleness=staleness, worker=w))
        return st, events

    # -------------------------------------------------- engine protocol
    def init(self, params) -> Dict[str, Any]:
        cfg = self.cfg
        K = cfg.num_workers
        ef = (jax.tree.map(
            lambda x: jnp.zeros((K,) + x.shape, jnp.float32), params)
            if self._ef_active else None)
        st: Dict[str, Any] = dict(
            params=params, ef=ef, rng=jax.random.PRNGKey(cfg.seed), wire=0)
        if cfg.sync in ("ssp", "asp"):
            st.update(
                # per-worker pulled copies are reference rebinds (like the
                # simulator); they are stacked once per tick for shard_map
                pulled=[params] * K,
                pulled_ver=[0] * K,
                server_ver=0,
                tick=0,
                updates=0,
                batch_idx=[0] * K,
                batch_cache=[None] * K,
                # reshard rebases the step↔update accounting here (one
                # global step = K updates at the *current* K)
                updates_base=0,
                step_base=0,
            )
        return st

    def step(self, st, batches: Callable[[int, int], Any], t: int):
        sync = self.cfg.sync
        if sync == "bsp":
            st, ev = self._step_bsp(st, batches, t)
        elif sync == "ssp":
            st, ev = self._step_async(st, batches, t, self.cfg.staleness)
        else:
            st, ev = self._step_async(st, batches, t, None)
        self._wire_total = st["wire"]
        return st, ev

    def finalize(self, st):
        return st["params"]

    def wire_bytes(self) -> int:
        return self._wire_total

    def per_device_state_bytes(self, st) -> Dict[str, int]:
        """Measured persistent bytes per device — comparable with the
        hybrid engine's accounting (benchmarks/hybrid_bench.py).  Plain
        SGD carries no optimizer state; params are replicated, EF
        residuals are per-worker."""
        K = self.cfg.num_workers
        params = sum(np.asarray(x).nbytes
                     for x in jax.tree.leaves(st["params"]))
        ef = (sum(np.asarray(x).nbytes
                  for x in jax.tree.leaves(st["ef"])) // K
              if st.get("ef") is not None else 0)
        return {"params": params, "opt": 0, "ef": ef, "total": params}

    # --------------------------------------------------- elastic interface
    # (set_slowdown / effective_periods / dropped_updates come from the
    # shared ElasticWorkerSet, so the schedule rule cannot diverge from
    # the simulator's)
    def reshard(self, st, new_workers: int, step: int = 0,
                lost: Tuple[int, ...] = ()):
        """Re-size the worker set N→M *in the same process*: rebuild the
        mesh over the first M live devices, invalidate the compiled step
        functions (the bucket plan is re-planned for the new mesh on the
        next step), and remap per-worker state — survivors (old slots
        minus ``lost``, in order) keep their EF residuals and batch
        clocks, grown slots start with zero residuals at the batch
        frontier.  A reshard is a synchronization barrier: every async
        worker re-pulls the current params, and the step↔update
        accounting rebases at global step ``step``."""
        cfg = self.cfg
        if new_workers < 1:
            raise ValueError("new_workers must be >= 1")
        if cfg.backup >= new_workers:
            raise ValueError(f"backup k={cfg.backup} needs > k workers")
        if new_workers > len(self._devs):
            raise ValueError(
                f"resize to {new_workers} workers needs {new_workers} "
                f"devices, have {len(self._devs)}")
        bad = [w for w in lost if w < 0 or w >= cfg.num_workers]
        if bad:
            raise ValueError(f"lost workers {bad} out of range for "
                             f"{cfg.num_workers} workers")
        survivors = [w for w in range(cfg.num_workers) if w not in set(lost)]
        slots = survivors[:new_workers]
        grown = new_workers - len(slots)
        # survivors keep their speed identity (like their slowdowns and
        # EF state); grown slots take the default-schedule tail
        periods = tuple([self.periods[s] for s in slots]
                        + list(default_periods(new_workers))[len(slots):])
        self.cfg = cfg = dataclasses.replace(
            cfg, num_workers=new_workers, periods=periods)
        self.mesh = Mesh(np.array(self._devs[:new_workers]), (AXIS,))
        self.periods = periods
        self.slowdowns = [self.slowdowns[s] for s in slots] + [1.0] * grown
        if self.detector is not None:
            self.detector.reshard(slots, new_workers)
        self._step_fn, self._wire_cell = None, []
        self._async_fns = None
        if st.get("ef") is not None:
            def remap_rows(x):     # (K_old,)+s -> (M,)+s
                rows = ([x[s] for s in slots]
                        + [jnp.zeros_like(x[0])] * grown)
                return jnp.stack(rows)
            st["ef"] = jax.tree.map(remap_rows, st["ef"])
        if cfg.sync in ("ssp", "asp"):
            frontier = max([st["batch_idx"][s] for s in slots] or [0])
            st["pulled"] = [st["params"]] * new_workers
            st["pulled_ver"] = [st["server_ver"]] * new_workers
            st["batch_idx"] = ([st["batch_idx"][s] for s in slots]
                               + [frontier] * grown)
            st["batch_cache"] = [None] * new_workers
            st["updates_base"] = st["updates"]
            st["step_base"] = step
        # arrays committed to the old mesh's devices would clash with the
        # new mesh inside jit — pull them to host; the next step re-places
        # them on the resized mesh
        for key in ("params", "ef", "pulled", "rng"):
            if st.get(key) is not None:
                st[key] = jax.device_get(st[key])
        return st

    def export_state(self, st) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Split the run-state into (array pytree, JSON-able meta) for
        ``repro.checkpoint`` — the inverse of ``import_state``.  The
        per-worker batch cache is dropped: batches are a pure function of
        (batch_idx, worker), so resume re-fetches identical tensors."""
        cfg = self.cfg
        arrays: Dict[str, Any] = {"params": st["params"], "ef": st["ef"],
                                  "rng": st["rng"]}
        meta: Dict[str, Any] = dict(
            backend="device", mode=cfg.sync, num_workers=cfg.num_workers,
            wire=int(st["wire"]), periods=list(self.periods),
            slowdowns=list(self.slowdowns), dropped=self._dropped,
            detector=(self.detector.state() if self.detector is not None
                      else None))
        if cfg.sync in ("ssp", "asp"):
            arrays["pulled"] = st["pulled"]
            meta.update(pulled_ver=list(st["pulled_ver"]),
                        server_ver=int(st["server_ver"]),
                        tick=int(st["tick"]), updates=int(st["updates"]),
                        batch_idx=list(st["batch_idx"]),
                        updates_base=int(st["updates_base"]),
                        step_base=int(st["step_base"]))
        return arrays, meta

    def import_state(self, arrays: Dict[str, Any], meta: Dict[str, Any]):
        """Rebuild the run-state from an ``export_state`` snapshot.  The
        engine must already be configured at ``meta['num_workers']``."""
        cfg = self.cfg
        if meta["num_workers"] != cfg.num_workers:
            raise ValueError(
                f"snapshot has {meta['num_workers']} workers, engine has "
                f"{cfg.num_workers}; reshard the engine first")
        # the worker speed schedule travels with the snapshot: a resharded
        # run's remapped periods must survive a cross-process restore
        self.periods = tuple(int(p) for p in meta["periods"])
        self.cfg = cfg = dataclasses.replace(cfg, periods=self.periods)
        self.slowdowns = [float(s) for s in meta["slowdowns"]]
        self._dropped = int(meta["dropped"])
        if self.detector is not None:
            self.detector.load_state(meta.get("detector"))
        st: Dict[str, Any] = dict(
            params=arrays["params"], ef=arrays["ef"],
            rng=jnp.asarray(arrays["rng"]), wire=int(meta["wire"]))
        if cfg.sync in ("ssp", "asp"):
            st.update(pulled=arrays["pulled"],
                      pulled_ver=list(meta["pulled_ver"]),
                      server_ver=int(meta["server_ver"]),
                      tick=int(meta["tick"]), updates=int(meta["updates"]),
                      batch_idx=list(meta["batch_idx"]),
                      batch_cache=[None] * cfg.num_workers,
                      updates_base=int(meta["updates_base"]),
                      step_base=int(meta["step_base"]))
        self._wire_total = st["wire"]
        return st

    # ------------------------------------------------------------------ run
    def run(self, params, batches: Callable[[int, int], Any], steps: int):
        """batches(t, worker) -> batch pytree (same contract as
        ``SimSyncEngine.run``).  Returns (params, history, wire_bytes)."""
        st = self.init(params)
        hist: List[dict] = []
        for t in range(steps):
            st, ev = self.step(st, batches, t)
            hist.extend(ev)
        return self.finalize(st), hist, st["wire"]


class DataParallelEngine(DeviceEngine):
    """Deprecated PR-1 alias for ``DeviceEngine`` — kept so existing call
    sites keep working.  Use ``repro.train.Strategy(sync=..., arch=...,
    backend='device').build(grad_fn)`` which wraps the same engine
    (bitwise-identical results)."""

    def __init__(self, cfg: DataParallelConfig, grad_fn: Callable,
                 devices: Optional[Sequence] = None):
        warn_deprecated("DataParallelEngine",
                        "repro.train.Strategy(...).build(grad_fn)")
        super().__init__(cfg, grad_fn, devices)
