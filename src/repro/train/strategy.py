"""One declarative ``Strategy`` surface for the survey's §3.3 cross-product.

The survey's core taxonomy is a cross-product — architecture (centralized
PS vs decentralized allreduce, §3.3.1) × synchronization (BSP/SSP/ASP/SMA,
§3.3.2) × gradient compression (§3.3.3) — and this module exposes it as
one frozen spec with interchangeable execution backends:

    Strategy(sync="ssp", arch="ps", compression="onebit", workers=8)
        .build(grad_fn)            # -> Engine (device or simulated)

or, equivalently, from a spec string (the examples' ``--strategy`` flag):

    Strategy.parse("ssp:3/ps/onebit@8")

The device topology is a further declarative dimension (docs/hybrid.md):
a mesh suffix after the worker count shapes the devices into a
data × tensor × stage mesh with optional ZeRO state sharding —

    Strategy.parse("bsp/ring/onebit@8:d2.t2.s2")   # 3D hybrid mesh
    Strategy.parse("bsp/ps/none@4:d4.z3.adamw")    # ZeRO-3 sharded AdamW

Hybrid cells execute on the ``HybridEngine`` of ``repro.parallel``; a
trivial mesh (``dK.t1.s1``, z0, sgd) is *exactly* the data-parallel
device engine (same object, bitwise).

Backends (the ``BACKENDS`` registry):

  sim     ``SimSyncEngine`` — the deterministic discrete-event simulation
          of core/sync.py.  Any sync model, any compressor, single device.
          Architecture is semantically transparent here: the simulated
          server *is* the PS, and RS+AG traffic equals ring-allreduce
          traffic, so both arches produce identical trajectories.
  device  ``DeviceEngine`` — N virtual/real devices under shard_map
          (train/data_parallel.py).  BSP natively; SSP/ASP by replaying
          the simulator's deterministic staleness schedule with gradient
          compute data-parallel on devices; arch=ps routed through the
          reduce-scatter/all-gather ZeRO path of core/parameter_server.py
          over the same bucket plan as allreduce; SMA with per-worker
          replicas whose center is a CommPlan exchange.

Every exchange executes a ``repro.comm.CommPlan``; the ``wire`` field
selects modeled (per-worker roundtrip, analytic bytes — simulator
cross-validatable) or measured (encoded payloads inside the collective
schedule, bytes counted from the planes exchanged) — docs/comm.md.

Every engine follows the ``Engine`` protocol (``init / step / finalize /
metrics``) and is driven by the single ``Trainer.fit`` loop, which is the
same ``train_loop`` that drives ``make_train_step`` steps.

``registered_cells()`` enumerates the supported (sync, arch, compression,
backend) cells; ``tools/strategy_smoke.py`` executes every one of them
(the ``make strategies`` tier-1 gate), and docs/strategies.md renders the
matrix.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple, Union)

import jax

from repro.core.allreduce import TOPOLOGIES
from repro.core.compression import EF_METHODS, METHODS, Compressor
from repro.kernels.backend import KERNEL_BACKENDS
from repro.core.sync import SimSyncEngine, SyncConfig
from repro.parallel.mesh_plan import (MeshSpec, OPTIMIZERS, PRECISIONS,
                                      SCHEDULES, parse_suffix, suffix_spec)
from repro.train.data_parallel import (ARCHS, DEVICE_SYNCS,
                                       DataParallelConfig, DeviceEngine)
from repro.train.train_loop import train_loop

SYNCS = ("bsp", "ssp", "asp", "sma")
# the ISSUE-2 acceptance matrix rows (sma device support came later and
# is registered separately, so the frozen acceptance set stays stable)
MATRIX_SYNCS = ("bsp", "ssp", "asp")
# the tested compression column set: the EF methods plus the baseline
MATRIX_METHODS = ("none",) + EF_METHODS
WIRE_MODES = ("modeled", "measured")
_DENSITY_DEFAULT = 0.01


class Cell(NamedTuple):
    """One point of the sync × arch × compression matrix on a backend."""
    sync: str
    arch: str
    compression: str
    backend: str


# the ISSUE-2 acceptance matrix: every one of these cells must stay
# registered and device-executable — `make strategies` and
# tests/test_strategy.py both enforce this single set
ACCEPTANCE_CELLS = frozenset(
    Cell(s, a, c, "device")
    for s in MATRIX_SYNCS for a in ARCHS for c in MATRIX_METHODS)


def registered_cells() -> List[Cell]:
    """Every supported Strategy cell.  ``make strategies`` executes each of
    these for 2 steps on 2 virtual devices and fails if any cell in this
    registry goes untested."""
    cells: List[Cell] = []
    # device: the full EF matrix, plus the stateless quantizers under BSP
    for s in MATRIX_SYNCS:
        for a in ARCHS:
            for c in MATRIX_METHODS:
                cells.append(Cell(s, a, c, "device"))
    for c in ("terngrad", "qsgd"):
        for a in ARCHS:
            cells.append(Cell("bsp", a, c, "device"))
    # sim: staleness replay source of truth + the SMA model on both
    # backends (device SMA exchanges replicas through the CommPlan)
    for s in MATRIX_SYNCS:
        for c in MATRIX_METHODS:
            cells.append(Cell(s, "allreduce", c, "sim"))
    cells.append(Cell("sma", "allreduce", "none", "sim"))
    cells.append(Cell("sma", "allreduce", "none", "device"))
    return cells


@dataclasses.dataclass(frozen=True)
class Strategy:
    """Frozen declarative spec for one cell of the survey's taxonomy.

    ``compression`` may be a method name (a ``Compressor`` is derived with
    ``density``) or a fully-configured ``Compressor``.  ``backend="auto"``
    picks the device backend when the process has >= ``workers`` devices
    and the cell is device-executable, else the simulator."""
    sync: str = "bsp"                # bsp | ssp | asp | sma
    arch: str = "allreduce"          # allreduce | ps
    compression: Union[str, Compressor] = "none"
    workers: int = 4
    backend: str = "auto"            # auto | sim | device
    # kernel backend seam (docs/kernels.md): which implementation the
    # codec math runs on — "kernel" the Pallas kernels, "ref" the jnp
    # oracles, "auto" resolved per host (TPU -> kernel, else ref; the
    # REPRO_KERNEL_BACKEND env var overrides "auto").  Applies when
    # ``compression`` is a method name or a Compressor left at
    # backend="auto"; a Compressor with an explicit backend wins.
    kernel_backend: str = "auto"     # auto | kernel | ref
    staleness: int = 3               # SSP bound s
    backup: int = 0                  # BSP backup workers: drop the k slowest
    lr: float = 0.1
    topology: str = "ring"           # device allreduce schedule
    bucket_mb: float = 4.0           # device gradient bucket fusion
    order: str = "tictac"            # device bucket issue order
    periods: Optional[Tuple[int, ...]] = None   # worker speeds (sim schedule)
    sma_mu: float = 0.1              # SMA correction strength
    density: float = _DENSITY_DEFAULT   # dgc density (compression as str)
    seed: int = 0
    # hybrid mesh dimensions (docs/hybrid.md): None mesh = pure data
    # parallelism at `workers`; a non-trivial mesh, a ZeRO level, or a
    # stateful optimizer routes the cell to repro.parallel.HybridEngine
    mesh: Optional[Union[str, MeshSpec]] = None
    zero: int = 0                    # ZeRO optimizer-state level 0-3
    optimizer: str = "sgd"           # sgd | adamw
    micro_batches: int = 0           # pipeline micro-batches (0 = auto)
    schedule: str = "gpipe"          # pipeline schedule: gpipe | 1f1b
    interleave: int = 0              # 1f1b virtual stages/device (0 = auto)
    precision: str = "fp32"          # fp32 | bf16 | bf16r (docs/hybrid.md)
    moments: str = "float32"         # adamw moment storage: float32|bfloat16
    detect: bool = False             # measured straggler detection (bsp)
    # wire accounting / exchange mode (docs/comm.md): "modeled" keeps
    # compression as a per-worker roundtrip with analytic byte accounting
    # (simulator-matching); "measured" moves the encoded payloads inside
    # the collective schedule and counts the planes actually exchanged
    wire: str = "modeled"

    def __post_init__(self):
        if self.sync not in SYNCS:
            raise ValueError(f"sync={self.sync!r} not in {SYNCS}")
        if self.arch not in ARCHS:
            raise ValueError(f"arch={self.arch!r} not in {ARCHS}")
        method = (self.compression.method
                  if isinstance(self.compression, Compressor)
                  else self.compression)
        if method not in METHODS:
            raise ValueError(f"compression={method!r} not in {METHODS}")
        if self.backend not in ("auto", "sim", "device"):
            raise ValueError(f"backend={self.backend!r}")
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(f"kernel_backend={self.kernel_backend!r} not "
                             f"in {KERNEL_BACKENDS}")
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        if self.staleness < 0:
            # a negative SSP bound blocks every worker forever
            raise ValueError("staleness must be >= 0")
        if self.backup < 0:
            raise ValueError("backup must be >= 0")
        if self.backup and self.sync != "bsp":
            # the backup-worker technique drops stragglers from a
            # synchronous round; async modes have no round to drop from
            raise ValueError("backup workers compose with bsp only")
        if self.backup >= self.workers:
            raise ValueError("backup k must leave at least one worker")
        if self.sync == "sma" and method != "none":
            # the SMA engine exchanges replicas, not gradients — it has no
            # compression path, so a compressed spec would silently run
            # uncompressed (docs/strategies.md marks these cells "—")
            raise ValueError("sma does not compose with compression; "
                             "use compression='none'")
        if self.sync == "sma" and self.arch != "allreduce":
            raise ValueError("sma exchanges replicas decentralized; use "
                             "arch='allreduce'")
        if self.wire not in WIRE_MODES:
            raise ValueError(f"wire={self.wire!r} not in {WIRE_MODES}")
        if isinstance(self.compression, Compressor) and \
                self.density != _DENSITY_DEFAULT:
            # a full Compressor instance carries its own density — a
            # Strategy-level density would be silently ignored
            raise ValueError(
                "pass density inside the Compressor instance, not as a "
                "separate Strategy field")
        if isinstance(self.mesh, str):
            object.__setattr__(self, "mesh", MeshSpec.parse(self.mesh))
        if self.mesh is not None and self.mesh.size != self.workers:
            raise ValueError(
                f"mesh {self.mesh.spec()} has {self.mesh.size} devices but "
                f"workers={self.workers}")
        if self.mesh is not None and self.mesh.is_trivial:
            # dK.t1.s1 IS plain data parallelism — normalize so equal
            # strategies compare equal and the canonical spec is minimal
            object.__setattr__(self, "mesh", None)
        if self.zero not in (0, 1, 2, 3):
            raise ValueError(f"zero={self.zero} (ZeRO levels are 0..3)")
        if self.optimizer not in OPTIMIZERS:
            raise ValueError(f"optimizer={self.optimizer!r} not in "
                             f"{OPTIMIZERS}")
        if self.micro_batches < 0:
            raise ValueError("micro_batches must be >= 0")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule={self.schedule!r} not in "
                             f"{SCHEDULES}")
        if self.schedule == "1f1b" and self.mesh_spec.stage < 2:
            raise ValueError("schedule='1f1b' needs a pipeline (mesh "
                             "stage >= 2); an unstaged mesh has no "
                             "schedule to choose")
        if self.interleave < 0:
            raise ValueError("interleave must be >= 0")
        if self.interleave and self.schedule != "1f1b":
            # interleaving (virtual stages) is what distinguishes the
            # 1f1b schedule's bubble; under gpipe it would silently noop
            raise ValueError("interleave (vK) composes with the 1f1b "
                             "schedule only")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision={self.precision!r} not in "
                             f"{PRECISIONS}")
        if self.moments not in ("float32", "bfloat16"):
            raise ValueError(f"moments={self.moments!r} (want float32 | "
                             "bfloat16)")
        if self.moments != "float32" and self.optimizer != "adamw":
            # only adamw has EMA moment buffers to quantize — a qmom sgd
            # spec would silently store nothing in bf16
            raise ValueError("moments='bfloat16' (qmom) requires "
                             "optimizer='adamw'")
        if self.zero and self.arch != "ps":
            # ZeRO *is* the sharded-state (parameter-server) architecture;
            # a decentralized-allreduce ZeRO spec would be an oxymoron
            raise ValueError("zero > 0 requires arch='ps' (ZeRO shards "
                             "state through the reduce-scatter PS path)")
        if self.is_hybrid:
            if self.sync != "bsp":
                # async sync models (and SMA) compose with the *data
                # axis* of a mesh: replicated pulls per data slot,
                # tensor-sharded compute inside the slot.  They do not
                # compose with a pipeline schedule, sharded state, or a
                # stateful optimizer (docs/hybrid.md)
                ok = (self.mesh_spec.stage == 1 and self.zero == 0
                      and self.optimizer == "sgd"
                      and self.arch == "allreduce")
                if not ok:
                    raise ValueError(
                        f"sync={self.sync!r} on a hybrid mesh needs "
                        "stage=1, zero=0, optimizer='sgd', and "
                        "arch='allreduce' (asynchrony composes with the "
                        "data axis, not the pipeline schedule or sharded "
                        "state)")
            if self.backup:
                raise ValueError("backup workers do not compose with "
                                 "hybrid meshes yet")
            if self.detect:
                # the hybrid step has no backup-drop path to feed —
                # accepting the flag would silently measure nothing
                raise ValueError("straggler detection does not compose "
                                 "with hybrid meshes yet")
        if self.detect and self.sync != "bsp":
            raise ValueError("straggler detection feeds the bsp backup "
                             "drop set; use sync='bsp'")

    # ------------------------------------------------------------ derived
    @property
    def mesh_spec(self) -> MeshSpec:
        """The effective mesh: the declared one, or pure data parallelism
        over all workers."""
        return self.mesh if self.mesh is not None else MeshSpec(self.workers)

    @property
    def is_hybrid(self) -> bool:
        """True when the cell needs the hybrid engine: a non-trivial
        (tensor/stage) mesh, ZeRO sharding, a stateful optimizer, or a
        non-default schedule/precision/moments dimension."""
        return ((self.mesh is not None and not self.mesh.is_trivial)
                or self.zero > 0 or self.optimizer != "sgd"
                or self.schedule != "gpipe" or self.precision != "fp32"
                or self.moments != "float32")

    @property
    def compressor(self) -> Compressor:
        if isinstance(self.compression, Compressor):
            comp = self.compression
            if self.kernel_backend != "auto" and comp.backend == "auto":
                comp = dataclasses.replace(comp,
                                           backend=self.kernel_backend)
            return comp
        return Compressor(self.compression, density=self.density,
                          backend=self.kernel_backend)

    def spec(self) -> str:
        """Canonical spec string (inverse of ``parse``)."""
        sync = self.sync + (f":{self.staleness}" if self.sync == "ssp"
                            else "")
        if self.backup:
            sync = f"bsp+backup:{self.backup}"
        if self.detect:
            sync += "+detect"
        comp = self.compressor.method
        if comp == "dgc":
            comp += f":{self.compressor.density:g}"
        # a non-default topology rides in the arch slot (its alias form)
        # so the canonical spec reproduces the run it came from
        arch = self.arch
        if arch == "allreduce" and self.topology != "ring":
            arch = self.topology
        suffix = suffix_spec(self.mesh_spec, self.zero, self.optimizer,
                             self.micro_batches, self.schedule,
                             self.interleave, self.precision, self.moments)
        suffix = f":{suffix}" if suffix else ""
        return f"{sync}/{arch}/{comp}@{self.workers}{suffix}"

    @classmethod
    def parse(cls, spec: str, **defaults) -> "Strategy":
        """Parse ``sync[:staleness]/arch/comp[:density]@workers[:mesh]`` —
        every segment after ``sync`` optional, e.g. ``"bsp"``,
        ``"ssp:2/ps"``, ``"bsp/allreduce/onebit@8"``,
        ``"asp/ps/dgc:0.05@4"``, ``"bsp/ring/onebit@8:d2.t2.s2"``,
        ``"bsp/ps/none@4:d4.z3.adamw"``.  Keyword arguments are defaults
        for fields the spec string does not name; named segments always
        win."""
        fields = dict(defaults)
        s = spec.strip()
        if "@" in s:
            s, w = s.rsplit("@", 1)
            if ":" in w:
                # the mesh suffix (docs/hybrid.md): d/t/s axes + ZeRO
                # level + optimizer + micro-batches as dot tokens
                w, suffix = w.split(":", 1)
                suffix_fields, named = parse_suffix(suffix)
                for key, was_named in named.items():
                    if was_named:
                        fields[key] = suffix_fields[key]
            fields["workers"] = int(w)
        parts = s.split("/") if s else [""]
        if not parts[0]:
            raise ValueError(f"empty strategy spec: {spec!r}")
        if len(parts) > 3:
            raise ValueError(
                f"bad strategy spec {spec!r}: want sync[/arch[/comp]][@N]")
        sync = parts[0]
        if sync.endswith("+detect"):
            # measured straggler detection: per-worker step-time EMA
            # feeds the backup drop set (docs/elasticity.md)
            fields["detect"] = True
            sync = sync[: -len("+detect")]
        val = None
        if ":" in sync:
            sync, val = sync.split(":", 1)
        if sync == "bsp+backup":
            # the survey's backup-worker straggler mitigation as a sync
            # knob: bsp+backup:k drops the k slowest workers per round
            if val is None:
                raise ValueError(
                    f"bad strategy spec {spec!r}: bsp+backup needs a "
                    "count, e.g. bsp+backup:1")
            fields["backup"] = int(val)
            sync = "bsp"
        elif sync == "ssp":
            if val is not None:
                fields["staleness"] = int(val)
        elif val is not None:
            raise ValueError(
                f"bad strategy spec {spec!r}: only ssp takes a "
                f"staleness bound (got {sync}:{val})")
        fields["sync"] = sync
        if len(parts) > 1 and parts[1]:
            arch = parts[1]
            if arch in TOPOLOGIES:
                # topology names are arch aliases: "ssp:2/ring/onebit@4"
                # means decentralized allreduce over a ring schedule
                fields["arch"] = "allreduce"
                fields["topology"] = arch
            else:
                fields["arch"] = arch
        if len(parts) > 2 and parts[2]:
            comp = parts[2]
            if ":" in comp:
                comp, d = comp.split(":", 1)
                if comp != "dgc":
                    raise ValueError(
                        f"bad strategy spec {spec!r}: only dgc takes a "
                        f"density (got {comp}:{d})")
                fields["density"] = float(d)
            fields["compression"] = comp
        return cls(**fields)

    # ------------------------------------------------------------ backends
    def resolve_backend(self, devices: Optional[Sequence] = None) -> str:
        if self.is_hybrid:
            # tensor/stage axes and sharded state have no simulation —
            # the mesh IS the execution plan
            if self.backend == "sim":
                raise ValueError(
                    "hybrid cells (mesh/zero/adamw) are device-only; the "
                    "simulator has no tensor/stage axes")
            return "device"
        if self.backend == "sim":
            if self.wire == "measured":
                # the simulator has no payloads to count — measured wire
                # accounting only exists where planes are exchanged
                raise ValueError("wire='measured' is device-only; the "
                                 "simulator models bytes, it does not "
                                 "move them")
            return "sim"
        if self.backend == "device":
            if self.sync not in DEVICE_SYNCS:
                raise ValueError(
                    f"sync={self.sync!r} is simulated-only; use "
                    "backend='sim' (or 'auto')")
            return "device"
        # auto: device when the cell is device-executable and the process
        # actually has the workers
        if self.sync not in DEVICE_SYNCS:
            return "sim"
        n = len(devices) if devices is not None else len(jax.devices())
        kind = "device" if n >= self.workers else "sim"
        if kind == "sim" and self.wire == "measured":
            raise ValueError(
                f"wire='measured' needs the device backend but only "
                f"{n} device(s) are available for workers={self.workers}")
        return kind

    def build(self, grad_fn: Callable,
              devices: Optional[Sequence] = None) -> "Engine":
        """Construct the engine for this cell: the single entry point that
        replaces direct ``SyncEngine`` / ``DataParallelEngine`` /
        ``make_ps_step`` wiring."""
        kind = self.resolve_backend(devices)
        return BACKENDS[kind](self, grad_fn, devices)


# --------------------------------------------------------------- engines
class Engine:
    """Execution-backend protocol shared by every Strategy cell:

      init(params)              -> run-state
      step(state, batches, t)   -> (state, events)   # one global step
      finalize(state)           -> params
      metrics()                 -> {backend, spec, wire_bytes, ...}
      extra_metrics()           -> backend-specific metric additions
                                   (empty dict when there are none —
                                   every inner engine implements it, so
                                   ``metrics`` needs no duck-typing)

    ``run`` composes them through the shared fit loop and returns the
    legacy ``(params, history, wire_bytes)`` triple."""

    backend = "?"

    def __init__(self, strategy: Strategy, grad_fn: Callable,
                 devices: Optional[Sequence] = None):
        self.strategy = strategy
        self.inner = self._make_inner(strategy, grad_fn, devices)

    def _make_inner(self, strategy, grad_fn, devices):
        raise NotImplementedError

    def init(self, params):
        return self.inner.init(params)

    def step(self, state, batches: Callable[[int, int], Any], t: int):
        return self.inner.step(state, batches, t)

    def finalize(self, state):
        return self.inner.finalize(state)

    def metrics(self) -> Dict[str, Any]:
        m = dict(backend=self.backend, spec=self.strategy.spec(),
                 wire_bytes=self.inner.wire_bytes())
        if hasattr(self.inner, "dropped_updates"):
            m["dropped_updates"] = self.inner.dropped_updates()
        m.update(self.inner.extra_metrics())
        return m

    def extra_metrics(self) -> Dict[str, Any]:
        return self.inner.extra_metrics()

    # --------------------------------------------------- elastic interface
    # (repro.elastic.recovery drives these; every backend implements them)
    def reshard(self, state, new_workers: int, step: int = 0,
                lost: Tuple[int, ...] = ()):
        # self.strategy stays the *launched* configuration: metrics()
        # keeps reporting the reproducible spec, and the current size is
        # the engine's (fit_elastic reports it as final_workers)
        return self.inner.reshard(state, new_workers, step=step, lost=lost)

    def set_slowdown(self, worker: int, factor: float):
        self.inner.set_slowdown(worker, factor)

    def export_state(self, state):
        return self.inner.export_state(state)

    def import_state(self, arrays, meta):
        return self.inner.import_state(arrays, meta)

    def run(self, params, batches: Callable[[int, int], Any], steps: int):
        params, events, mets = fit(self, params, batches, steps)
        return params, events, mets["wire_bytes"]


def _as_grad_fn(model_or_grad_fn):
    """A StagedModel handed to a non-hybrid backend runs as its stacked
    (unpipelined, unsharded) reference — the same trajectory the hybrid
    engine is validated against."""
    from repro.parallel.staged import is_staged_model, stacked_grad_fn
    if is_staged_model(model_or_grad_fn):
        return stacked_grad_fn(model_or_grad_fn)
    return model_or_grad_fn


class SimBackend(Engine):
    """Wraps the deterministic event simulation (``SimSyncEngine``)."""

    backend = "sim"

    def _make_inner(self, s: Strategy, grad_fn, devices):
        grad_fn = _as_grad_fn(grad_fn)
        return SimSyncEngine(
            SyncConfig(mode=s.sync, num_workers=s.workers,
                       staleness=s.staleness, lr=s.lr, sma_mu=s.sma_mu,
                       periods=s.periods, compressor=s.compressor,
                       backup=s.backup, detect=s.detect, seed=s.seed),
            grad_fn)


class DeviceBackend(Engine):
    """Wraps the device-sharded engines: ``DeviceEngine`` for pure data
    parallelism, ``repro.parallel.HybridEngine`` for hybrid cells (a
    non-trivial mesh, ZeRO level, or stateful optimizer).  A trivial
    ``dK.t1.s1`` mesh is by construction the same ``DeviceEngine`` object
    the mesh-less spec builds — bitwise-identical trajectories."""

    backend = "device"

    def _make_inner(self, s: Strategy, grad_fn, devices):
        if s.is_hybrid:
            from repro.parallel.engine import HybridConfig, HybridEngine
            return HybridEngine(
                HybridConfig(
                    mesh=s.mesh_spec, lr=s.lr, compressor=s.compressor,
                    zero=s.zero, optimizer=s.optimizer,
                    topology=s.topology, bucket_mb=s.bucket_mb,
                    order=s.order, micro_batches=s.micro_batches,
                    sync=s.sync, staleness=s.staleness, periods=s.periods,
                    sma_mu=s.sma_mu, wire=s.wire, seed=s.seed,
                    schedule=s.schedule, interleave=s.interleave,
                    precision=s.precision, moments=s.moments),
                grad_fn, devices)
        grad_fn = _as_grad_fn(grad_fn)
        return DeviceEngine(
            DataParallelConfig(
                num_workers=s.workers, lr=s.lr, sync=s.sync, arch=s.arch,
                staleness=s.staleness, periods=s.periods,
                topology=s.topology, compressor=s.compressor,
                backup=s.backup, bucket_mb=s.bucket_mb, order=s.order,
                detect=s.detect, wire=s.wire, sma_mu=s.sma_mu,
                seed=s.seed),
            grad_fn, devices)


BACKENDS: Dict[str, type] = {"sim": SimBackend, "device": DeviceBackend}


# -------------------------------------------------------------- trainer
def fit(engine: Engine, params, batches: Callable[[int, int], Any],
        steps: int):
    """The single driver loop shared by every backend — the Engine protocol
    adapted onto the same ``train_loop`` that drives ``make_train_step``
    steps.  Returns (params, events, metrics); ``events`` is the full
    per-update history (no subsampling — async engines' staleness records
    are the point)."""
    all_events: List[dict] = []

    def step_fn(st, t, rng=None):
        st, events = engine.step(st, batches, t)
        all_events.extend(events)
        mets = dict(
            loss=events[-1]["loss"] if events else float("nan"),
            max_staleness=max((e["max_staleness"] for e in events),
                              default=0))
        return st, mets

    state, _ = train_loop(step_fn, engine.init(params), lambda t: t, steps,
                          log_every=steps, jit=False)
    return engine.finalize(state), all_events, engine.metrics()


class Trainer:
    """Declarative front-end: ``Trainer(strategy).fit(grad_fn, params,
    batches, steps)`` builds the strategy's engine and drives it through
    the shared loop.  Returns (params, history, metrics).

    Passing ``plan`` (an ``repro.elastic`` EventPlan, typed plan, or plan
    spec string like ``"crash:w1@5,resize:4@10"``) routes the run through
    the elastic trainer: the engine is periodically snapshotted through
    ``repro.checkpoint`` and survives crashes, resizes, restarts, and
    straggler events without restarting the process (docs/elasticity.md)."""

    def __init__(self, strategy: Strategy,
                 devices: Optional[Sequence] = None):
        self.strategy = strategy
        self.devices = devices

    def fit(self, grad_fn: Callable, params,
            batches: Callable[[int, int], Any], steps: int, *,
            plan=None, checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 5):
        if plan is not None:
            from repro.elastic.recovery import fit_elastic
            return fit_elastic(self.strategy, grad_fn, params, batches,
                               steps, plan, checkpoint_dir=checkpoint_dir,
                               checkpoint_every=checkpoint_every,
                               devices=self.devices)
        engine = self.strategy.build(grad_fn, self.devices)
        return fit(engine, params, batches, steps)
