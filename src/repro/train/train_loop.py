"""The trainer: composes model loss, optimizer, LR schedule, precision
policy, and (optionally) a gradient compressor — the full data-parallel
step the survey's Figure 4 describes, in one jitted function.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.compression import Compressor, EF_METHODS
from repro.core.precision import PrecisionPolicy, DEFAULT
from repro.obs.trace import get_recorder
from repro.optim.schedule import constant


class TrainState:
    """Factory for the train-state pytree (a plain dict with keys
    params / opt_state / step / ef)."""
    @staticmethod
    def create(params, opt, compressor: Optional[Compressor] = None):
        return dict(
            params=params,
            opt_state=opt.init(params),
            step=jnp.zeros((), jnp.int32),
            ef=(compressor.init_state(params)
                if compressor and compressor.method in EF_METHODS
                else None),
        )


def make_train_step(loss_fn: Callable, opt, lr_schedule=None,
                    precision: PrecisionPolicy = DEFAULT,
                    compressor: Optional[Compressor] = None,
                    remat: bool = False,
                    reduce_fn: Optional[Callable] = None):
    """loss_fn(params, batch, compute_dtype) -> (loss, metrics).

    ``reduce_fn(grads) -> grads`` runs after compression roundtrip — a
    data-parallel caller passes the bucketed topology allreduce here (the
    step is then used inside ``shard_map``; see train/data_parallel.py).

    Returns train_step(state, batch, rng) -> (state, metrics)."""
    lr_schedule = lr_schedule or constant(1e-3)

    def train_step(state: Dict, batch, rng=None):
        def lf(p):
            return loss_fn(p, batch, compute_dtype=precision.cdt)
        if remat:
            lf = jax.checkpoint(lf)
        (loss, mets), grads = jax.value_and_grad(lf, has_aux=True)(
            state["params"])
        grads = precision.cast_for_reduce(grads)
        wire = jnp.int32(0)
        ef = state["ef"]
        if compressor is not None and compressor.method != "none":
            grads, ef, wire_py = compressor.roundtrip(grads, ef, rng)
            wire = jnp.int32(wire_py % (2**31 - 1))
        if reduce_fn is not None:
            grads = reduce_fn(grads)
        lr = lr_schedule(state["step"])
        params, opt_state = opt.step(state["params"], grads,
                                     state["opt_state"], lr)
        new_state = dict(params=params, opt_state=opt_state,
                         step=state["step"] + 1, ef=ef)
        mets = dict(mets)
        mets.update(loss=loss, lr=lr, wire_bytes=wire)
        return new_state, mets

    return train_step


def train_loop(train_step, state, batch_fn: Callable[[int], Any],
               steps: int, log_every: int = 10, jit: bool = True,
               rng=None):
    """The single host driver loop: drives ``make_train_step`` steps in
    the examples AND every Strategy engine (``repro.train.strategy.fit``
    adapts the Engine protocol onto this same contract, with ``batch_fn``
    yielding the global-step index).  Returns (state, history)."""
    step_fn = jax.jit(train_step) if jit else train_step
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    hist = []
    t0 = time.time()
    tracer = get_recorder()     # no-op by default: tracing off is free
    for t in range(steps):
        rng, sub = jax.random.split(rng)
        if tracer.enabled:
            # one span per global step on the deterministic step clock;
            # engines emit their compute/exchange sub-spans on the same
            # track (docs/observability.md)
            with tracer.span("step", pid="train", tid="loop", cat="train",
                             clock=("train_step", t), step=t):
                state, mets = step_fn(state, batch_fn(t), sub)
        else:
            state, mets = step_fn(state, batch_fn(t), sub)
        if t % log_every == 0 or t == steps - 1:
            rec = {k: float(v) for k, v in mets.items()}
            rec["step"] = t
            rec["wall_s"] = time.time() - t0
            hist.append(rec)
    return state, hist
