"""Launchers: production meshes, the multi-pod dry-run, roofline analysis,
and host-scale train/serve entry points."""
