"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch x shape x mesh) record:
  compute term    = HLO_FLOPs / peak_FLOP/s            (per-chip module)
  memory term     = HLO_bytes / HBM_bw                 (unfused upper bound)
  collective term = ring-weighted collective bytes / ICI_bw

plus MODEL_FLOPS = 6*N*D (training; 2*N_active*D_dec for decode) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
Writes results/roofline.json and prints the table.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole step (all chips)."""
    cfg = ARCHS[arch]
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze_record(rec: Dict, chips: int) -> Dict:
    cost = rec.get("cost", {})
    coll = rec.get("collectives", {})
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes_accessed", 0.0)
    coll_dev = coll.get("traffic_weighted", 0.0)
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW_PER_LINK
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    ratio = mf / max(flops_dev * chips, 1.0)
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": round(ratio, 4),
        "bound_step_s": round(max(terms.values()), 6),
    }


def _rank(rec: Dict, path: str) -> int:
    """Cost-source quality: probe (per-layer exact, extrapolated) >
    unrolled > scanned (XLA counts scan bodies once)."""
    if "__tp_only" in path or "__moehints" in path:
        return -1      # hillclimb variants never replace the baseline
    if rec.get("probe"):
        return 3
    if rec.get("unrolled") or path.endswith("__unrolled.json"):
        return 2
    return 1


def load_all(dir_: str) -> List[Dict]:
    """One record per (arch, shape, mesh): the scanned compile is the
    fits/compiles evidence; cost/collectives come from the best available
    measurement (probe > unrolled > scanned)."""
    base: Dict = {}       # scanned records (memory evidence)
    best: Dict = {}       # best cost source
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"))
        r = _rank(rec, p)
        if r == 1:
            base[key] = rec
        if r > 0 and rec.get("status") in ("ok", "skipped"):
            if key not in best or r > best[key][0]:
                best[key] = (r, rec)
    out = []
    for key in sorted(set(base) | set(best),
                      key=lambda t: (str(t[0]), str(t[1]), str(t[2]))):
        rec = dict(base.get(key) or best[key][1])
        if key in best and best[key][0] > 1 and rec.get("status") == "ok":
            src = best[key][1]
            rec["cost"] = src.get("cost", rec.get("cost"))
            rec["collectives"] = src.get("collectives",
                                         rec.get("collectives"))
            rec["cost_source"] = "probe" if src.get("probe") else "unrolled"
        out.append(rec)
    return out


def main():
    ap = argparse.ArgumentParser()
    default_dir = os.path.abspath(os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    ap.add_argument("--dir", default=default_dir)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_path = args.out or os.path.join(os.path.dirname(args.dir),
                                        "roofline.json")

    rows = []
    for rec in load_all(args.dir):
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh"),
                         "status": rec.get("status"),
                         "reason": rec.get("reason", rec.get("error", ""))})
            continue
        chips = 512 if rec["mesh"] == "2x16x16" else 256
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "mesh": rec["mesh"], "status": "ok", "chips": chips}
        row.update(analyze_record(rec, chips))
        rows.append(row)

    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)

    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute':>10s} "
           f"{'memory':>10s} {'collect':>10s} {'dominant':>10s} "
           f"{'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} {str(r.get('mesh')):8s} "
                  f"{r.get('status'):>10s}  {r.get('reason','')[:40]}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f}")
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()
