"""Serving launcher: batched greedy decoding on this host (reduced config).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
      --prompt-len 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window override (sub-quadratic decode)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("use examples/whisper_decode.py for enc-dec serving")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = generate(model, params, prompt, args.max_new,
                   window_override=args.window)
    dt = time.time() - t0
    print("prompt :", prompt.tolist())
    print("output :", out[:, args.prompt_len:].tolist())
    n_tok = args.batch * (args.prompt_len + args.max_new)
    print(f"{n_tok} decode steps in {dt:.2f}s "
          f"({1e3 * dt / n_tok:.1f} ms/token incl. compile)")


if __name__ == "__main__":
    main()
