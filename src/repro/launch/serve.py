"""Serving launcher: the continuous-batching engine on this host
(reduced config), driven by an open-loop arrival trace.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --smoke --requests 8 --rate 0.5 --policy continuous --pages 4

  # static-batching baseline on the same trace
  PYTHONPATH=src python -m repro.launch.serve --smoke --policy oneshot

  # tensor-parallel decode (needs >= tp devices)
  PYTHONPATH=src python -m repro.launch.serve --smoke --tp 2
"""
from __future__ import annotations

import argparse
import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.autoscale import poisson_trace
from repro.serve.batcher import POLICIES
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="max concurrent batch slots")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate (req per engine "
                         "iteration); 0 = all requests arrive at t=0")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache capacity (0 = prompt+max_new)")
    ap.add_argument("--policy", choices=POLICIES, default="continuous")
    ap.add_argument("--pages", type=int, default=0,
                    help="KV page size (0 = contiguous per-slot cache)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool cap (0 = size for all slots full)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel decode degree")
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window override (sub-quadratic decode)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="<= 0 is greedy argmax")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome trace (request lifecycles + KV "
                         "occupancy); see docs/observability.md")
    ap.add_argument("--report", action="store_true",
                    help="print the trace analysis (latency summary, SLO "
                         "burn) after the run; implies tracing even "
                         "without --trace")
    ap.add_argument("--slo", action="append", default=[], metavar="SPEC",
                    help="attach an SLO objective, e.g. ttft_p99<8 "
                         "(repeatable); burning SLOs emit slo_burn "
                         "instants (docs/serving.md)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("use examples/whisper_decode.py for enc-dec serving")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    max_len = args.max_len or (args.prompt_len + args.max_new)
    horizon = max(1.0, args.requests / args.rate) if args.rate > 0 else 1.0
    arrivals = ([0.0] + poisson_trace(args.rate, horizon, seed=args.seed,
                                      max_requests=args.requests - 1)
                if args.rate > 0 else [0.0] * args.requests)
    rng = np.random.RandomState(args.seed + 1)
    prompts = rng.randint(1, cfg.vocab_size,
                          size=(len(arrivals), args.prompt_len))
    reqs = [Request(rid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=args.max_new, arrival=arrivals[i],
                    sampling=SamplingParams(temperature=args.temperature,
                                            top_k=args.top_k,
                                            seed=args.seed + i))
            for i in range(len(arrivals))]

    slo = None
    if args.slo:
        from repro.obs.slo import SLOMonitor
        slo = SLOMonitor(args.slo)
    eng = ServeEngine(model, params, ServeConfig(
        slots=args.slots, max_len=max_len, page_size=args.pages,
        num_pages=args.num_pages or None, policy=args.policy, tp=args.tp,
        window_override=args.window,
        cache_dtype=jnp.float32, compute_dtype=jnp.float32), slo=slo)
    rec = None
    with contextlib.ExitStack() as stack:
        if args.trace or args.report:
            from repro.obs.trace import tracing
            rec = stack.enter_context(tracing(args.trace))
        metrics = eng.run(reqs)
    if args.trace:
        print(f"trace written to {args.trace}")
    if args.report and rec is not None:
        from repro.obs.report import render
        print(render(rec.to_chrome(), slos=args.slo))
    if slo is not None:
        print(f"slo alerts: {len(eng.slo_alerts)}"
              + (f" (first at t={eng.slo_alerts[0]['t']})"
                 if eng.slo_alerts else ""))

    for r in reqs[:4]:
        print(f"req {r.rid}: arrival={r.arrival:5.1f} "
              f"ttft={r.first_token_latency():5.1f} "
              f"output={r.output[:8]}{'...' if len(r.output) > 8 else ''}")
    if len(reqs) > 4:
        print(f"... {len(reqs) - 4} more")
    print(f"policy={metrics['policy']} paged={metrics['paged']} "
          f"tp={metrics['tp']}")
    print(f"{metrics['completed']} requests, "
          f"{metrics['generated_tokens']} tokens in "
          f"{metrics['clock']:.0f} iterations "
          f"({metrics['tokens_per_s']:.2f} tok/iter, "
          f"{metrics['wall_s']:.2f}s wall)")
    print(f"first-token p50/p99: {metrics['p50_first_token']:.1f}/"
          f"{metrics['p99_first_token']:.1f} iters   per-token p50/p99: "
          f"{metrics['p50_per_token']:.2f}/{metrics['p99_per_token']:.2f}"
          f"   stalls: {metrics['admission_stalls']}")


if __name__ == "__main__":
    main()
