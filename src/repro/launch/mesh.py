"""Production meshes.  Functions (never module-level constants) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hybrid_mesh(devices, data: int, tensor: int, stage: int,
                     axes=("data", "tensor", "stage")):
    """The hybrid-parallel training mesh (repro.parallel): ``data`` x
    ``tensor`` x ``stage`` over an explicit device list — virtual host
    devices in tests, real chips in production.  Device order is
    data-major so a data-axis resize keeps (tensor, stage) blocks
    contiguous."""
    import numpy as np
    n = data * tensor * stage
    if len(devices) < n:
        raise ValueError(f"mesh {data}x{tensor}x{stage} needs {n} devices, "
                         f"have {len(devices)}")
    from jax.sharding import Mesh
    return Mesh(np.array(devices[:n]).reshape(data, tensor, stage), axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has (smoke tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s per link
