"""Sharded train / prefill / serve step builders used by the dry-run and
the launchers."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import Model, build_model
from repro.optim import Adafactor, Adam

ADAFACTOR_THRESHOLD = 20e9     # params above this use factored moments


def choose_optimizer(cfg: ModelConfig):
    if cfg.param_count() > ADAFACTOR_THRESHOLD:
        return Adafactor()
    return Adam()


def make_train_step(model: Model, opt, lr: float = 1e-3,
                    remat: bool = True, unroll: bool = False):
    def train_step(params, opt_state, batch):
        def lf(p):
            return model.loss_fn(p, batch, compute_dtype=jnp.bfloat16,
                                 remat=remat, unroll=unroll)
        (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_p, new_o = opt.step(params, grads, opt_state, lr)
        return new_p, new_o, loss
    return train_step


def make_prefill_step(model: Model, unroll: bool = False):
    cfg = model.cfg

    def prefill_step(params, batch):
        if cfg.is_encoder_decoder:
            from repro.models import whisper as W
            enc = W.encode(params, cfg, batch["frames"], unroll=unroll)
            logits = W.decode_train(params, cfg, batch["tokens"], enc,
                                    unroll=unroll)
            cross = W.build_cross_cache(params, cfg, enc)
            return logits[:, -1:], cross
        return model.prefill(params, batch["tokens"],
                             positions=batch.get("positions"),
                             vision_embeds=batch.get("vision_embeds"),
                             unroll=unroll)
    return prefill_step


def make_serve_step(model: Model, window_override: int = 0,
                    unroll: bool = False):
    cfg = model.cfg

    def serve_step(params, caches, token, pos):
        if cfg.is_encoder_decoder:
            return model.decode_step(params, caches, token, pos,
                                     unroll=unroll)
        return model.decode_step(params, caches, token, pos,
                                 window_override=window_override,
                                 unroll=unroll)
    return serve_step
