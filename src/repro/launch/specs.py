"""ShapeDtypeStruct stand-ins + sharding specs for every (arch x shape).

`input_specs(cfg, shape)` produces the exact abstract inputs the dry-run
lowers against (no allocation); `cache_specs` / `batch_sharding` assign
PartitionSpecs with divisibility-aware fallbacks (e.g. long_500k batch=1:
the batch axis cannot shard, so the sequence axis of attention caches
shards over `data` instead, and SSM states shard heads over `model`).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import InputShape, ModelConfig
from repro.core.parallelism import data_axes

VOCAB_PAD = 16       # model-axis shard count
VISION_PATCHES = 256
SWA_WINDOW = 4096    # sliding-window override for dense archs at long_500k


def _div(n: int, k: int) -> bool:
    return n % k == 0


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ------------------------------------------------------------------ batches
def batch_shardable(shape: InputShape, mesh: Mesh) -> bool:
    sizes = mesh_axis_sizes(mesh)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    return _div(shape.global_batch, dp)


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.is_encoder_decoder:
        # conv/mel frontend stub: precomputed frame embeddings
        return {"frames": jax.ShapeDtypeStruct(
                    (B, cfg.max_source_positions, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
             "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.family == "vlm":
        # ViT stub: precomputed patch embeddings + M-RoPE position ids
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, VISION_PATCHES, cfg.d_model), jnp.bfloat16)
        specs["positions"] = jax.ShapeDtypeStruct((B, 3, S), i32)
    return specs


def batch_specs_tree(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                     multi_pod: bool) -> Dict[str, P]:
    shard_b = batch_shardable(shape, mesh)
    b = data_axes(multi_pod) if shard_b else None
    out: Dict[str, P] = {}
    for name, sds in train_input_specs(cfg, shape).items():
        out[name] = P(b, *([None] * (len(sds.shape) - 1)))
    return out


# ------------------------------------------------------------------- caches
def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    """window_override for the serve step (0 = full cache)."""
    if shape.name != "long_500k":
        return 0
    if cfg.attn_type == "mla":
        return 0          # MLA latent cache makes full 500k memory-feasible
    if cfg.family in ("dense", "vlm", "moe"):
        return SWA_WINDOW  # sub-quadratic requirement: sliding window
    return 0              # hybrid/ssm already have bounded state


def cache_leaf_spec(names, shape_t: Tuple[int, ...], *, multi_pod: bool,
                    shard_batch: bool, model_n: int, data_n: int,
                    policy: str = "auto") -> P:
    name = names[-1] if names else ""
    dp = data_axes(multi_pod)
    b = dp if shard_batch else None

    def model_split(*dims):
        """pick the first trailing dim divisible by the model axis."""
        for di in dims:
            if _div(shape_t[di], model_n):
                return di
        return None

    if name in ("k", "v"):           # [..., B, L, KV, hd]
        nd = len(shape_t)
        lead = (None,) * (nd - 4)
        if policy == "attn_hints_seq":
            # flash-decoding storage: sequence over model, batch over data
            l_spec = "model" if _div(shape_t[nd - 3], model_n) else None
            return P(*(lead + (b, l_spec, None, None)))
        if policy == "seq_data":
            # flash-decoding layout: batch over model, sequence over data —
            # the cache is fully partitioned without touching the (too few)
            # KV heads, and only tiny per-token activations reshard.
            b_spec = "model" if _div(shape_t[nd - 4], model_n) else None
            l_spec = dp if _div(shape_t[nd - 3], data_n) else None
            return P(*(lead + (b_spec, l_spec, None, None)))
        l_spec = None if shard_batch else (dp if _div(shape_t[nd - 3],
                                                      data_n) else None)
        mi = model_split(nd - 2, nd - 1)
        tail = [b, l_spec, None, None]
        if mi is not None:
            tail[mi - (nd - 4)] = "model"
        return P(*(lead + tuple(tail)))
    if name in ("c_kv", "k_rope"):   # [..., B, L, r]
        nd = len(shape_t)
        lead = (None,) * (nd - 3)
        l_spec = None if shard_batch else (dp if _div(shape_t[nd - 2],
                                                      data_n) else None)
        r_spec = "model" if _div(shape_t[nd - 1], model_n) else None
        return P(*(lead + (b, l_spec, r_spec)))
    if name == "S":                  # [..., B, H, hs, hs]
        nd = len(shape_t)
        lead = (None,) * (nd - 4)
        h_spec = "model" if _div(shape_t[nd - 3], model_n) else None
        return P(*(lead + (b, h_spec, None, None)))
    if name in ("h", "shift", "shift_tm", "shift_cm"):   # [..., B, w]
        nd = len(shape_t)
        lead = (None,) * (nd - 2)
        w_spec = "model" if _div(shape_t[nd - 1], model_n) else None
        return P(*(lead + (b, w_spec)))
    if name == "conv":               # [..., B, cw-1, w]
        nd = len(shape_t)
        lead = (None,) * (nd - 3)
        w_spec = "model" if _div(shape_t[nd - 1], model_n) else None
        return P(*(lead + (b, None, w_spec)))
    return P(*([None] * len(shape_t)))


def cache_specs(cache_shapes, mesh: Mesh, multi_pod: bool,
                shard_batch: bool, policy: str = "auto"):
    sizes = mesh_axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    data_n = sizes.get("data", 1) * sizes.get("pod", 1)

    def one(path, leaf):
        names = []
        for k in path:
            if isinstance(k, DictKey):
                names.append(str(k.key))
            elif isinstance(k, SequenceKey):
                names.append(f"[{k.idx}]")
        return cache_leaf_spec(names, leaf.shape, multi_pod=multi_pod,
                               shard_batch=shard_batch, model_n=model_n,
                               data_n=data_n, policy=policy)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# --------------------------------------------------------------- optimizers
def opt_state_specs(opt_state_shapes, pspecs):
    """Optimizer-state specs derived from the param specs (PS-style: the
    optimizer shard lives with the parameter shard).  Handles same-shape
    moments (sgd/adam m, v) and adafactor's factored vr/vc."""
    import jax.tree_util as jtu

    def match(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        ndim = len(leaf.shape)
        spec = _lookup_param_spec(pspecs, names)
        if spec is None:
            return P(*([None] * ndim))
        st = tuple(spec)
        if "vr" in names and len(st) >= 2:        # param shape minus last dim
            return P(*st[:-1])
        if "vc" in names and len(st) >= 2:        # minus second-to-last dim
            return P(*(st[:-2] + st[-1:]))
        if len(st) == ndim:
            return P(*st)
        return P(*([None] * ndim))

    return jtu.tree_map_with_path(match, opt_state_shapes)


def _lookup_param_spec(pspecs, names):
    """Walk pspecs following the param-path segment of an optimizer path
    (skipping the optimizer's own wrapper keys like m/v/f/vr/vc)."""
    skip = {"m", "v", "f", "vr", "vc", "t"}
    node = pspecs
    for n in names:
        if n in skip:
            continue
        if isinstance(node, dict) and n in node:
            node = node[n]
        elif isinstance(node, (list, tuple)) and n.startswith("["):
            node = node[int(n[1:-1])]
        elif isinstance(node, P):
            break
        else:
            return None
    return node if isinstance(node, P) else None
