"""Extract roofline terms from a lowered/compiled dry-run artifact.

``cost_analysis()`` provides HLO FLOPs and bytes; collective bytes are NOT
in cost_analysis, so we parse the optimized HLO text and convert each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
into estimated per-device ring traffic:

  op               result bytes R, group size S   traffic per device
  all-reduce       R                               2 (S-1)/S * R
  all-gather       R (the gathered tensor)         (S-1)/S * R
  reduce-scatter   R (the shard)                   (S-1) * R   (input = S*R)
  all-to-all       R                               (S-1)/S * R
  collective-permute R                             R

Group size S is parsed from replica_groups=[G,S]<=[N] (iota form) or the
explicit {{...}} list; missing/odd formats fall back to S=2 semantics
(factor 1) so traffic is never silently inflated.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
# iota form: replica_groups=[G,S]<=[N...]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# explicit form: replica_groups={{0,1,2,...},{...}}
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2    # unknown: conservative (factor (S-1)/S ~ 1/2 .. 1)


def _result_bytes(line: str) -> int:
    lhs = line.split(" = ", 1)
    region = lhs[1] if len(lhs) == 2 else line
    m = re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                  r"collective-permute)", region)
    region = region[:m.start()] if m else region
    total = 0
    for dtype, dims in _SHAPE_RE.findall(region):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _traffic(op: str, result_bytes: int, s: int) -> float:
    if s <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (s - 1) / s * result_bytes
    if op == "all-gather":
        return (s - 1) / s * result_bytes
    if op == "reduce-scatter":
        return float(s - 1) * result_bytes
    if op == "all-to-all":
        return (s - 1) / s * result_bytes
    return float(result_bytes)      # collective-permute


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-type result bytes + per-device ring-traffic estimate."""
    out = {k: 0.0 for k in _COLLECTIVES}
    traffic = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue       # async pair: count the -start only
        op = m.group(1)
        b = _result_bytes(line)
        out[op] += b
        traffic += _traffic(op, b, _group_size(line))
    out["traffic_weighted"] = traffic
    return out


def summarize_cost(cost) -> Dict[str, float]:
    """Normalize compiled.cost_analysis() output across jax versions."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals",
              "optimal_seconds"):
        if k in cost:
            out[k.replace(" ", "_")] = float(cost[k])
    out.setdefault("flops", 0.0)
    out.setdefault("bytes_accessed", 0.0)
    return out


def summarize_memory(mem) -> Dict[str, float]:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = float(v)
    return out
