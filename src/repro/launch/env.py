"""Host process environment setup for virtual-device runs.

Every tool, test, and benchmark in this repo that wants N devices on a
CPU host has to set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
*before the first jax import* — after jax initializes its backend the
flag is silently ignored and the run proceeds on 1 device until a mesh
constructor fails with a confusing "needs N devices" error.  This module
is the one implementation of that dance:

    from repro.launch.env import ensure_host_devices
    ensure_host_devices(8)       # before any jax import
    import jax

and, for the subprocess pattern (benchmarks / multi-device tests):

    subprocess.run([...], env=subprocess_env(8))

Allocator note (docs/hybrid.md): on hosts where glibc malloc fragments
under the engine's per-bucket arrays, preload tcmalloc *outside* the
process — an env var cannot retroactively swap the allocator of a
running interpreter::

    LD_PRELOAD=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
        PYTHONPATH=src python tools/hybrid_smoke.py

``subprocess_env`` forwards an LD_PRELOAD already present in the parent
environment, so one export covers a whole bench tree.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Optional

_FLAG = "--xla_force_host_platform_device_count"


def _with_flag(flags: str, n: int) -> str:
    """XLA_FLAGS value with the host-device-count flag ensured.  An
    explicit count already present (env override) wins."""
    if _FLAG in flags:
        return flags
    return f"{flags} {_FLAG}={n}".strip()


def ensure_host_devices(n: int) -> None:
    """Idempotently request ``n`` virtual host devices for this process.

    Must run before the first jax import; raises if jax's backend is
    already initialized (the flag would be silently ignored).  A count
    already present in ``XLA_FLAGS`` — e.g. set by an outer launcher or
    ``subprocess_env`` — is respected, not overwritten.
    """
    if n < 1:
        raise ValueError("device count must be >= 1")
    jax_mod = sys.modules.get("jax")
    if jax_mod is not None and _FLAG not in os.environ.get("XLA_FLAGS", ""):
        backends = sys.modules.get("jax._src.xla_bridge")
        if backends is not None and getattr(backends, "_backends", None):
            raise RuntimeError(
                "ensure_host_devices() called after jax initialized its "
                "backend; XLA_FLAGS would be ignored.  Call it before the "
                "first jax import (see repro.launch.env docstring)")
    os.environ["XLA_FLAGS"] = _with_flag(os.environ.get("XLA_FLAGS", ""), n)


def subprocess_env(n: int,
                   base: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """A copy of ``base`` (default ``os.environ``) with ``XLA_FLAGS``
    requesting ``n`` virtual host devices — the env to hand
    ``subprocess.run`` for a fresh multi-device child process.  Unlike
    ``ensure_host_devices`` this *overrides* any existing count: a child
    launched for n devices must get n devices regardless of the parent's
    own flag."""
    if n < 1:
        raise ValueError("device count must be >= 1")
    env = dict(os.environ if base is None else base)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(f"{_FLAG}=")]
    env["XLA_FLAGS"] = " ".join(flags + [f"{_FLAG}={n}"])
    return env
