"""Training launcher.

Small-scale real run (this host):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 20
lowers + executes a reduced config on the host devices; the production
mesh path is exercised via `repro.launch.dryrun` (no TPU in this
container).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.compression import Compressor
from repro.core.precision import PrecisionPolicy
from repro.data import LMDataConfig, make_lm_batches
from repro.models import build_model
from repro.optim import OPTIMIZERS
from repro.optim.schedule import cosine_warmup
from repro.train import TrainState, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host devices")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adam", choices=list(OPTIMIZERS))
    ap.add_argument("--compress", default="none",
                    choices=["none", "onebit", "terngrad", "qsgd", "dgc"])
    ap.add_argument("--compute-dtype", default="float32")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome trace (Perfetto-loadable) of the "
                         "run; see docs/observability.md")
    ap.add_argument("--report", action="store_true",
                    help="print the trace analysis (step-time "
                         "attribution etc.) after the run; implies "
                         "tracing even without --trace")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    data_cfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                            batch_size=args.batch_size)
    batches = make_lm_batches(data_cfg)

    if cfg.is_encoder_decoder:
        F = cfg.max_source_positions
        fkey = jax.random.PRNGKey(7)

        def batch_fn(t):
            b = batches(t)
            return {"frames": jax.random.normal(
                        jax.random.fold_in(fkey, t),
                        (args.batch_size, F, cfg.d_model)),
                    "tokens": b["tokens"], "labels": b["labels"]}
    else:
        def batch_fn(t):
            return batches(t)

    opt = OPTIMIZERS[args.optimizer]()
    comp = Compressor(args.compress)
    precision = PrecisionPolicy(compute_dtype=args.compute_dtype)
    step = make_train_step(model.loss_fn, opt,
                           cosine_warmup(args.lr, 5, args.steps),
                           precision=precision, compressor=comp)
    state = TrainState.create(params, opt, comp)
    t0 = time.time()
    rec = None
    with contextlib.ExitStack() as stack:
        if args.trace or args.report:
            from repro.obs.trace import tracing
            rec = stack.enter_context(tracing(args.trace))
        state, hist = train_loop(step, state, batch_fn, args.steps,
                                 log_every=max(1, args.steps // 10))
    if args.trace:
        print(f"trace written to {args.trace}")
    if args.report and rec is not None:
        from repro.obs.report import render
        print(render(rec.to_chrome()))
    for rec in hist:
        print(json.dumps({k: round(v, 5) for k, v in rec.items()}))
    print(f"done in {time.time() - t0:.1f}s; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
