import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers AND compiles on the production meshes, and extract the
roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Writes JSON records to results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SKIPS, get_config, get_shape
from repro.core.parallelism import param_specs, data_axes
from repro.launch.hlo_analysis import (collective_bytes, summarize_cost,
                                       summarize_memory)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (batch_shardable, batch_specs_tree,
                                cache_specs, decode_window, mesh_axis_sizes,
                                train_input_specs, VOCAB_PAD)
from repro.launch.steps import (choose_optimizer, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import build_model

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _sharded(mesh, shapes, specs):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs)


def _out_shardings(mesh, specs):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _sharded_param_bytes(shapes, specs, mesh) -> float:
    sizes = mesh_axis_sizes(mesh)

    def one(s, sp):
        denom = 1
        for ax in sp:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                denom *= sizes.get(a, 1)
        return s.size * s.dtype.itemsize / denom

    return sum(jax.tree.leaves(jax.tree.map(one, shapes, specs)))


def build_dryrun(arch: str, shape_name: str, multi_pod: bool,
                 unroll: bool = False, policy: str = "fsdp",
                 moe_hints: bool = False, cfg=None,
                 cache_policy: str = "auto"):
    """Returns (jitted_fn, example_args) ready to lower."""
    from repro.core.parallelism import (set_attn_decode_hints,
                                        set_moe_sharding_hints)
    set_moe_sharding_hints(bool(moe_hints), multi_pod=multi_pod,
                           mode=moe_hints if isinstance(moe_hints, str)
                           and moe_hints != "full" else "full")
    set_attn_decode_hints(cache_policy in ("attn_hints", "attn_hints_seq"),
                          multi_pod=multi_pod,
                          mode="seq" if cache_policy == "attn_hints_seq"
                          else "hd")
    cfg = cfg if cfg is not None else get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)

    p_shapes = jax.eval_shape(
        lambda k: model.init(k, dtype=jnp.bfloat16,
                             vocab_pad_multiple=VOCAB_PAD), key)
    pspecs = param_specs(p_shapes, multi_pod=multi_pod, policy=policy)
    p_in = _sharded(mesh, p_shapes, pspecs)
    shard_b = batch_shardable(shape, mesh)
    info: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params_analytic": cfg.param_count(),
        "active_params_analytic": cfg.active_param_count(),
        "param_bytes_per_device": _sharded_param_bytes(p_shapes, pspecs, mesh),
        "batch_sharded": shard_b,
        "policy": policy,
        "moe_hints": moe_hints,
    }

    if shape.kind == "train":
        opt = choose_optimizer(cfg)
        info["optimizer"] = type(opt).__name__
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        from repro.launch.specs import opt_state_specs
        ospecs = opt_state_specs(o_shapes, pspecs)
        o_in = _sharded(mesh, o_shapes, ospecs)
        b_shapes = train_input_specs(cfg, shape)
        bspecs = batch_specs_tree(cfg, shape, mesh, multi_pod)
        b_in = _sharded(mesh, b_shapes, bspecs)
        step = make_train_step(model, opt, remat=True, unroll=unroll)
        out_sh = (_out_shardings(mesh, pspecs), _out_shardings(mesh, ospecs),
                  NamedSharding(mesh, P()))
        fn = jax.jit(step, out_shardings=out_sh)
        args = (p_in, o_in, b_in)
        return mesh, fn, args, info

    dp = data_axes(multi_pod)
    logits_spec = P(dp if shard_b else None, None, "model")

    if shape.kind == "prefill":
        b_shapes = train_input_specs(cfg, shape)
        b_shapes.pop("labels", None)
        bspecs = batch_specs_tree(cfg, shape, mesh, multi_pod)
        bspecs.pop("labels", None)
        b_in = _sharded(mesh, b_shapes, bspecs)
        step = make_prefill_step(model, unroll=unroll)
        out_shapes = jax.eval_shape(step, p_shapes, b_shapes)
        cspecs = cache_specs(out_shapes[1], mesh, multi_pod, shard_b)
        out_sh = (NamedSharding(mesh, logits_spec),
                  _out_shardings(mesh, cspecs))
        fn = jax.jit(step, out_shardings=out_sh)
        return mesh, fn, (p_in, b_in), info

    # ---- decode
    window = decode_window(cfg, shape)
    info["window_override"] = window
    B = shape.global_batch
    if cfg.is_encoder_decoder:
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(B, shape.seq_len, dtype=jnp.bfloat16))
    else:
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(B, shape.seq_len, dtype=jnp.bfloat16,
                                     window_override=window))
    cspecs = cache_specs(c_shapes, mesh, multi_pod, shard_b,
                         policy=cache_policy)
    c_in = _sharded(mesh, c_shapes, cspecs)
    tok = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=NamedSharding(mesh, P(dp if shard_b else None, None)))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    step = make_serve_step(model, window_override=window, unroll=unroll)
    out_sh = (NamedSharding(mesh, logits_spec), _out_shardings(mesh, cspecs))
    fn = jax.jit(step, out_shardings=out_sh)
    info["cache_bytes_per_device"] = _sharded_param_bytes(
        c_shapes, cspecs, mesh)
    return mesh, fn, (p_in, c_in, tok, pos), info


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, force: bool = False,
             unroll: bool = False, policy: str = "fsdp",
             moe_hints: bool = False,
             cache_policy: str = "attn_hints_seq") -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    suffix = "__unrolled" if unroll else ""
    if policy != "fsdp":
        suffix += f"__{policy}"
    if moe_hints:
        suffix += f"__moehints_{moe_hints}" if isinstance(moe_hints, str) \
            else "__moehints"
    if cache_policy == "auto":
        suffix += "__legacycache"
    elif cache_policy != "attn_hints_seq":
        suffix += f"__{cache_policy}"
    out_path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    if (arch, shape_name) in SKIPS:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
        os.makedirs(out_dir, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    try:
        mesh, fn, args, info = build_dryrun(arch, shape_name, multi_pod,
                                            unroll=unroll, policy=policy,
                                            moe_hints=moe_hints,
                                            cache_policy=cache_policy)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            cost = summarize_cost(compiled.cost_analysis())
            mem = summarize_memory(compiled.memory_analysis())
            coll = collective_bytes(compiled.as_text())
        rec = dict(info)
        rec.update(status="ok", unrolled=unroll, lower_s=round(t_lower, 2),
                   compile_s=round(t_compile, 2), cost=cost, memory=mem,
                   collectives=coll)
    except Exception as e:  # noqa: BLE001 — record failures, they are bugs
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _compile_stats(arch, shape_name, multi_pod, policy, moe_hints, cfg,
                   cache_policy="auto"):
    mesh, fn, args, _ = build_dryrun(arch, shape_name, multi_pod,
                                     unroll=True, policy=policy,
                                     moe_hints=moe_hints, cfg=cfg,
                                     cache_policy=cache_policy)
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        cost = summarize_cost(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())
    return cost, coll


def _depth_variant(cfg, n_groups: int):
    """Full-width config with first_k_dense + n_groups*pattern layers."""
    import dataclasses
    pat = len(cfg.block_pattern)
    layers = (cfg.first_k_dense if cfg.moe else 0) + n_groups * pat
    kw = dict(num_layers=layers)
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = n_groups
    return dataclasses.replace(cfg, **kw)


def probe_pair(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
               force: bool = False, policy: str = "fsdp",
               moe_hints: bool = False,
               cache_policy: str = "auto") -> Dict[str, Any]:
    """Layer-probe roofline measurement: XLA cost analysis counts scanned
    layer stacks once, so we compile FULL-WIDTH unrolled variants with 1
    and 2 layer-groups; the difference is the exact per-group cost, which
    extrapolates to the full depth:  total = base + n_groups * body.
    Validated against true fully-unrolled compiles (see EXPERIMENTS.md)."""
    mesh_name = "2x16x16" if multi_pod else "16x16"
    suffix = "__probe"
    if policy != "fsdp":
        suffix += f"__{policy}"
    if moe_hints:
        suffix += f"__moehints_{moe_hints}" if isinstance(moe_hints, str) \
            else "__moehints"
    if cache_policy not in ("auto", "attn_hints_seq"):
        suffix += f"__{cache_policy}"
    elif cache_policy == "auto":
        suffix += "__legacycache"
    out_path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    if (arch, shape_name) in SKIPS:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
    else:
        t0 = time.time()
        try:
            cfg = get_config(arch)
            pat = len(cfg.block_pattern)
            prefix = cfg.first_k_dense if cfg.moe else 0
            full_groups = (cfg.num_layers - prefix) // pat
            tail = (cfg.num_layers - prefix) - full_groups * pat
            c1, l1 = _compile_stats(arch, shape_name, multi_pod, policy,
                                    moe_hints, _depth_variant(cfg, 1),
                                    cache_policy)
            c2, l2 = _compile_stats(arch, shape_name, multi_pod, policy,
                                    moe_hints, _depth_variant(cfg, 2),
                                    cache_policy)
            mult = full_groups + tail / pat

            def extrap(d1, d2):
                out = {}
                for k in d2:
                    body = d2[k] - d1.get(k, 0.0)
                    base = d1.get(k, 0.0) - body
                    out[k] = max(base + mult * body, 0.0)
                return out

            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "status": "ok", "probe": True, "policy": policy,
                   "moe_hints": moe_hints,
                   "params_analytic": cfg.param_count(),
                   "active_params_analytic": cfg.active_param_count(),
                   "probe_groups": [1, 2], "extrap_mult": mult,
                   "cost": extrap(c1, c2), "collectives": extrap(l1, l2),
                   "cost_n1": c1, "cost_n2": c2,
                   "collectives_n1": l1, "collectives_n2": l2,
                   "wall_s": round(time.time() - t0, 1)}
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--unrolled", action="store_true",
                    help="analysis pass: unroll layer stacks so HLO cost "
                         "analysis counts every layer (scan bodies are "
                         "counted once by XLA)")
    ap.add_argument("--policy", default="fsdp", choices=["fsdp", "tp_only"],
                    help="parameter sharding policy (hillclimb lever)")
    ap.add_argument("--moe-hints", default="", 
                    choices=["", "full", "expert_only"],
                    help="explicit MoE dispatch sharding constraints")
    ap.add_argument("--cache-policy", default="attn_hints_seq",
                    choices=["auto", "seq_data", "attn_hints",
                             "attn_hints_seq"],
                    help="decode cache sharding layout (hillclimb lever)")
    ap.add_argument("--probe", action="store_true",
                    help="layer-probe roofline measurement (1- and 2-group "
                         "full-width unrolled compiles, extrapolated)")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ARCHS:
            for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs.append((args.arch, args.shape))

    for a, s in pairs:
        if args.probe:
            rec = probe_pair(a, s, args.multi_pod, args.out,
                             force=args.force, policy=args.policy,
                             moe_hints=args.moe_hints,
                             cache_policy=args.cache_policy)
        else:
            rec = run_pair(a, s, args.multi_pod, args.out, force=args.force,
                           unroll=args.unrolled, policy=args.policy,
                           moe_hints=args.moe_hints,
                           cache_policy=args.cache_policy)
        status = rec.get("status")
        extra = ""
        if status == "ok":
            if rec.get("probe"):
                extra = (f"wall={rec['wall_s']}s "
                         f"flops~={rec['cost'].get('flops', 0):.3g}")
            else:
                extra = (f"lower={rec['lower_s']}s "
                         f"compile={rec['compile_s']}s "
                         f"flops={rec['cost'].get('flops', 0):.3g}")
        elif status == "error":
            extra = rec["error"]
        print(f"[{status:7s}] {a} x {s} x {rec.get('mesh')}  {extra}",
              flush=True)


if __name__ == "__main__":
    main()
