"""Hybrid-parallel subsystem (survey §3.2): multi-axis
data × tensor × stage meshes with ZeRO optimizer-state sharding, as a
declarative Strategy dimension.

  mesh_plan.py  MeshSpec geometry + suffix grammar (``d2.t2.s2.z3.adamw``)
                and MeshPlan — the composition plan (role-based tensor
                shards, GPipe micro-batching, the shared data-axis bucket
                plan, ZeRO shard sizes)
  staged.py     StagedModel contract + Megatron collective helpers + the
                tiny transformer-FFN reference model
  zero.py       ZeRO-1/2/3 sharded update over the data axis through the
                core/parameter_server.py reduce-scatter path (SGD + AdamW)
  engine.py     HybridEngine — the single device-executed train step over
                the 3-axis mesh, speaking the Engine/elastic protocol

See docs/hybrid.md for the grammar, axis semantics, and memory math.
"""
from repro.parallel.engine import HybridConfig, HybridEngine
from repro.parallel.mesh_plan import (AXES, MeshPlan, MeshSpec, parse_suffix,
                                      plan_mesh, suffix_spec)
from repro.parallel.staged import (StagedModel, is_staged_model,
                                   make_tiny_transformer, stacked_grad_fn,
                                   stacked_loss, tensor_copy)
from repro.parallel.zero import (make_zero_bucket_update,
                                 state_bytes_per_device,
                                 wire_bytes_per_device)

__all__ = [
    "AXES", "MeshSpec", "MeshPlan", "parse_suffix", "suffix_spec",
    "plan_mesh", "StagedModel", "is_staged_model", "make_tiny_transformer",
    "stacked_grad_fn", "stacked_loss", "tensor_copy", "HybridConfig",
    "HybridEngine", "make_zero_bucket_update", "state_bytes_per_device",
    "wire_bytes_per_device",
]
