"""ZeRO optimizer-state sharding over the data axis (Rajbhandari et al.),
as a first-class Strategy dimension (``z1``/``z2``/``z3`` mesh tokens).

The survey's PS-vs-allreduce dichotomy already gave this repo the
reduce-scatter / shard-update / all-gather path (core/parameter_server.py,
``arch=ps``); ZeRO is that path with the *persistent* state progressively
sharded over the D data-parallel ranks:

  level  persistent per-rank state          data-axis exchange per step
  z0     params + opt                       allreduce(grads)
  z1     params + opt/D                     allreduce(grads) + allgather(params)
  z2     params + opt/D                     reduce-scatter(grads) + allgather(params)
  z3     params/D + opt/D                   allgather(params) + reduce-scatter(grads)

z1 and z2 hold the same persistent state; they differ in the gradient
exchange (z1 materializes the full reduced gradient on every rank, z2
reduce-scatters so each rank only ever owns its shard) and therefore in
wire/transient-memory accounting.  z3 additionally shards the parameters
themselves: each step starts by all-gathering the param shards for
compute and ends by updating only the local shard.

Everything here operates on *flat per-bucket vectors* over the same
fused-bucket plan (``MeshPlan``) the data-parallel engine executes, and
is meant to run inside ``shard_map`` with a ``data`` mesh axis.  The
optimizer step works on shard pytrees, so ``repro.optim.adam.Adam`` (and
plain SGD) apply unchanged — the Adam moments simply live sharded.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.parameter_server import (all_gather_flat, pad_to_multiple,
                                         reduce_scatter_flat, shard_of_flat)
from repro.optim.adam import AdamW
from repro.parallel.mesh_plan import MeshPlan

ZERO_LEVELS = (0, 1, 2, 3)


def make_optimizer_step(optimizer: str, lr: float,
                        moment_dtype: str = "float32") -> Callable:
    """(params, grads, opt_state) -> (new_params, new_opt_state) on any
    pytree — full leaves (z0) or flat shards (z1-z3) alike.
    ``moment_dtype="bfloat16"`` stores the AdamW EMA buffers quantized
    (olmax-style); math stays fp32."""
    if optimizer == "sgd":
        def sgd_step(p, g, opt):
            return jax.tree.map(lambda a, b: a - lr * b, p, g), opt
        return sgd_step
    if optimizer == "adamw":
        adam = AdamW(moment_dtype=moment_dtype)

        def adam_step(p, g, opt):
            return adam.step(p, g, opt, lr)
        return adam_step
    raise ValueError(f"optimizer={optimizer!r} (want sgd | adamw)")


def init_opt_state(optimizer: str, params_like,
                   moment_dtype: str = "float32"):
    """Optimizer state matching ``params_like`` (full leaves or shards);
    None for stateless SGD."""
    if optimizer == "sgd":
        return None
    return AdamW(moment_dtype=moment_dtype).init(params_like)


def flatten_bucket(leaves: List[Any], idxs: List[int]) -> Any:
    """Concatenate the chosen leaves into one fp32 flat vector."""
    return jnp.concatenate(
        [leaves[i].astype(jnp.float32).reshape(-1) for i in idxs])


def make_zero_bucket_update(plan: MeshPlan, zero: int, optimizer: str,
                            lr: float, axis: str = "data",
                            moment_dtype: str = "float32") -> Callable:
    """Build the per-step ZeRO-1/2/3 update over ``plan``'s buckets.

    Returns ``update(p_buckets, g_buckets, opt, grad_reduce=None) ->
    (new_p_buckets, new_opt)`` where the bucket lists follow
    ``plan.order`` issue order; for z1/z2 ``p_buckets`` are full flat
    buckets in and out, for z3 they are per-rank shards in and out (the
    engine owns the gather-for-compute side).  ``opt`` is the sharded
    optimizer state ({"m","v","t"} of per-bucket shards for adamw, None
    for sgd).  Gradient buckets are summed over ``axis`` and divided by
    the axis size (mean semantics, matching the allreduce path).

    ``grad_reduce(padded_flat, bucket_pos) -> my_shard_sum`` replaces the
    default full-precision psum / reduce-scatter with a caller-supplied
    exchange — the hook the hybrid engine uses to route the gradient push
    through the compressed-payload schedules of ``repro.comm`` under
    ``wire="measured"`` (parameters still travel exact)."""
    if zero not in (1, 2, 3):
        raise ValueError(f"zero={zero} (bucket update is for levels 1-3)")
    opt_step = make_optimizer_step(optimizer, lr, moment_dtype)
    n_data = plan.mesh.data
    sizes = [plan.bucket_sizes[b] for b in plan.order]

    def update(p_buckets, g_buckets, opt, grad_reduce=None):
        g_shards = []
        for j, (g, n_b) in enumerate(zip(g_buckets, sizes)):
            padded, _ = pad_to_multiple(g, n_data)
            if grad_reduce is not None:
                g_shards.append(grad_reduce(padded, j))
            elif zero == 1:
                # full allreduce, then slice my shard (grads materialize
                # everywhere — ZeRO-1 only shards the *optimizer* state)
                g_shards.append(shard_of_flat(lax.psum(padded, axis), axis))
            else:
                g_shards.append(reduce_scatter_flat(padded, axis))
        g_shards = [g / n_data for g in g_shards]
        if zero == 3:
            p_shards = list(p_buckets)
        else:
            p_shards = [shard_of_flat(pad_to_multiple(p, n_data)[0], axis)
                        for p in p_buckets]
        new_shards, new_opt = opt_step(p_shards, g_shards, opt)
        if zero == 3:
            return new_shards, new_opt
        return [all_gather_flat(s, axis, n_b)
                for s, n_b in zip(new_shards, sizes)], new_opt

    return update


# --------------------------------------------------------- memory model
def state_bytes_per_device(plan: MeshPlan, zero: int, optimizer: str,
                           moment_dtype: str = "float32") -> Dict[str, int]:
    """Analytic persistent param+optimizer bytes per device for the mesh
    — the memory math of docs/hybrid.md (fp32 params; moments at
    ``moment_dtype`` width, 2 B when quantized to bf16).  ``hybrid_bench``
    cross-checks this against the engine's measured state sizes."""
    n_local = plan.n_local_params
    shard = sum(plan.shard_sizes)        # padded 1/D of the local block
    params = shard if zero == 3 else n_local
    adam = AdamW(moment_dtype=moment_dtype)
    moments = adam.moments_per_param if optimizer == "adamw" else 0
    mb = adam.moment_bytes
    opt = moments * (shard if zero >= 1 else n_local)
    return {"params": 4 * params, "opt": mb * opt,
            "total": 4 * params + mb * opt}


def wire_bytes_per_device(plan: MeshPlan, zero: int,
                          grad_bytes: Optional[int] = None) -> int:
    """Modeled data-axis bytes one device moves per step under the ZeRO
    exchange schedule (ring collectives: AR = 2(D-1)/D, RS = AG =
    (D-1)/D of the payload).  ``grad_bytes`` defaults to the dense local
    gradient size; pass the compressor's accounting for compressed runs."""
    d = plan.mesh.data
    if d == 1:
        return 0
    n_local = 4 * plan.n_local_params
    g = n_local if grad_bytes is None else grad_bytes
    ar, rs = 2 * (d - 1) / d, (d - 1) / d
    if zero == 0:
        return int(ar * g)
    if zero == 1:
        return int(ar * g + rs * n_local)          # AR grads + AG params
    return int(rs * g + rs * n_local)              # RS grads + AG params
