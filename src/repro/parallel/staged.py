"""Stage-decomposable models for the hybrid (tensor × pipeline) axes.

``gpipe_forward`` (core/pipeline.py) needs a model expressed as a
shape-preserving per-stage function; Megatron-style tensor parallelism
additionally needs the stage function to know the tensor mesh axis so it
can place the two collectives of the column→row parallel pair:

  * forward of the row-parallel matmul: psum of the partial products,
    whose backward must be the *identity* (the cotangent is replicated);
  * backward of the column-parallel matmul: the input is replicated over
    the tensor axis, so its cotangent must be summed across tensor ranks
    — ``tensor_copy`` is the identity-forward / psum-backward operator
    (Megatron's conjugate "g" to the forward "f" = ``tensor_reduce``).

Both are ``custom_vjp``-wrapped: under ``shard_map(check_rep=False)``
(the only mode jax 0.4.37 supports for these programs) a raw ``lax.psum``
transposes to another psum — pmap semantics — which over-counts the
cotangent by the axis size.  The custom rules pin the correct transposes
(psum ↔ identity), which is exactly Megatron's f/g conjugate pair.

``StagedModel`` is the contract the hybrid engine consumes; the tiny
transformer-FFN block model below is the reference instance (residual
``x + gelu(x @ w_up) @ w_down`` blocks — leaf names chosen so
``core/parallelism.py``'s role table classifies ``w_up`` column-parallel
and ``w_down`` row-parallel).  ``stacked_loss`` runs the same parameters
unpipelined and unsharded — the single-device reference every mesh cell
is validated against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax


def tensor_copy(axis_name: str):
    """Identity forward, psum-over-``axis_name`` backward — apply to the
    (tensor-replicated) input of a column-parallel matmul so its cotangent
    sums the per-rank partials."""
    @jax.custom_vjp
    def f(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, g):
        return (lax.psum(g, axis_name),)

    f.defvjp(fwd, bwd)
    return f


def tensor_reduce(axis_name: str):
    """psum-over-``axis_name`` forward, *identity* backward — combine the
    partial products of a row-parallel matmul (the replicated output's
    cotangent flows back to each rank unchanged)."""
    @jax.custom_vjp
    def f(x):
        return lax.psum(x, axis_name)

    def fwd(x):
        return lax.psum(x, axis_name), None

    def bwd(_, g):
        return (g,)

    f.defvjp(fwd, bwd)
    return f


@dataclasses.dataclass(frozen=True)
class StagedModel:
    """A model the hybrid engine can pipeline and tensor-shard.

    stage_fn(stage_params, x, tensor_axis=None) -> y
        Shape-preserving per-stage transform.  When ``tensor_axis`` is a
        mesh axis name, ``stage_params`` arrive tensor-sharded on their
        role dimension and stage_fn must place the Megatron collectives
        (see module docstring); with ``tensor_axis=None`` it computes on
        full weights.
    inputs(batch) -> x [B, ...]
        The activation entering stage 0.
    readout(y, batch) -> scalar
        The loss head, applied to the last stage's outputs.

    Params are NOT carried here — they flow through ``engine.init`` like
    every other engine's, with each leaf carrying a leading stage dim.
    """
    stage_fn: Callable
    inputs: Callable
    readout: Callable


def is_staged_model(obj: Any) -> bool:
    return isinstance(obj, StagedModel)


def stacked_loss(model: StagedModel, params, batch,
                 tensor_axis: Optional[str] = None):
    """Unpipelined reference: run the S stacked stages sequentially on one
    device and apply the loss head — the trajectory every mesh cell must
    reproduce."""
    x = model.inputs(batch)
    n_stages = jax.tree.leaves(params)[0].shape[0]
    for s in range(n_stages):
        sp = jax.tree.map(lambda leaf: leaf[s], params)
        x = model.stage_fn(sp, x, tensor_axis=tensor_axis)
    return model.readout(x, batch)


def stacked_grad_fn(model: StagedModel) -> Callable:
    """(params, batch) -> (loss, grads) over the unpipelined stacked model
    — plugs a StagedModel into any data-parallel-only engine or the
    simulator as a reference."""
    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: stacked_loss(model, p, batch))(params)
    return grad_fn


# ------------------------------------------------- reference tiny model
def make_tiny_transformer(stages: int, d_model: int = 8, d_ff: int = 16,
                          seed: int = 0):
    """Residual transformer-FFN blocks (the tiny cross-check model of the
    hybrid acceptance tests): ``stages`` blocks of
    ``x + gelu(x @ w_up) @ w_down``, stacked on a leading stage dim.

    Returns ``(params, model)``; targets live in ``batch["y"]`` and the
    loss is mean squared error on the final activations."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    scale_up = 1.0 / jnp.sqrt(d_model)
    scale_dn = 1.0 / jnp.sqrt(d_ff)
    params = {
        "w_up": jax.random.normal(k1, (stages, d_model, d_ff)) * scale_up,
        "w_down": jax.random.normal(k2, (stages, d_ff, d_model)) * scale_dn,
    }

    def stage_fn(sp, x, tensor_axis=None):
        xin = x
        if tensor_axis is not None:
            x = tensor_copy(tensor_axis)(x)
        h = jax.nn.gelu(x @ sp["w_up"])      # column-parallel: local cols
        y = h @ sp["w_down"]                 # row-parallel: partial product
        if tensor_axis is not None:
            y = tensor_reduce(tensor_axis)(y)
        return xin + y

    def inputs(batch):
        return batch["x"]

    def readout(y, batch):
        return jnp.mean((y - batch["y"]) ** 2)

    return params, StagedModel(stage_fn=stage_fn, inputs=inputs,
                               readout=readout)
