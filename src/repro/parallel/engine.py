"""The hybrid-parallel engine: one device-executed train step over a
data × tensor × stage mesh with ZeRO-sharded optimizer state.

``HybridEngine`` composes the three parallelization methods of the
survey's §3.2 — and the repo's three previously-disconnected modules —
into a single jitted ``shard_map`` over a 3-axis mesh:

  stage axis    ``core/pipeline.py``'s GPipe micro-batch schedule: each
                stage device holds its stage's parameters, activations
                flow through the ``lax.scan`` + ``ppermute`` loop forward
                AND backward (ppermute's transpose runs the reverse
                pipeline), micro-batch gradients accumulate in the scan.
  tensor axis   ``core/parallelism.py``'s role-based PartitionSpecs made
                explicit: each leaf is sharded on its role dimension
                (column-parallel on the output dim, row-parallel on the
                input dim) and the StagedModel places the two Megatron
                collectives (see parallel/staged.py).
  data axis     the existing bucketed / compressed / error-feedback
                exchange of ``train/data_parallel.py`` — same bucket
                planner, same compressor accounting — either as a
                topology-explicit allreduce (z0) or through the
                reduce-scatter/shard-update/all-gather ZeRO path of
                ``core/parameter_server.py`` (z1-z3, parallel/zero.py).

The engine speaks the same Engine/elastic protocol as the other two
backends (init / step / finalize, export_state / import_state / reshard),
so ``Trainer.fit(plan=...)`` checkpoint-recovers and resizes hybrid runs
— resizing rebuilds the *data* axis (tensor × stage geometry is a model
property and survives), and checkpoints carry the sharded optimizer
state.  BSP only: asynchrony composes with the data axis, not with the
pipeline schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.collectives import shard_map
from repro.core.compression import Compressor, EF_METHODS
from repro.core.pipeline import gpipe_forward, gpipe_ticks
from repro.launch.mesh import make_hybrid_mesh
from repro.parallel.mesh_plan import AXES, MeshPlan, MeshSpec, plan_mesh
from repro.parallel.staged import (StagedModel, is_staged_model,
                                   tensor_reduce)
from repro.parallel.zero import (flatten_bucket, init_opt_state,
                                 make_optimizer_step, make_zero_bucket_update,
                                 state_bytes_per_device,
                                 wire_bytes_per_device)
from repro.train.data_parallel import (_scatter_flat, make_bucketed_allreduce)

DATA, TENSOR, STAGE = AXES


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    mesh: MeshSpec = MeshSpec()
    lr: float = 0.1
    compressor: Compressor = Compressor("none")
    zero: int = 0                    # ZeRO level 0-3 (data-axis sharding)
    optimizer: str = "sgd"           # sgd | adamw
    topology: str = "ring"           # z0 data-axis allreduce schedule
    bucket_mb: float = 4.0
    order: str = "tictac"
    micro_batches: int = 0           # 0 = auto (2*stages when pipelined)
    seed: int = 0

    @property
    def num_workers(self) -> int:
        """Total devices — the elastic layer's worker count."""
        return self.mesh.size


class HybridEngine:
    """BSP over a d×t×s mesh with ZeRO-0/1/2/3 state sharding.

    The model is either a plain ``grad_fn(params, batch)`` (pure data
    axis: mesh must be dK.t1.s1) or a ``StagedModel`` with stage-stacked
    params (any mesh).  ``batches(t, w)`` is keyed by *data-parallel
    slot* w in [0, mesh.data) — the tensor/stage axes replicate the
    slot's batch."""

    def __init__(self, cfg: HybridConfig, model, devices: Optional[Sequence] = None):
        if cfg.zero not in (0, 1, 2, 3):
            raise ValueError(f"zero={cfg.zero} (want 0..3)")
        if cfg.optimizer not in ("sgd", "adamw"):
            raise ValueError(f"optimizer={cfg.optimizer!r}")
        self.staged = is_staged_model(model)
        if not self.staged and not cfg.mesh.is_trivial:
            raise ValueError(
                f"mesh {cfg.mesh.spec()} has tensor/stage axes; pass a "
                "repro.parallel.StagedModel (a bare grad_fn cannot be "
                "pipelined or tensor-sharded)")
        self.cfg = cfg
        self.model: Optional[StagedModel] = model if self.staged else None
        self.grad_fn: Optional[Callable] = None if self.staged else model
        self._devs = list(devices or jax.devices())
        if len(self._devs) < cfg.mesh.size:
            raise ValueError(
                f"mesh {cfg.mesh.spec()} needs {cfg.mesh.size} devices, "
                f"have {len(self._devs)} (run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        self.mesh = make_hybrid_mesh(self._devs, cfg.mesh.data,
                                     cfg.mesh.tensor, cfg.mesh.stage)
        self.plan: Optional[MeshPlan] = None
        self.slowdowns: List[float] = [1.0] * cfg.mesh.data
        self._step_fn = None
        self._wire_cell: List[int] = []
        self._act_cell: List[int] = []
        self._wire_total = 0
        self._leaf_meta = None           # (treedef, [(local_shape, dtype)])

    # ------------------------------------------------------------ helpers
    @property
    def data_streams(self) -> int:
        """Batch streams the engine consumes (the data axis size) — the
        elastic layer keys ``ElasticBatches`` on this, not on the total
        device count."""
        return self.cfg.mesh.data

    @property
    def _ef_active(self) -> bool:
        return self.cfg.compressor.method in EF_METHODS

    def _ensure_plan(self, params):
        if self.plan is None:
            self.plan = plan_mesh(
                params, self.cfg.mesh, staged=self.staged,
                bucket_mb=self.cfg.bucket_mb, order=self.cfg.order,
                micro_batches=self.cfg.micro_batches, seed=self.cfg.seed)
            leaves = jax.tree.leaves(params)
            locals_ = jax.tree.leaves(self.plan.local_example)
            self._leaf_meta = (
                jax.tree.structure(params),
                [(tuple(lo.shape), le.dtype)
                 for lo, le in zip(locals_, leaves)])
        return self.plan

    def _local_block(self, leaf, t_dim, s_idx: int, t_idx: int):
        """Host-side (s, t) block of a stacked leaf — the array one mesh
        coordinate holds: a contiguous chunk of layers along dim 0, a
        role-dim slice along the tensor axis."""
        x = np.asarray(leaf)
        if self.staged:
            chunk = x.shape[0] // self.cfg.mesh.stage
            x = x[s_idx * chunk:(s_idx + 1) * chunk]
        if self.cfg.mesh.tensor > 1 and t_dim is not None:
            m = x.shape[t_dim] // self.cfg.mesh.tensor
            x = np.take(x, range(t_idx * m, (t_idx + 1) * m), axis=t_dim)
        return x

    def _bucket_flat(self, params, b: int, s_idx: int, t_idx: int):
        """Host-side flat (s, t)-local bucket vector, padded over data."""
        plan = self.plan
        leaves = jax.tree.leaves(params)
        flat = np.concatenate(
            [self._local_block(leaves[i], plan.tensor_dims[i], s_idx,
                               t_idx).astype(np.float32).reshape(-1)
             for i in plan.buckets[b]])
        pad = plan.mesh.data * -(-flat.size // plan.mesh.data) - flat.size
        return np.pad(flat, (0, pad))

    def _shard_array(self, params, b: int) -> np.ndarray:
        """[D, S, T, m] array of per-rank flat shards for bucket ``b``."""
        cfg, plan = self.cfg, self.plan
        d, t, s = cfg.mesh.data, cfg.mesh.tensor, cfg.mesh.stage
        m = -(-plan.bucket_sizes[b] // d)
        out = np.zeros((d, s, t, m), np.float32)
        for si in range(s):
            for ti in range(t):
                out[:, si, ti, :] = self._bucket_flat(
                    params, b, si, ti).reshape(d, m)
        return out

    def _materialize_params(self, pshard_arrays: List[np.ndarray]):
        """Inverse of ``_shard_array``: rebuild the full stacked parameter
        pytree from the per-bucket [D, S, T, m] shard arrays (host side —
        checkpointing, finalize, reshard)."""
        cfg, plan = self.cfg, self.plan
        treedef, meta = self._leaf_meta
        t_dims = plan.tensor_dims
        s_ax, t_ax = cfg.mesh.stage, cfg.mesh.tensor
        # allocate full stacked leaves
        full = []
        for i, (lshape, dtype) in enumerate(meta):
            gshape = list(lshape)
            td = t_dims[i]
            if t_ax > 1 and td is not None:
                gshape[td] *= t_ax
            if self.staged:
                gshape[0] *= s_ax
            full.append(np.zeros(gshape, np.float32))
        for arr, b in zip(pshard_arrays, plan.order):
            n_b = plan.bucket_sizes[b]
            for si in range(s_ax):
                for ti in range(t_ax):
                    flat = np.asarray(arr)[:, si, ti, :].reshape(-1)[:n_b]
                    off = 0
                    for i in plan.buckets[b]:
                        lshape, dtype = meta[i]
                        size = int(np.prod(lshape)) if lshape else 1
                        block = flat[off:off + size].reshape(lshape)
                        off += size
                        td = t_dims[i]
                        sl = [slice(None)] * block.ndim
                        if self.staged:
                            chunk = lshape[0]
                            sl[0] = slice(si * chunk, (si + 1) * chunk)
                        if t_ax > 1 and td is not None:
                            m = block.shape[td]
                            sl[td] = slice(ti * m, (ti + 1) * m)
                        full[i][tuple(sl)] = block
        full = [f.astype(meta[i][1]) for i, f in enumerate(full)]
        return jax.tree.unflatten(treedef, full)

    # -------------------------------------------------------------- specs
    def _param_spec(self, t_dim, local_ndim: int):
        """PartitionSpec of one stacked leaf: layer dim over the stage
        axis + the role dim over the tensor axis, replicated over data
        (local and global rank agree — stage/tensor divide dims)."""
        if not self.staged:
            return P()
        axes: List[Optional[str]] = [None] * local_ndim
        axes[0] = STAGE
        if t_dim is not None and self.cfg.mesh.tensor > 1:
            axes[t_dim] = TENSOR
        return P(*axes)

    def _state_specs(self):
        plan, cfg = self.plan, self.cfg
        t_dims = plan.tensor_dims
        locals_ = jax.tree.leaves(plan.local_example)
        treedef = self._leaf_meta[0]
        p_specs = jax.tree.unflatten(
            treedef, [self._param_spec(td, lo.ndim)
                      for td, lo in zip(t_dims, locals_)])
        shard_spec = [P(DATA, STAGE, TENSOR) for _ in plan.order]
        if cfg.zero == 3:
            params_spec: Any = shard_spec
        else:
            params_spec = p_specs
        if cfg.optimizer == "adamw":
            if cfg.zero == 0:
                opt_spec: Any = {"m": p_specs, "v": p_specs, "t": P()}
            else:
                opt_spec = {"m": list(shard_spec), "v": list(shard_spec),
                            "t": P()}
        else:
            opt_spec = P()      # None pytree: placeholder spec
        ef_spec = (jax.tree.unflatten(
            treedef, [P(DATA, STAGE, TENSOR) for _ in locals_])
            if self._ef_active else P())
        return params_spec, opt_spec, ef_spec

    # ---------------------------------------------------------------- init
    def init(self, params) -> Dict[str, Any]:
        cfg = self.cfg
        plan = self._ensure_plan(params)
        st: Dict[str, Any] = dict(rng=jax.random.PRNGKey(cfg.seed), wire=0)
        if cfg.zero == 3:
            st["params"] = [jnp.asarray(self._shard_array(params, b))
                            for b in plan.order]
        else:
            st["params"] = params
        if cfg.optimizer == "adamw":
            if cfg.zero == 0:
                st["opt"] = init_opt_state("adamw", params)
            else:
                # one moment shard per bucket, in ISSUE order — aligned
                # with the p/g bucket lists the step function builds
                zeros = [jnp.zeros((cfg.mesh.data, cfg.mesh.stage,
                                    cfg.mesh.tensor,
                                    plan.shard_sizes[b]), jnp.float32)
                         for b in plan.order]
                st["opt"] = {"m": list(zeros),
                             "v": [jnp.zeros_like(z) for z in zeros],
                             "t": jnp.zeros((), jnp.int32)}
        else:
            st["opt"] = None
        if self._ef_active:
            d, t, s = cfg.mesh.data, cfg.mesh.tensor, cfg.mesh.stage
            st["ef"] = jax.tree.map(
                lambda lo: jnp.zeros((d, s, t) + lo.shape, jnp.float32),
                plan.local_example)
        else:
            st["ef"] = None
        return st

    # ---------------------------------------------------------------- step
    def _build_step(self):
        cfg, plan = self.cfg, self.plan
        model, grad_fn = self.model, self.grad_fn
        comp = cfg.compressor
        D, T, S = cfg.mesh.data, cfg.mesh.tensor, cfg.mesh.stage
        micro = plan.micro
        treedef, meta = self._leaf_meta
        sizes = [plan.bucket_sizes[b] for b in plan.order]
        reduce0 = (make_bucketed_allreduce(
            plan.local_example, topology=cfg.topology,
            bucket_mb=cfg.bucket_mb, order=cfg.order, seed=cfg.seed,
            axis=DATA) if cfg.zero == 0 else None)
        zero_update = (make_zero_bucket_update(
            plan, cfg.zero, cfg.optimizer, cfg.lr, axis=DATA)
            if cfg.zero else None)
        opt_step0 = (make_optimizer_step(cfg.optimizer, cfg.lr)
                     if cfg.zero == 0 else None)
        tensor_axis = TENSOR if T > 1 else None
        wire_cell: List[int] = []
        act_cell: List[int] = []

        def squeeze3(x):
            return x[0, 0, 0]

        def expand3(x):
            return jnp.expand_dims(x, (0, 1, 2))

        chunk = (jax.tree.leaves(plan.local_example)[0].shape[0]
                 if self.staged else 0)

        def local_params(pstate):
            if cfg.zero == 3:
                shards = [squeeze3(x) for x in pstate]
                out: List[Any] = [None] * len(meta)
                for shard, b, n_b in zip(shards, plan.order, sizes):
                    full = lax.all_gather(shard, DATA).reshape(-1)[:n_b]
                    _scatter_flat(full, plan.buckets[b],
                                  meta, out)
                return jax.tree.unflatten(treedef, out)
            return pstate

        def stage_call(sp, xx):
            # one stage device holds a contiguous chunk of layers
            for j in range(chunk):
                xx = model.stage_fn(jax.tree.map(lambda l: l[j], sp), xx,
                                    tensor_axis=tensor_axis)
            return xx

        def local_loss_and_grads(p_local, batch):
            if not self.staged:
                return grad_fn(p_local, batch)

            def lloss(pl):
                x = model.inputs(batch)
                bsz = x.shape[0]
                xm = x.reshape((micro, bsz // micro) + x.shape[1:])
                if not act_cell:
                    act_cell.append(int(np.prod(xm.shape[1:])) * 4)
                outs = gpipe_forward(stage_call, pl, xm, STAGE)
                y = outs.reshape((bsz,) + x.shape[1:])
                loss = model.readout(y, batch)
                # only the last stage holds real outputs; the reduce
                # broadcasts its loss along the stage axis with identity
                # transpose (each stage's masked loss gets the plain
                # cotangent — the pipeline backward itself flows through
                # the ppermute chain inside gpipe_forward)
                loss = jnp.where(lax.axis_index(STAGE) == S - 1, loss, 0.0)
                return tensor_reduce(STAGE)(loss)

            return jax.value_and_grad(lloss)(p_local)

        def body(pstate, opt, ef, batch, key0):
            batch_l = jax.tree.map(lambda x: x[0], batch)
            p_local = local_params(pstate)
            loss, grads = local_loss_and_grads(p_local, batch_l)
            key = key0
            for ax in AXES:
                key = jax.random.fold_in(key, lax.axis_index(ax))
            if comp.method != "none":
                ef_l = jax.tree.map(squeeze3, ef) if ef is not None else None
                grads, ef_new, wb = comp.roundtrip(grads, ef_l, key)
                ef_out = (jax.tree.map(expand3, ef_new)
                          if ef_new is not None else ef)
            else:
                ef_out = ef
                wb = sum(int(np.prod(s)) * 4 for s, _ in meta)
            if not wire_cell:
                wire_cell.append(int(wb))
            if cfg.zero == 0:
                avg = reduce0(grads)
                p_out, opt_new = opt_step0(p_local, avg, opt)
            else:
                g_leaves = jax.tree.leaves(grads)
                g_buckets = [flatten_bucket(g_leaves, plan.buckets[b])
                             for b in plan.order]
                if cfg.zero == 3:
                    p_buckets = [squeeze3(x) for x in pstate]
                else:
                    p_leaves = jax.tree.leaves(p_local)
                    p_buckets = [flatten_bucket(p_leaves, plan.buckets[b])
                                 for b in plan.order]
                opt_l = opt
                if opt is not None:
                    opt_l = {"m": [squeeze3(x) for x in opt["m"]],
                             "v": [squeeze3(x) for x in opt["v"]],
                             "t": opt["t"]}
                new_buckets, opt_new = zero_update(p_buckets, g_buckets,
                                                   opt_l)
                if opt_new is not None:
                    opt_new = {"m": [expand3(x) for x in opt_new["m"]],
                               "v": [expand3(x) for x in opt_new["v"]],
                               "t": opt_new["t"]}
                if cfg.zero == 3:
                    p_out = [expand3(x) for x in new_buckets]
                else:
                    out: List[Any] = [None] * len(meta)
                    for flat, b in zip(new_buckets, plan.order):
                        _scatter_flat(flat, plan.buckets[b], meta, out)
                    p_out = jax.tree.unflatten(treedef, out)
            return p_out, opt_new if opt is not None else opt, ef_out, \
                loss[None]

        params_spec, opt_spec, ef_spec = self._state_specs()
        fn = shard_map(
            body, mesh=self.mesh,
            in_specs=(params_spec, opt_spec, ef_spec, P(DATA), P()),
            out_specs=(params_spec, opt_spec, ef_spec, P(DATA)),
            check_vma=False)
        return jax.jit(fn), wire_cell, act_cell

    def step(self, st, batches: Callable[[int, int], Any], t: int):
        cfg = self.cfg
        if self._step_fn is None:
            self._step_fn, self._wire_cell, self._act_cell = \
                self._build_step()
        D = cfg.mesh.data
        per = [batches(t, w) for w in range(D)]
        if self.staged and cfg.mesh.stage > 1:
            bsz = int(np.shape(self.model.inputs(per[0]))[0])
            if bsz % self.plan.micro:
                raise ValueError(
                    f"batch size {bsz} not divisible into "
                    f"{self.plan.micro} micro-batches")
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        st["rng"], sub = jax.random.split(st["rng"])
        params, opt, ef, losses = self._step_fn(st["params"], st["opt"],
                                                st["ef"], batch, sub)
        st.update(params=params, opt=opt, ef=ef)
        st["wire"] += self._wire_cell[0] * cfg.mesh.size
        self._wire_total = st["wire"]
        ev = dict(step=t, loss=float(np.mean(np.asarray(losses))),
                  max_staleness=0)
        return st, [ev]

    def finalize(self, st):
        if self.cfg.zero == 3:
            return self._materialize_params(
                [np.asarray(x) for x in st["params"]])
        return st["params"]

    def wire_bytes(self) -> int:
        return self._wire_total

    # ------------------------------------------------------------- metrics
    def per_device_state_bytes(self, st) -> Dict[str, int]:
        """Measured persistent bytes per device, from the actual state
        arrays divided by their sharding factor — what docs/hybrid.md's
        memory math predicts and the ZeRO acceptance test asserts on."""
        cfg = self.cfg
        D, T, S = cfg.mesh.data, cfg.mesh.tensor, cfg.mesh.stage
        stacked_div = (S * T) if self.staged else 1
        shard_div = D * S * T
        out = {"params": 0, "opt": 0, "ef": 0}
        if cfg.zero == 3:
            out["params"] = sum(np.asarray(x).nbytes // shard_div
                                for x in st["params"])
        else:
            out["params"] = sum(np.asarray(x).nbytes // stacked_div
                                for x in jax.tree.leaves(st["params"]))
        if st["opt"] is not None:
            for k in ("m", "v"):
                leaves = jax.tree.leaves(st["opt"][k])
                div = stacked_div if cfg.zero == 0 else shard_div
                out["opt"] += sum(np.asarray(x).nbytes // div
                                  for x in leaves)
            out["opt"] += 4
        if st["ef"] is not None:
            out["ef"] = sum(np.asarray(x).nbytes // shard_div
                            for x in jax.tree.leaves(st["ef"]))
        out["total"] = out["params"] + out["opt"]
        return out

    def extra_metrics(self) -> Dict[str, Any]:
        cfg, plan = self.cfg, self.plan
        m: Dict[str, Any] = dict(
            mesh=cfg.mesh.spec(), zero=cfg.zero, optimizer=cfg.optimizer)
        if plan is not None:
            wb = self._wire_cell[0] if self._wire_cell else None
            m["modeled_data_bytes_per_dev"] = wire_bytes_per_device(
                plan, cfg.zero, grad_bytes=wb)
            m["analytic_state_bytes"] = state_bytes_per_device(
                plan, cfg.zero, cfg.optimizer)
            if self._act_cell and cfg.mesh.stage > 1:
                ticks = gpipe_ticks(cfg.mesh.stage, plan.micro)
                m["modeled_pipeline_bytes_per_dev"] = \
                    self._act_cell[0] * ticks
                if cfg.mesh.tensor > 1:
                    t = cfg.mesh.tensor
                    m["modeled_tensor_bytes_per_dev"] = int(
                        self._act_cell[0] * ticks * 2 * (t - 1) / t)
        return m

    # --------------------------------------------------- elastic interface
    def set_slowdown(self, worker: int, factor: float):
        """Record a straggler event.  Plan worker ids are flat device
        indices; a device's slowdown is recorded against its data slot
        (devices are data-major, so slot = id // (t*s)).  The hybrid step
        is a single fused BSP program — there is no backup-drop path to
        feed — so the record only affects reshard bookkeeping."""
        ts = self.cfg.mesh.tensor * self.cfg.mesh.stage
        slot = worker // ts
        if not 0 <= slot < self.cfg.mesh.data:
            raise ValueError(f"worker {worker} out of range for mesh "
                             f"{self.cfg.mesh.spec()}")
        self.slowdowns[slot] = factor

    def crash_plan(self, worker: int) -> Tuple[int, Tuple[int, ...]]:
        """What losing device ``worker`` means for this mesh: its whole
        tensor × stage block (the model-parallel replica of one data
        slot) goes with it, so the run reshards to one fewer data
        replica.  The elastic trainer consults this instead of assuming
        flat worker = device - 1 semantics."""
        cfg = self.cfg
        if not 0 <= worker < cfg.mesh.size:
            raise ValueError(f"worker {worker} out of range for mesh "
                             f"{cfg.mesh.spec()}")
        ts = cfg.mesh.tensor * cfg.mesh.stage
        if cfg.mesh.data <= 1:
            raise ValueError(
                f"mesh {cfg.mesh.spec()} has a single data replica; "
                "losing a device leaves nothing to reshard to")
        return cfg.mesh.size - ts, (worker // ts,)

    def reshard(self, st, new_workers: int, step: int = 0,
                lost: Tuple[int, ...] = ()):
        """Resize the mesh to ``new_workers`` total devices by rebuilding
        the *data* axis (tensor × stage geometry is a property of the
        model and survives).  ZeRO shards are re-cut over the new data
        axis; survivor data slots keep their EF residuals."""
        cfg, plan = self.cfg, self.plan
        ts = cfg.mesh.tensor * cfg.mesh.stage
        if new_workers < ts or new_workers % ts:
            raise ValueError(
                f"resize to {new_workers} devices does not factor over the "
                f"tensor*stage block of {ts} (mesh {cfg.mesh.spec()}); "
                "hybrid meshes resize along the data axis only")
        new_d = new_workers // ts
        if new_workers > len(self._devs):
            raise ValueError(
                f"resize to {new_workers} devices: have {len(self._devs)}")
        bad = [w for w in lost if w < 0 or w >= cfg.mesh.data]
        if bad:
            raise ValueError(f"lost data slots {bad} out of range for "
                             f"data axis {cfg.mesh.data}")
        survivors = [w for w in range(cfg.mesh.data) if w not in set(lost)]
        slots = survivors[:new_d]
        grown = new_d - len(slots)
        st = {k: (jax.device_get(v) if k not in ("wire",) else v)
              for k, v in st.items()}
        # re-cut the flat data-axis shards (params for z3, moments for z1+)
        old_plan = plan

        def recut(arrs: List[np.ndarray]) -> List[np.ndarray]:
            out = []
            for arr, b in zip(arrs, old_plan.order):
                arr = np.asarray(arr)
                n_b = old_plan.bucket_sizes[b]
                m_new = -(-n_b // new_d)
                _, S, T, _ = arr.shape
                new = np.zeros((new_d, S, T, m_new), np.float32)
                for si in range(S):
                    for ti in range(T):
                        flat = arr[:, si, ti, :].reshape(-1)[:n_b]
                        new[:, si, ti, :] = np.pad(
                            flat, (0, new_d * m_new - n_b)).reshape(
                                new_d, m_new)
                out.append(new)
            return out

        if cfg.zero == 3:
            st["params"] = recut(st["params"])
        if st["opt"] is not None and cfg.zero >= 1:
            st["opt"] = {"m": recut(st["opt"]["m"]),
                         "v": recut(st["opt"]["v"]), "t": st["opt"]["t"]}
        if st["ef"] is not None:
            def remap_rows(x):
                x = np.asarray(x)
                rows = ([x[s] for s in slots]
                        + [np.zeros_like(x[0])] * grown)
                return np.stack(rows)
            st["ef"] = jax.tree.map(remap_rows, st["ef"])
        new_mesh = MeshSpec(new_d, cfg.mesh.tensor, cfg.mesh.stage)
        self.cfg = cfg = dataclasses.replace(cfg, mesh=new_mesh)
        self.mesh = make_hybrid_mesh(self._devs, new_d, cfg.mesh.tensor,
                                     cfg.mesh.stage)
        self.slowdowns = [self.slowdowns[s] for s in slots] + [1.0] * grown
        # the bucket identity is a function of the local block structure
        # and survives; only the per-rank shard length changes
        self.plan = dataclasses.replace(
            old_plan, mesh=new_mesh,
            shard_sizes=[-(-n // new_d) for n in old_plan.bucket_sizes])
        self._step_fn = None
        self._wire_cell, self._act_cell = [], []
        return st

    def export_state(self, st) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        cfg = self.cfg
        arrays = {"params": st["params"], "opt": st["opt"], "ef": st["ef"],
                  "rng": st["rng"]}
        meta = dict(backend="hybrid", mesh=cfg.mesh.spec(), zero=cfg.zero,
                    optimizer=cfg.optimizer, num_workers=cfg.mesh.size,
                    wire=int(st["wire"]), slowdowns=list(self.slowdowns))
        return arrays, meta

    def import_state(self, arrays: Dict[str, Any], meta: Dict[str, Any]):
        cfg = self.cfg
        if meta["num_workers"] != cfg.mesh.size:
            raise ValueError(
                f"snapshot has {meta['num_workers']} devices, engine has "
                f"{cfg.mesh.size}; reshard the engine first")
        if meta["mesh"] != cfg.mesh.spec() or meta["zero"] != cfg.zero \
                or meta["optimizer"] != cfg.optimizer:
            raise ValueError(
                f"snapshot geometry {meta['mesh']}/z{meta['zero']}/"
                f"{meta['optimizer']} does not match engine "
                f"{cfg.mesh.spec()}/z{cfg.zero}/{cfg.optimizer}")
        self.slowdowns = [float(s) for s in meta["slowdowns"]]
        st = dict(params=arrays["params"], opt=arrays["opt"],
                  ef=arrays["ef"], rng=jnp.asarray(arrays["rng"]),
                  wire=int(meta["wire"]))
        self._wire_total = st["wire"]
        return st

    # ------------------------------------------------------------------ run
    def run(self, params, batches: Callable[[int, int], Any], steps: int):
        st = self.init(params)
        hist: List[dict] = []
        for t in range(steps):
            st, ev = self.step(st, batches, t)
            hist.extend(ev)
        return self.finalize(st), hist, st["wire"]
