"""The hybrid-parallel engine: one device-executed train step over a
data × tensor × stage mesh with ZeRO-sharded optimizer state.

``HybridEngine`` composes the three parallelization methods of the
survey's §3.2 — and the repo's three previously-disconnected modules —
into a single jitted ``shard_map`` over a 3-axis mesh:

  stage axis    ``core/pipeline.py``'s GPipe micro-batch schedule: each
                stage device holds its stage's parameters, activations
                flow through the ``lax.scan`` + ``ppermute`` loop forward
                AND backward (ppermute's transpose runs the reverse
                pipeline), micro-batch gradients accumulate in the scan.
  tensor axis   ``core/parallelism.py``'s role-based PartitionSpecs made
                explicit: each leaf is sharded on its role dimension
                (column-parallel on the output dim, row-parallel on the
                input dim) and the StagedModel places the two Megatron
                collectives (see parallel/staged.py).
  data axis     the existing bucketed / compressed / error-feedback
                exchange of ``train/data_parallel.py`` — same bucket
                planner, same compressor accounting — either as a
                topology-explicit allreduce (z0) or through the
                reduce-scatter/shard-update/all-gather ZeRO path of
                ``core/parameter_server.py`` (z1-z3, parallel/zero.py).

The engine speaks the same Engine/elastic protocol as the other two
backends (init / step / finalize, export_state / import_state / reshard),
so ``Trainer.fit(plan=...)`` checkpoint-recovers and resizes hybrid runs
— resizing rebuilds the *data* axis (tensor × stage geometry is a model
property and survives), and checkpoints carry the sharded optimizer
state.  BSP only: asynchrony composes with the data axis, not with the
pipeline schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.comm.codecs import SPARSE_ELEM_BYTES, codec_for, make_codec
from repro.comm.plan import CommPlan, modeled_event_bytes
from repro.comm.transport import (compressed_allreduce,
                                  compressed_reduce_scatter,
                                  schedule_tx_bytes)
from repro.core.collectives import shard_map
from repro.core.compression import Compressor, EF_METHODS
from repro.core.parameter_server import shard_of_flat
from repro.core.pipeline import (bubble_fraction, gpipe_forward, gpipe_ticks,
                                 onefb_bubble_fraction, onefb_forward,
                                 onefb_ticks)
from repro.core.precision import policy_for
from repro.obs.trace import get_recorder
from repro.core.sync import default_periods
from repro.launch.mesh import make_hybrid_mesh
from repro.parallel.mesh_plan import AXES, MeshPlan, MeshSpec, plan_mesh
from repro.parallel.staged import (StagedModel, is_staged_model,
                                   tensor_reduce)
from repro.parallel.zero import (flatten_bucket, init_opt_state,
                                 make_optimizer_step, make_zero_bucket_update,
                                 state_bytes_per_device,
                                 wire_bytes_per_device)
from repro.train.data_parallel import _scatter_flat, async_replay_step

DATA, TENSOR, STAGE = AXES

ASYNC_SYNCS = ("ssp", "asp")


def emit_pipeline_trace(rec, stages: int, micro: int, *,
                        schedule: str = "gpipe", interleave: int = 1,
                        pid: str = "pipeline", clock=None) -> None:
    """The pipeline schedule this step executed, as trace spans on the
    deterministic tick clock (docs/observability.md): a ``pipe`` parent
    span on ``pipeline/schedule`` carrying the schedule-specific analytic
    bubble fraction, and per-stage tracks ``stage<s>`` with one span per
    schedule tick — ``mb<k>`` while the stage device computes micro-batch
    k, and ``bubble`` for the fill/drain ticks where it sits idle.  Under
    GPipe stage s holds micro k = tick - s; under (interleaved) 1F1B
    device i is busy for its ``v * m`` consecutive chunk calls starting
    at tick i, computing micro ``(tick - i) mod m`` of chunk
    ``(tick - i) // m``.  The fused jitted step cannot be split at
    runtime, so like the CommPlan exchange spans this is the plan's own
    deterministic model of what executed;
    ``obs.analyze.pipeline_accounting`` measures the bubble fraction
    back off these spans."""
    if not rec.enabled:
        return
    if schedule == "1f1b":
        v = interleave
        ticks = onefb_ticks(stages, micro, v)
        analytic = onefb_bubble_fraction(stages, micro, v)
    else:
        v = 1
        ticks = gpipe_ticks(stages, micro)
        analytic = bubble_fraction(stages, micro)
    rec.begin("pipe", pid=pid, tid="schedule", cat="pipeline", clock=clock,
              stages=stages, micro=micro, ticks=ticks, schedule=schedule,
              interleave=v, analytic_bubble=round(analytic, 6))
    for s in range(stages):
        tid = f"stage{s}"
        for k in range(ticks):
            if schedule == "1f1b":
                active = s <= k < s + v * micro
                mb = (k - s) % micro
            else:
                mb = k - s
                active = 0 <= mb < micro
            name = f"mb{mb}" if active else "bubble"
            rec.begin(name, pid=pid, tid=tid, cat="pipeline",
                      clock=("pipe_tick", k), stage=s)
            rec.end(pid=pid, tid=tid)
    rec.end(pid=pid, tid="schedule")


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    mesh: MeshSpec = MeshSpec()
    lr: float = 0.1
    compressor: Compressor = Compressor("none")
    zero: int = 0                    # ZeRO level 0-3 (data-axis sharding)
    optimizer: str = "sgd"           # sgd | adamw
    topology: str = "ring"           # z0 data-axis allreduce schedule
    bucket_mb: float = 4.0
    order: str = "tictac"
    micro_batches: int = 0           # 0 = auto (2*stages when pipelined)
    schedule: str = "gpipe"          # pipeline schedule: gpipe | 1f1b
    interleave: int = 0              # 1f1b virtual stages/device (0 = auto 2)
    precision: str = "fp32"          # fp32 | bf16 | bf16r (core/precision)
    moments: str = "float32"         # AdamW EMA storage: float32 | bfloat16
    # sync model over the DATA axis (docs/hybrid.md): bsp natively; ssp/
    # asp replay the simulator's staleness schedule per data slot, sma
    # keeps a replica per data slot — all three need stage=1, z0, sgd
    sync: str = "bsp"
    staleness: int = 3
    periods: Optional[Tuple[int, ...]] = None   # per data-slot speeds
    sma_mu: float = 0.1
    wire: str = "modeled"            # modeled | measured (docs/comm.md)
    seed: int = 0

    @property
    def num_workers(self) -> int:
        """Total devices — the elastic layer's worker count."""
        return self.mesh.size


class HybridEngine:
    """BSP over a d×t×s mesh with ZeRO-0/1/2/3 state sharding.

    The model is either a plain ``grad_fn(params, batch)`` (pure data
    axis: mesh must be dK.t1.s1) or a ``StagedModel`` with stage-stacked
    params (any mesh).  ``batches(t, w)`` is keyed by *data-parallel
    slot* w in [0, mesh.data) — the tensor/stage axes replicate the
    slot's batch."""

    def __init__(self, cfg: HybridConfig, model, devices: Optional[Sequence] = None):
        if cfg.zero not in (0, 1, 2, 3):
            raise ValueError(f"zero={cfg.zero} (want 0..3)")
        if cfg.optimizer not in ("sgd", "adamw"):
            raise ValueError(f"optimizer={cfg.optimizer!r}")
        if cfg.sync not in ("bsp",) + ASYNC_SYNCS + ("sma",):
            raise ValueError(f"sync={cfg.sync!r}")
        if cfg.wire not in ("modeled", "measured"):
            raise ValueError(f"wire={cfg.wire!r}")
        if cfg.sync != "bsp" and (cfg.mesh.stage != 1 or cfg.zero
                                  or cfg.optimizer != "sgd"):
            raise ValueError(
                f"sync={cfg.sync!r} composes with the data axis only: "
                "needs stage=1, zero=0, optimizer='sgd'")
        if cfg.schedule not in ("gpipe", "1f1b"):
            raise ValueError(f"schedule={cfg.schedule!r} (want gpipe|1f1b)")
        if cfg.schedule == "1f1b" and cfg.mesh.stage < 2:
            raise ValueError(
                "schedule='1f1b' needs a pipeline (mesh stage >= 2)")
        if cfg.interleave and cfg.schedule != "1f1b":
            raise ValueError(
                f"interleave=v{cfg.interleave} only applies to the 1f1b "
                "schedule")
        if cfg.interleave < 0:
            raise ValueError(f"interleave={cfg.interleave} (want >= 1)")
        if cfg.moments not in ("float32", "bfloat16"):
            raise ValueError(
                f"moments={cfg.moments!r} (want float32|bfloat16)")
        self._policy = policy_for(cfg.precision)   # raises on unknown name
        if cfg.sync != "bsp" and cfg.precision != "fp32":
            raise ValueError(
                f"sync={cfg.sync!r} cells run fp32 (precision="
                f"{cfg.precision!r} composes with BSP only)")
        # effective 1f1b interleave: v virtual stages per device
        self._v = ((cfg.interleave or 2)
                   if cfg.schedule == "1f1b" else 1)
        self.staged = is_staged_model(model)
        if not self.staged and not cfg.mesh.is_trivial:
            raise ValueError(
                f"mesh {cfg.mesh.spec()} has tensor/stage axes; pass a "
                "repro.parallel.StagedModel (a bare grad_fn cannot be "
                "pipelined or tensor-sharded)")
        self.cfg = cfg
        self.model: Optional[StagedModel] = model if self.staged else None
        self.grad_fn: Optional[Callable] = None if self.staged else model
        self._devs = list(devices or jax.devices())
        if len(self._devs) < cfg.mesh.size:
            raise ValueError(
                f"mesh {cfg.mesh.spec()} needs {cfg.mesh.size} devices, "
                f"have {len(self._devs)} (run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        self.mesh = make_hybrid_mesh(self._devs, cfg.mesh.data,
                                     cfg.mesh.tensor, cfg.mesh.stage)
        self.plan: Optional[MeshPlan] = None
        self.periods = cfg.periods or default_periods(cfg.mesh.data)
        assert len(self.periods) == cfg.mesh.data
        self.slowdowns: List[float] = [1.0] * cfg.mesh.data
        self._step_fn = None
        self._async_fns = None
        self._sma_fn = None
        self._act_cell: List[int] = []
        self._dev_event_bytes: Optional[int] = None
        self._measured_tx: Optional[int] = None
        self._trace_plan: Optional[CommPlan] = None
        self._wire_total = 0
        self._leaf_meta = None           # (treedef, [(local_shape, dtype)])
        # same replicated apply as the flat engines (async data axis)
        self._apply = jax.jit(
            lambda p, g, lr: jax.tree.map(lambda a, b: a - lr * b, p, g))

    # ------------------------------------------------------------ helpers
    @property
    def data_streams(self) -> int:
        """Batch streams the engine consumes (the data axis size) — the
        elastic layer keys ``ElasticBatches`` on this, not on the total
        device count."""
        return self.cfg.mesh.data

    @property
    def _ef_active(self) -> bool:
        return self.cfg.compressor.method in EF_METHODS

    def _ensure_plan(self, params):
        if self.plan is None:
            self.plan = plan_mesh(
                params, self.cfg.mesh, staged=self.staged,
                bucket_mb=self.cfg.bucket_mb, order=self.cfg.order,
                micro_batches=self.cfg.micro_batches, seed=self.cfg.seed)
            leaves = jax.tree.leaves(params)
            locals_ = jax.tree.leaves(self.plan.local_example)
            self._leaf_meta = (
                jax.tree.structure(params),
                [(tuple(lo.shape), le.dtype)
                 for lo, le in zip(locals_, leaves)])
            if self.cfg.schedule == "1f1b":
                s = self.cfg.mesh.stage
                if self.plan.micro < s:
                    raise ValueError(
                        f"1f1b needs micro_batches >= stages (got "
                        f"m={self.plan.micro} < s={s}); the wrap-link "
                        "FIFO gap m - s must be >= 0")
                chunk = locals_[0].shape[0]
                if chunk % self._v:
                    raise ValueError(
                        f"1f1b interleave v{self._v}: per-stage layer "
                        f"count {chunk} not divisible into v virtual "
                        "stages")
        return self.plan

    # ------------------------------------------- 1f1b virtual-stage layout
    def _stage_perm(self, n_rows: int) -> np.ndarray:
        """Row permutation of a globally stacked leaf for interleaved
        1F1B: device i must hold virtual stages {c*S + i | c < v} as its
        v contiguous local chunks (chunk-major), so the existing
        contiguous stage slicing of ``_local_block`` / the P(STAGE)
        in-spec hands every device exactly the layers
        ``onefb_forward``'s per-chunk dynamic slice expects."""
        s, v = self.cfg.mesh.stage, self._v
        cl = n_rows // (s * v)
        idx: List[int] = []
        for i in range(s):
            for c in range(v):
                vs = c * s + i
                idx.extend(range(vs * cl, (vs + 1) * cl))
        return np.asarray(idx)

    def _permute_stacked(self, params, inverse: bool = False):
        """Reorder stacked-leaf rows into (or back out of) the 1f1b
        virtual-stage layout.  Identity for gpipe / v=1, so every
        existing cell's arrays are untouched."""
        if not self.staged or self._v == 1:
            return params

        def f(leaf):
            perm = self._stage_perm(np.shape(leaf)[0])
            if inverse:
                perm = np.argsort(perm)
            return jnp.asarray(leaf)[perm]
        return jax.tree.map(f, params)

    def _local_block(self, leaf, t_dim, s_idx: int, t_idx: int):
        """Host-side (s, t) block of a stacked leaf — the array one mesh
        coordinate holds: a contiguous chunk of layers along dim 0, a
        role-dim slice along the tensor axis."""
        x = np.asarray(leaf)
        if self.staged:
            chunk = x.shape[0] // self.cfg.mesh.stage
            x = x[s_idx * chunk:(s_idx + 1) * chunk]
        if self.cfg.mesh.tensor > 1 and t_dim is not None:
            m = x.shape[t_dim] // self.cfg.mesh.tensor
            x = np.take(x, range(t_idx * m, (t_idx + 1) * m), axis=t_dim)
        return x

    def _bucket_flat(self, params, b: int, s_idx: int, t_idx: int):
        """Host-side flat (s, t)-local bucket vector, padded over data."""
        plan = self.plan
        leaves = jax.tree.leaves(params)
        flat = np.concatenate(
            [self._local_block(leaves[i], plan.tensor_dims[i], s_idx,
                               t_idx).astype(np.float32).reshape(-1)
             for i in plan.buckets[b]])
        pad = plan.mesh.data * -(-flat.size // plan.mesh.data) - flat.size
        return np.pad(flat, (0, pad))

    def _shard_array(self, params, b: int) -> np.ndarray:
        """[D, S, T, m] array of per-rank flat shards for bucket ``b``."""
        cfg, plan = self.cfg, self.plan
        d, t, s = cfg.mesh.data, cfg.mesh.tensor, cfg.mesh.stage
        m = -(-plan.bucket_sizes[b] // d)
        out = np.zeros((d, s, t, m), np.float32)
        for si in range(s):
            for ti in range(t):
                out[:, si, ti, :] = self._bucket_flat(
                    params, b, si, ti).reshape(d, m)
        return out

    def _materialize_params(self, pshard_arrays: List[np.ndarray]):
        """Inverse of ``_shard_array``: rebuild the full stacked parameter
        pytree from the per-bucket [D, S, T, m] shard arrays (host side —
        checkpointing, finalize, reshard)."""
        cfg, plan = self.cfg, self.plan
        treedef, meta = self._leaf_meta
        t_dims = plan.tensor_dims
        s_ax, t_ax = cfg.mesh.stage, cfg.mesh.tensor
        # allocate full stacked leaves
        full = []
        for i, (lshape, dtype) in enumerate(meta):
            gshape = list(lshape)
            td = t_dims[i]
            if t_ax > 1 and td is not None:
                gshape[td] *= t_ax
            if self.staged:
                gshape[0] *= s_ax
            full.append(np.zeros(gshape, np.float32))
        for arr, b in zip(pshard_arrays, plan.order):
            n_b = plan.bucket_sizes[b]
            for si in range(s_ax):
                for ti in range(t_ax):
                    flat = np.asarray(arr)[:, si, ti, :].reshape(-1)[:n_b]
                    off = 0
                    for i in plan.buckets[b]:
                        lshape, dtype = meta[i]
                        size = int(np.prod(lshape)) if lshape else 1
                        block = flat[off:off + size].reshape(lshape)
                        off += size
                        td = t_dims[i]
                        sl = [slice(None)] * block.ndim
                        if self.staged:
                            chunk = lshape[0]
                            sl[0] = slice(si * chunk, (si + 1) * chunk)
                        if t_ax > 1 and td is not None:
                            m = block.shape[td]
                            sl[td] = slice(ti * m, (ti + 1) * m)
                        full[i][tuple(sl)] = block
        full = [f.astype(meta[i][1]) for i, f in enumerate(full)]
        return jax.tree.unflatten(treedef, full)

    # -------------------------------------------------------------- specs
    def _param_spec(self, t_dim, local_ndim: int):
        """PartitionSpec of one stacked leaf: layer dim over the stage
        axis + the role dim over the tensor axis, replicated over data
        (local and global rank agree — stage/tensor divide dims)."""
        if not self.staged:
            return P()
        axes: List[Optional[str]] = [None] * local_ndim
        axes[0] = STAGE
        if t_dim is not None and self.cfg.mesh.tensor > 1:
            axes[t_dim] = TENSOR
        return P(*axes)

    def _state_specs(self):
        plan, cfg = self.plan, self.cfg
        t_dims = plan.tensor_dims
        locals_ = jax.tree.leaves(plan.local_example)
        treedef = self._leaf_meta[0]
        p_specs = jax.tree.unflatten(
            treedef, [self._param_spec(td, lo.ndim)
                      for td, lo in zip(t_dims, locals_)])
        shard_spec = [P(DATA, STAGE, TENSOR) for _ in plan.order]
        if cfg.zero == 3:
            params_spec: Any = shard_spec
        else:
            params_spec = p_specs
        if cfg.optimizer == "adamw":
            if cfg.zero == 0:
                opt_spec: Any = {"m": p_specs, "v": p_specs, "t": P()}
            else:
                opt_spec = {"m": list(shard_spec), "v": list(shard_spec),
                            "t": P()}
        else:
            opt_spec = P()      # None pytree: placeholder spec
        ef_spec = (jax.tree.unflatten(
            treedef, [P(DATA, STAGE, TENSOR) for _ in locals_])
            if self._ef_active else P())
        return params_spec, opt_spec, ef_spec

    # ---------------------------------------------------------------- init
    def init(self, params) -> Dict[str, Any]:
        cfg = self.cfg
        plan = self._ensure_plan(params)
        # 1f1b interleaving holds params in virtual-stage row order for
        # the whole run (identity otherwise); finalize() restores it
        params = self._permute_stacked(params)
        st: Dict[str, Any] = dict(rng=jax.random.PRNGKey(cfg.seed), wire=0)
        D = cfg.mesh.data
        if cfg.sync in ASYNC_SYNCS:
            # async over the data axis: per-slot pulled copies of the
            # FULL stacked params (reference rebinds, like the flat
            # engines); EF state is per-slot over full leaves too, since
            # a slot's push is its assembled full gradient
            st.update(
                params=params, opt=None,
                ef=(jax.tree.map(
                    lambda x: jnp.zeros((D,) + x.shape, jnp.float32),
                    params) if self._ef_active else None),
                pulled=[params] * D, pulled_ver=[0] * D, server_ver=0,
                tick=0, updates=0, batch_idx=[0] * D,
                batch_cache=[None] * D, updates_base=0, step_base=0)
            return st
        if cfg.sync == "sma":
            st["replicas"] = jax.tree.map(
                lambda x: jnp.stack([x] * D), params)
            return st
        if cfg.zero == 3:
            st["params"] = [jnp.asarray(self._shard_array(params, b))
                            for b in plan.order]
        else:
            st["params"] = params
        if cfg.optimizer == "adamw":
            if cfg.zero == 0:
                st["opt"] = init_opt_state("adamw", params, cfg.moments)
            else:
                # one moment shard per bucket, in ISSUE order — aligned
                # with the p/g bucket lists the step function builds
                zeros = [jnp.zeros((cfg.mesh.data, cfg.mesh.stage,
                                    cfg.mesh.tensor,
                                    plan.shard_sizes[b]),
                                   jnp.dtype(cfg.moments))
                         for b in plan.order]
                st["opt"] = {"m": list(zeros),
                             "v": [jnp.zeros_like(z) for z in zeros],
                             "t": jnp.zeros((), jnp.int32)}
        else:
            st["opt"] = None
        if self._ef_active:
            d, t, s = cfg.mesh.data, cfg.mesh.tensor, cfg.mesh.stage
            st["ef"] = jax.tree.map(
                lambda lo: jnp.zeros((d, s, t) + lo.shape, jnp.float32),
                plan.local_example)
        else:
            st["ef"] = None
        return st

    # ---------------------------------------------------------------- step
    def _comm_plan(self) -> CommPlan:
        """The data-axis ``CommPlan`` over this device's local block
        structure — the same plan object (bucket fusion, issue order,
        codec, wire mode) the pure data-parallel engine executes."""
        cfg = self.cfg
        return CommPlan.plan(
            self.plan.local_example, axis=DATA, n=cfg.mesh.data,
            topology=cfg.topology, compressor=cfg.compressor,
            wire=cfg.wire, bucket_mb=cfg.bucket_mb, order=cfg.order,
            seed=cfg.seed, reduce_dtype=self._policy.reduce_dtype)

    def _measured_step_tx_bytes(self) -> int:
        """Shape-static measured bytes ONE device puts on the data axis
        per step, per bucket from the plan: z0 = the topology schedule;
        z1 = ring-allreduce grads + fp32 param all-gather; z2/z3 = the
        CommPlan ``ps`` accounting (RS grads + fp32 param all-gather)."""
        cfg, plan = self.cfg, self.plan
        d = cfg.mesh.data
        if d == 1:
            return 0
        comm = self._comm_plan()
        if cfg.zero == 0:
            return comm.measured_step_tx_bytes("allreduce")
        if cfg.zero >= 2:
            return comm.measured_step_tx_bytes("ps")
        # z1: compressed ring allreduce of grads + exact param all-gather
        codec = comm.codec if comm.in_schedule else make_codec("none")
        # bf16 reduce halves the exact grad words; params stay fp32
        scale = (comm.word_bytes / 4
                 if codec.exact and comm.word_bytes != 4 else 1.0)
        total = 0.0
        for b in plan.order:
            P = d * (-(-plan.bucket_sizes[b] // d))
            total += schedule_tx_bytes("ring", d, P, codec) * scale
            total += (d - 1) * 4 * (P // d)       # params travel exact
        return int(total)

    def _build_step(self):
        cfg, plan = self.cfg, self.plan
        model, grad_fn = self.model, self.grad_fn
        comp = cfg.compressor
        D, T, S = cfg.mesh.data, cfg.mesh.tensor, cfg.mesh.stage
        micro = plan.micro
        treedef, meta = self._leaf_meta
        sizes = [plan.bucket_sizes[b] for b in plan.order]
        comm = self._comm_plan()
        in_schedule = comm.in_schedule
        codec = codec_for(comp)
        gain = comp.ef_gain if comp.method == "onebit" else 1.0
        reduce0 = comm.reduce_grads if cfg.zero == 0 else None
        zero_update = (make_zero_bucket_update(
            plan, cfg.zero, cfg.optimizer, cfg.lr, axis=DATA,
            moment_dtype=cfg.moments)
            if cfg.zero else None)
        opt_step0 = (make_optimizer_step(cfg.optimizer, cfg.lr, cfg.moments)
                     if cfg.zero == 0 else None)
        tensor_axis = TENSOR if T > 1 else None
        policy = self._policy
        bf16_compute = policy.compute_dtype != "float32"
        bf16_reduce = policy.reduce_dtype != "float32"
        act_cell: List[int] = []

        def squeeze3(x):
            return x[0, 0, 0]

        def expand3(x):
            return jnp.expand_dims(x, (0, 1, 2))

        chunk = (jax.tree.leaves(plan.local_example)[0].shape[0]
                 if self.staged else 0)

        def local_params(pstate):
            if cfg.zero == 3:
                shards = [squeeze3(x) for x in pstate]
                out: List[Any] = [None] * len(meta)
                for shard, b, n_b in zip(shards, plan.order, sizes):
                    full = lax.all_gather(shard, DATA).reshape(-1)[:n_b]
                    _scatter_flat(full, plan.buckets[b],
                                  meta, out)
                return jax.tree.unflatten(treedef, out)
            return pstate

        def stage_call(sp, xx):
            # one stage device holds a contiguous chunk of layers
            for j in range(chunk):
                xx = model.stage_fn(jax.tree.map(lambda l: l[j], sp), xx,
                                    tensor_axis=tensor_axis)
            return xx

        cl = chunk // self._v if self.staged else 0

        def chunk_call(sp, xx):
            # one 1f1b virtual stage: the cl-layer chunk onefb_forward
            # sliced out of the device's (virtual-stage-ordered) block
            for j in range(cl):
                xx = model.stage_fn(jax.tree.map(lambda l: l[j], sp), xx,
                                    tensor_axis=tensor_axis)
            return xx

        def local_loss_and_grads(p_local, batch):
            if not self.staged:
                if not bf16_compute:
                    return grad_fn(p_local, batch)
                # bf16 compute, fp32 master weights: the cast transposes
                # cotangents back to fp32, and p_local stays the fp32
                # master copy the optimizer updates
                loss, grads = grad_fn(policy.cast_for_compute(p_local),
                                      batch)
                return loss, jax.tree.map(
                    lambda g: g.astype(jnp.float32), grads)

            def lloss(pl):
                if bf16_compute:
                    pl = policy.cast_for_compute(pl)
                x = model.inputs(batch)
                if bf16_compute:
                    x = x.astype(policy.cdt)
                bsz = x.shape[0]
                xm = x.reshape((micro, bsz // micro) + x.shape[1:])
                if not act_cell:
                    act_cell.append(int(np.prod(xm.shape[1:]))
                                    * int(jnp.dtype(xm.dtype).itemsize))
                if cfg.schedule == "1f1b":
                    outs = onefb_forward(chunk_call, pl, xm, STAGE,
                                         interleave=self._v)
                else:
                    outs = gpipe_forward(stage_call, pl, xm, STAGE)
                y = outs.reshape((bsz,) + x.shape[1:])
                loss = model.readout(y, batch).astype(jnp.float32)
                # only the last stage holds real outputs; the reduce
                # broadcasts its loss along the stage axis with identity
                # transpose (each stage's masked loss gets the plain
                # cotangent — the pipeline backward itself flows through
                # the ppermute chain inside the schedule)
                loss = jnp.where(lax.axis_index(STAGE) == S - 1, loss, 0.0)
                return tensor_reduce(STAGE)(loss)

            loss, grads = jax.value_and_grad(lloss)(p_local)
            return loss, grads

        def zero_buckets(pstate, opt, p_local):
            if cfg.zero == 3:
                p_buckets = [squeeze3(x) for x in pstate]
            else:
                p_leaves = jax.tree.leaves(p_local)
                p_buckets = [flatten_bucket(p_leaves, plan.buckets[b])
                             for b in plan.order]
            opt_l = opt
            if opt is not None:
                opt_l = {"m": [squeeze3(x) for x in opt["m"]],
                         "v": [squeeze3(x) for x in opt["v"]],
                         "t": opt["t"]}
            return p_buckets, opt_l

        def zero_unpack(new_buckets, opt_new, opt):
            if opt_new is not None:
                opt_new = {"m": [expand3(x) for x in opt_new["m"]],
                           "v": [expand3(x) for x in opt_new["v"]],
                           "t": opt_new["t"]}
            if cfg.zero == 3:
                p_out = [expand3(x) for x in new_buckets]
            else:
                out: List[Any] = [None] * len(meta)
                for flat, b in zip(new_buckets, plan.order):
                    _scatter_flat(flat, plan.buckets[b], meta, out)
                p_out = jax.tree.unflatten(treedef, out)
            return p_out, opt_new if opt is not None else opt

        def body(pstate, opt, ef, batch, key0):
            batch_l = jax.tree.map(lambda x: x[0], batch)
            p_local = local_params(pstate)
            loss, grads = local_loss_and_grads(p_local, batch_l)
            if bf16_reduce:
                # round the push to the bf16 wire words the measured
                # accounting counts (the exchange math re-widens to fp32)
                grads = policy.cast_for_reduce(grads)
            key = key0
            for ax in AXES:
                key = jax.random.fold_in(key, lax.axis_index(ax))
            sent = jnp.zeros((), jnp.int32)
            ef_l = jax.tree.map(squeeze3, ef) if ef is not None else None
            if in_schedule:
                # compressed payloads ride inside the data-axis schedule:
                # z0 through the CommPlan topology exchange, z1-z3 through
                # the compressed ring AR/RS of the ZeRO bucket update;
                # parameters always travel exact (docs/comm.md)
                if cfg.zero == 0:
                    avg, ef_new, sent = comm.exchange(grads, ef_l, key)
                    p_out, opt_new = opt_step0(p_local, avg, opt)
                    ef_out = (jax.tree.map(expand3, ef_new)
                              if ef_new is not None else ef)
                else:
                    g_leaves = jax.tree.leaves(grads)
                    if ef_l is not None:
                        e_leaves = jax.tree.leaves(ef_l)
                        cin = [g.astype(jnp.float32) + gain * e
                               for g, e in zip(g_leaves, e_leaves)]
                    else:
                        cin = g_leaves
                    g_buckets = [flatten_bucket(cin, plan.buckets[b])
                                 for b in plan.order]
                    p_buckets, opt_l = zero_buckets(pstate, opt, p_local)
                    resids: List[Any] = []
                    nz_acc: List[Any] = []
                    keybox = [key]

                    def grad_reduce(padded, _j):
                        keybox[0], sub = jax.random.split(keybox[0])
                        if cfg.zero == 1:
                            red, res, nz = compressed_allreduce(
                                padded, DATA, "ring", codec, sub)
                            shard = shard_of_flat(red, DATA)
                        else:
                            shard, res, nz = compressed_reduce_scatter(
                                padded, DATA, codec, sub)
                        resids.append(res)
                        nz_acc.append(nz)
                        return shard

                    new_buckets, opt_new = zero_update(
                        p_buckets, g_buckets, opt_l,
                        grad_reduce=grad_reduce)
                    sent = sum(nz_acc, sent)
                    p_out, opt_new = zero_unpack(new_buckets, opt_new, opt)
                    if ef_l is not None:
                        res_list: List[Any] = [None] * len(meta)
                        for res, b in zip(resids, plan.order):
                            _scatter_flat(res[:plan.bucket_sizes[b]],
                                          plan.buckets[b], meta, res_list)
                        res_tree = jax.tree.unflatten(treedef, res_list)
                        # telescoping EF: (g+e) - (g+gain*e) + hop residual
                        ef_new = jax.tree.map(
                            lambda e, r: (1.0 - gain) * e
                            + r.astype(jnp.float32), ef_l, res_tree)
                        ef_out = jax.tree.map(expand3, ef_new)
                    else:
                        ef_out = ef
            else:
                if comp.method != "none":
                    grads, ef_new, _wb = comp.roundtrip(grads, ef_l, key)
                    ef_out = (jax.tree.map(expand3, ef_new)
                              if ef_new is not None else ef)
                else:
                    ef_out = ef
                if cfg.zero == 0:
                    avg = reduce0(grads)
                    p_out, opt_new = opt_step0(p_local, avg, opt)
                else:
                    g_leaves = jax.tree.leaves(grads)
                    g_buckets = [flatten_bucket(g_leaves, plan.buckets[b])
                                 for b in plan.order]
                    p_buckets, opt_l = zero_buckets(pstate, opt, p_local)
                    new_buckets, opt_new = zero_update(p_buckets, g_buckets,
                                                       opt_l)
                    p_out, opt_new = zero_unpack(new_buckets, opt_new, opt)
            return p_out, opt_new, ef_out, loss[None], expand3(sent)

        params_spec, opt_spec, ef_spec = self._state_specs()
        fn = shard_map(
            body, mesh=self.mesh,
            in_specs=(params_spec, opt_spec, ef_spec, P(DATA), P()),
            out_specs=(params_spec, opt_spec, ef_spec, P(DATA),
                       P(DATA, STAGE, TENSOR)),
            check_vma=False)
        return jax.jit(fn), act_cell

    def _modeled_event_bytes(self) -> int:
        """The compressor's analytic per-device push accounting over the
        local block structure — recomputed from the plan (host side),
        never captured from a step-0 trace."""
        if self._dev_event_bytes is None:
            self._dev_event_bytes = modeled_event_bytes(
                self.cfg.compressor, self.plan.local_example)
        return self._dev_event_bytes

    def _step_bsp(self, st, batches, t):
        cfg = self.cfg
        if self._step_fn is None:
            self._step_fn, self._act_cell = self._build_step()
            self._measured_tx = self._measured_step_tx_bytes()
        D = cfg.mesh.data
        per = [batches(t, w) for w in range(D)]
        if self.staged and cfg.mesh.stage > 1:
            bsz = int(np.shape(self.model.inputs(per[0]))[0])
            if bsz % self.plan.micro:
                raise ValueError(
                    f"batch size {bsz} not divisible into "
                    f"{self.plan.micro} micro-batches")
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        st["rng"], sub = jax.random.split(st["rng"])
        rec = get_recorder()
        if rec.enabled:
            with rec.span("compute", pid="train", tid="loop", cat="train",
                          clock=("train_step", t), mesh=cfg.mesh.spec(),
                          zero=cfg.zero, fused=True):
                params, opt, ef, losses, sent = self._step_fn(
                    st["params"], st["opt"], st["ef"], batch, sub)
                jax.block_until_ready(losses)
        else:
            params, opt, ef, losses, sent = self._step_fn(
                st["params"], st["opt"], st["ef"], batch, sub)
        st.update(params=params, opt=opt, ef=ef)
        if rec.enabled:
            if D > 1 and cfg.zero == 0:
                # z0 runs the CommPlan schedule on the data axis; z1-3
                # exchange through the ZeRO shard path instead, which the
                # per-step byte accounting (not bucket spans) covers
                if self._trace_plan is None:
                    self._trace_plan = self._comm_plan()
                self._trace_plan.emit_trace(rec, arch="allreduce",
                                            clock=("train_step", t))
            if self.staged and cfg.mesh.stage > 1:
                emit_pipeline_trace(rec, cfg.mesh.stage, self.plan.micro,
                                    schedule=cfg.schedule,
                                    interleave=self._v,
                                    clock=("train_step", t))
        if cfg.wire == "measured":
            # per bucket from the plan, every step: static plane bytes of
            # the data-axis schedule on every device + dgc's traced
            # per-step sparse payload
            st["wire"] += self._measured_tx * cfg.mesh.size \
                + SPARSE_ELEM_BYTES * int(np.sum(np.asarray(sent)))
        else:
            st["wire"] += self._modeled_event_bytes() * cfg.mesh.size
        if rec.enabled:
            rec.counter("wire_bytes", {"cumulative": int(st["wire"])},
                        pid="train", cat="comm", clock=("train_step", t))
        ev = dict(step=t, loss=float(np.mean(np.asarray(losses))),
                  max_staleness=0)
        return st, [ev]

    def step(self, st, batches: Callable[[int, int], Any], t: int):
        sync = self.cfg.sync
        if sync == "bsp":
            st, ev = self._step_bsp(st, batches, t)
        elif sync == "ssp":
            st, ev = self._step_async(st, batches, t, self.cfg.staleness)
        elif sync == "asp":
            st, ev = self._step_async(st, batches, t, None)
        else:
            st, ev = self._step_sma(st, batches, t)
        self._wire_total = st["wire"]
        return st, ev

    def finalize(self, st):
        if self.cfg.sync == "sma":
            return jax.tree.map(lambda x: jnp.mean(x, axis=0),
                                st["replicas"])
        if self.cfg.zero == 3:
            full = self._materialize_params(
                [np.asarray(x) for x in st["params"]])
            return self._permute_stacked(full, inverse=True)
        return self._permute_stacked(st["params"], inverse=True)

    def wire_bytes(self) -> int:
        return self._wire_total

    # -------------------------------------- async / sma over the data axis
    def effective_periods(self) -> Tuple[int, ...]:
        """Per data-slot speed schedule with straggler slowdowns folded
        in — the same rule as ``ElasticWorkerSet.effective_periods``."""
        return tuple(max(1, int(round(p * s)))
                     for p, s in zip(self.periods, self.slowdowns))

    def _slice_blocks(self, pl, t_idx):
        """This tensor rank's (stage=1) parameter blocks of the full
        stacked leaves — dynamic role-dim slices per the mesh plan."""
        plan, T = self.plan, self.cfg.mesh.tensor
        leaves = jax.tree.leaves(pl)
        locals_ = jax.tree.leaves(plan.local_example)
        out = []
        for leaf, t_dim, lo in zip(leaves, plan.tensor_dims, locals_):
            if T > 1 and t_dim is not None:
                m = lo.shape[t_dim]
                starts = [0] * leaf.ndim
                starts[t_dim] = t_idx * m
                leaf = lax.dynamic_slice(leaf, starts, lo.shape)
            out.append(leaf)
        return jax.tree.unflatten(self._leaf_meta[0], out)

    def _slot_loss_and_grads(self, pulled, batch):
        """Per data-slot loss/grads of the staged model at stage=1:
        tensor-sharded compute inside the slot, full gradients assembled
        with a tensor-axis psum (outside AD)."""
        model, T = self.model, self.cfg.mesh.tensor
        t_idx = lax.axis_index(TENSOR)
        chunk = jax.tree.leaves(self.plan.local_example)[0].shape[0]
        tensor_axis = TENSOR if T > 1 else None

        def lloss(pl):
            blocks = self._slice_blocks(pl, t_idx)
            xx = model.inputs(batch)
            for j in range(chunk):
                xx = model.stage_fn(
                    jax.tree.map(lambda l: l[j], blocks), xx,
                    tensor_axis=tensor_axis)
            return model.readout(xx, batch)

        loss, g = jax.value_and_grad(lloss)(pulled)
        if T > 1:
            # each rank's cotangent covers only its role-dim block; the
            # psum assembles the full gradient, replicated over tensor
            g = jax.tree.map(lambda x: lax.psum(x, TENSOR), g)
        return loss, g

    def _build_async_fns(self):
        cfg = self.cfg
        comp = cfg.compressor

        def grad_body(pulled, ef, batch, key, fire):
            pulled = jax.tree.map(lambda x: x[0], pulled)
            batch = jax.tree.map(lambda x: x[0], batch)
            key = key[0]
            fire = fire[0]
            loss, g = self._slot_loss_and_grads(pulled, batch)
            if comp.method != "none":
                ef_w = (jax.tree.map(lambda x: x[0], ef)
                        if ef is not None else None)
                g, ef_new, _wb = comp.roundtrip(g, ef_w, key)
                if ef_new is not None:
                    ef_out = jax.tree.map(
                        lambda new, old: jnp.where(fire > 0, new, old),
                        ef_new, ef_w)
                    ef_out = jax.tree.map(lambda x: x[None], ef_out)
                else:
                    ef_out = ef
            else:
                ef_out = ef
            g = jax.tree.map(lambda x: x[None], g)
            return loss[None], g, ef_out

        ef_spec = P(DATA) if self._ef_active else P()
        return jax.jit(shard_map(
            grad_body, mesh=self.mesh,
            in_specs=(P(DATA), ef_spec, P(DATA), P(DATA), P(DATA)),
            out_specs=(P(DATA), P(DATA), ef_spec),
            check_vma=False))

    def _full_param_event_bytes(self, params_like) -> int:
        """Per-event modeled bytes of one slot's push: the compressor's
        accounting over the FULL stacked leaves — exactly what the
        simulator reports for the same spec, so async hybrid wire
        accounting cross-validates."""
        return modeled_event_bytes(self.cfg.compressor, params_like)

    def _step_async(self, st, batches, t, bound: Optional[int]):
        cfg = self.cfg
        if self._async_fns is None:
            self._async_fns = self._build_async_fns()
            self._event_wire = self._full_param_event_bytes(st["params"])
        return async_replay_step(
            st, batches, t, bound, K=cfg.mesh.data,
            compressor=cfg.compressor, grad_fn=self._async_fns,
            apply_fn=self._apply, ps_apply=None, lr=cfg.lr,
            event_wire=self._event_wire,
            eff_periods=self.effective_periods())

    def _build_sma(self):
        cfg = self.cfg

        def sma_body(replicas, batch):
            r = jax.tree.map(lambda x: x[0], replicas)
            batch = jax.tree.map(lambda x: x[0], batch)
            loss, g = self._slot_loss_and_grads(r, batch)
            center = jax.tree.map(lambda x: lax.pmean(x, DATA), r)
            mu = cfg.sma_mu
            new_r = jax.tree.map(
                lambda rr, zz, gg: rr - cfg.lr * gg - mu * (rr - zz),
                r, center, g)
            return (jax.tree.map(lambda x: x[None], new_r), loss[None])

        return jax.jit(shard_map(
            sma_body, mesh=self.mesh,
            in_specs=(P(DATA), P(DATA)),
            out_specs=(P(DATA), P(DATA)),
            check_vma=False))

    def _step_sma(self, st, batches, t):
        cfg = self.cfg
        D = cfg.mesh.data
        if self._sma_fn is None:
            self._sma_fn = self._build_sma()
            self._event_wire = self._full_param_event_bytes(
                jax.tree.map(lambda x: x[0], st["replicas"]))
        per = [batches(t, w) for w in range(D)]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        st["replicas"], losses = self._sma_fn(st["replicas"], batch)
        st["wire"] += self._event_wire * D
        ev = dict(step=t, loss=float(np.mean(np.asarray(losses))),
                  max_staleness=0)
        return st, [ev]

    # ------------------------------------------------------------- metrics
    def per_device_state_bytes(self, st) -> Dict[str, int]:
        """Measured persistent bytes per device, from the actual state
        arrays divided by their sharding factor — what docs/hybrid.md's
        memory math predicts and the ZeRO acceptance test asserts on."""
        cfg = self.cfg
        D, T, S = cfg.mesh.data, cfg.mesh.tensor, cfg.mesh.stage
        stacked_div = (S * T) if self.staged else 1
        shard_div = D * S * T
        out = {"params": 0, "opt": 0, "ef": 0}
        if cfg.sync == "sma":
            out["params"] = sum(np.asarray(x).nbytes // (D * stacked_div)
                                for x in jax.tree.leaves(st["replicas"]))
            out["total"] = out["params"]
            return out
        if cfg.zero == 3:
            out["params"] = sum(np.asarray(x).nbytes // shard_div
                                for x in st["params"])
        else:
            out["params"] = sum(np.asarray(x).nbytes // stacked_div
                                for x in jax.tree.leaves(st["params"]))
        if st["opt"] is not None:
            for k in ("m", "v"):
                leaves = jax.tree.leaves(st["opt"][k])
                div = stacked_div if cfg.zero == 0 else shard_div
                out["opt"] += sum(np.asarray(x).nbytes // div
                                  for x in leaves)
            out["opt"] += 4
        if st["ef"] is not None:
            out["ef"] = sum(np.asarray(x).nbytes // shard_div
                            for x in jax.tree.leaves(st["ef"]))
        out["total"] = out["params"] + out["opt"]
        return out

    def extra_metrics(self) -> Dict[str, Any]:
        cfg, plan = self.cfg, self.plan
        m: Dict[str, Any] = dict(
            mesh=cfg.mesh.spec(), zero=cfg.zero, optimizer=cfg.optimizer,
            wire_mode=cfg.wire)
        if cfg.schedule != "gpipe":
            m["schedule"] = cfg.schedule
            m["interleave"] = self._v
        if cfg.precision != "fp32":
            m["precision"] = cfg.precision
        if cfg.moments != "float32":
            m["moments"] = cfg.moments
        if plan is not None and cfg.sync == "bsp":
            m["modeled_data_bytes_per_dev"] = wire_bytes_per_device(
                plan, cfg.zero, grad_bytes=self._modeled_event_bytes())
            m["analytic_state_bytes"] = state_bytes_per_device(
                plan, cfg.zero, cfg.optimizer, cfg.moments)
            if self._measured_tx is not None:
                m["measured_step_tx_bytes"] = self._measured_tx
            if self._act_cell and cfg.mesh.stage > 1:
                if cfg.schedule == "1f1b":
                    ticks = onefb_ticks(cfg.mesh.stage, plan.micro, self._v)
                else:
                    ticks = gpipe_ticks(cfg.mesh.stage, plan.micro)
                m["modeled_pipeline_bytes_per_dev"] = \
                    self._act_cell[0] * ticks
                if cfg.mesh.tensor > 1:
                    t = cfg.mesh.tensor
                    m["modeled_tensor_bytes_per_dev"] = int(
                        self._act_cell[0] * ticks * 2 * (t - 1) / t)
        return m

    # --------------------------------------------------- elastic interface
    def set_slowdown(self, worker: int, factor: float):
        """Record a straggler event.  Plan worker ids are flat device
        indices; a device's slowdown is recorded against its data slot
        (devices are data-major, so slot = id // (t*s)).  The hybrid step
        is a single fused BSP program — there is no backup-drop path to
        feed — so the record only affects reshard bookkeeping."""
        ts = self.cfg.mesh.tensor * self.cfg.mesh.stage
        slot = worker // ts
        if not 0 <= slot < self.cfg.mesh.data:
            raise ValueError(f"worker {worker} out of range for mesh "
                             f"{self.cfg.mesh.spec()}")
        self.slowdowns[slot] = factor

    def crash_plan(self, worker: int) -> Tuple[int, Tuple[int, ...]]:
        """What losing device ``worker`` means for this mesh: its whole
        tensor × stage block (the model-parallel replica of one data
        slot) goes with it, so the run reshards to one fewer data
        replica.  The elastic trainer consults this instead of assuming
        flat worker = device - 1 semantics."""
        cfg = self.cfg
        if not 0 <= worker < cfg.mesh.size:
            raise ValueError(f"worker {worker} out of range for mesh "
                             f"{cfg.mesh.spec()}")
        ts = cfg.mesh.tensor * cfg.mesh.stage
        if cfg.mesh.data <= 1:
            raise ValueError(
                f"mesh {cfg.mesh.spec()} has a single data replica; "
                "losing a device leaves nothing to reshard to")
        return cfg.mesh.size - ts, (worker // ts,)

    def reshard(self, st, new_workers: int, step: int = 0,
                lost: Tuple[int, ...] = ()):
        """Resize the mesh to ``new_workers`` total devices by rebuilding
        the *data* axis (tensor × stage geometry is a property of the
        model and survives).  ZeRO shards are re-cut over the new data
        axis; survivor data slots keep their EF residuals."""
        cfg, plan = self.cfg, self.plan
        if cfg.sync != "bsp":
            raise ValueError(
                f"sync={cfg.sync!r} hybrid cells do not reshard yet "
                "(async/sma over a mesh is a fixed-geometry run)")
        ts = cfg.mesh.tensor * cfg.mesh.stage
        if new_workers < ts or new_workers % ts:
            raise ValueError(
                f"resize to {new_workers} devices does not factor over the "
                f"tensor*stage block of {ts} (mesh {cfg.mesh.spec()}); "
                "hybrid meshes resize along the data axis only")
        new_d = new_workers // ts
        if new_workers > len(self._devs):
            raise ValueError(
                f"resize to {new_workers} devices: have {len(self._devs)}")
        bad = [w for w in lost if w < 0 or w >= cfg.mesh.data]
        if bad:
            raise ValueError(f"lost data slots {bad} out of range for "
                             f"data axis {cfg.mesh.data}")
        survivors = [w for w in range(cfg.mesh.data) if w not in set(lost)]
        slots = survivors[:new_d]
        grown = new_d - len(slots)
        st = {k: (jax.device_get(v) if k not in ("wire",) else v)
              for k, v in st.items()}
        # re-cut the flat data-axis shards (params for z3, moments for z1+)
        old_plan = plan

        def recut(arrs: List[np.ndarray]) -> List[np.ndarray]:
            out = []
            for arr, b in zip(arrs, old_plan.order):
                arr = np.asarray(arr)
                n_b = old_plan.bucket_sizes[b]
                m_new = -(-n_b // new_d)
                _, S, T, _ = arr.shape
                new = np.zeros((new_d, S, T, m_new), arr.dtype)
                for si in range(S):
                    for ti in range(T):
                        flat = arr[:, si, ti, :].reshape(-1)[:n_b]
                        new[:, si, ti, :] = np.pad(
                            flat, (0, new_d * m_new - n_b)).reshape(
                                new_d, m_new)
                out.append(new)
            return out

        if cfg.zero == 3:
            st["params"] = recut(st["params"])
        if st["opt"] is not None and cfg.zero >= 1:
            st["opt"] = {"m": recut(st["opt"]["m"]),
                         "v": recut(st["opt"]["v"]), "t": st["opt"]["t"]}
        if st["ef"] is not None:
            def remap_rows(x):
                x = np.asarray(x)
                rows = ([x[s] for s in slots]
                        + [np.zeros_like(x[0])] * grown)
                return np.stack(rows)
            st["ef"] = jax.tree.map(remap_rows, st["ef"])
        new_mesh = MeshSpec(new_d, cfg.mesh.tensor, cfg.mesh.stage)
        self.cfg = cfg = dataclasses.replace(cfg, mesh=new_mesh)
        self.mesh = make_hybrid_mesh(self._devs, new_d, cfg.mesh.tensor,
                                     cfg.mesh.stage)
        self.slowdowns = [self.slowdowns[s] for s in slots] + [1.0] * grown
        # the bucket identity is a function of the local block structure
        # and survives; only the per-rank shard length changes
        self.plan = dataclasses.replace(
            old_plan, mesh=new_mesh,
            shard_sizes=[-(-n // new_d) for n in old_plan.bucket_sizes])
        self.periods = tuple(default_periods(new_d))
        self._step_fn, self._async_fns, self._sma_fn = None, None, None
        self._act_cell = []
        self._dev_event_bytes, self._measured_tx = None, None
        self._trace_plan = None
        return st

    def export_state(self, st) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        cfg = self.cfg
        if cfg.sync != "bsp":
            raise ValueError(
                f"sync={cfg.sync!r} hybrid cells do not snapshot yet; "
                "use the flat DeviceEngine (trivial mesh) for elastic "
                "async runs")
        arrays = {"params": st["params"], "opt": st["opt"], "ef": st["ef"],
                  "rng": st["rng"]}
        meta = dict(backend="hybrid", mesh=cfg.mesh.spec(), zero=cfg.zero,
                    optimizer=cfg.optimizer, num_workers=cfg.mesh.size,
                    wire=int(st["wire"]), slowdowns=list(self.slowdowns),
                    schedule=cfg.schedule, interleave=self._v,
                    precision=cfg.precision, moments=cfg.moments)
        return arrays, meta

    def import_state(self, arrays: Dict[str, Any], meta: Dict[str, Any]):
        cfg = self.cfg
        if meta["num_workers"] != cfg.mesh.size:
            raise ValueError(
                f"snapshot has {meta['num_workers']} devices, engine has "
                f"{cfg.mesh.size}; reshard the engine first")
        if meta["mesh"] != cfg.mesh.spec() or meta["zero"] != cfg.zero \
                or meta["optimizer"] != cfg.optimizer:
            raise ValueError(
                f"snapshot geometry {meta['mesh']}/z{meta['zero']}/"
                f"{meta['optimizer']} does not match engine "
                f"{cfg.mesh.spec()}/z{cfg.zero}/{cfg.optimizer}")
        # schedule/precision change the on-disk layout (virtual-stage row
        # order, moment dtype); pre-existing snapshots default to gpipe/fp32
        snap = (meta.get("schedule", "gpipe"), meta.get("interleave", 1),
                meta.get("precision", "fp32"), meta.get("moments", "float32"))
        mine = (cfg.schedule, self._v, cfg.precision, cfg.moments)
        if snap != mine:
            raise ValueError(
                f"snapshot schedule/precision {snap} does not match "
                f"engine {mine}")
        self.slowdowns = [float(s) for s in meta["slowdowns"]]
        st = dict(params=arrays["params"], opt=arrays["opt"],
                  ef=arrays["ef"], rng=jnp.asarray(arrays["rng"]),
                  wire=int(meta["wire"]))
        self._wire_total = st["wire"]
        return st

    # ------------------------------------------------------------------ run
    def run(self, params, batches: Callable[[int, int], Any], steps: int):
        st = self.init(params)
        hist: List[dict] = []
        rec = get_recorder()
        for t in range(steps):
            # same step spans train_loop emits for the flat engines, so
            # hybrid traces feed obs.analyze.step_attribution too
            if rec.enabled:
                with rec.span("step", pid="train", tid="loop", cat="train",
                              clock=("train_step", t), step=t):
                    st, ev = self.step(st, batches, t)
            else:
                st, ev = self.step(st, batches, t)
            hist.extend(ev)
        return self.finalize(st), hist, st["wire"]
