"""Multi-axis device meshes as a declarative Strategy dimension.

The survey's §3.2 parallelization taxonomy — data-, model- (tensor-), and
pipeline-parallelism — becomes a *mesh suffix* on the Strategy spec
string::

    bsp/ring/onebit@8:d2.t2.s2      8 devices as data=2 x tensor=2 x stage=2
    bsp/ps/none@4:d4.z3.adamw       4-way data parallel, ZeRO-3 AdamW

Suffix grammar (order-insensitive dot-separated tokens, ``parse_suffix``
and ``suffix_spec`` are inverses)::

    token := "d" N   data-parallel replicas        (default 1)
           | "t" N   tensor-parallel shards        (default 1)
           | "s" N   pipeline stages               (default 1)
           | "z" L   ZeRO optimizer-state level    (0..3, default 0)
           | "m" K   pipeline micro-batches        (default 2*stages)
           | "sgd" | "adamw"                       (optimizer, default sgd)
           | "gpipe" | "1f1b"                      (pipeline schedule,
                                                    default gpipe)
           | "v" K   1f1b interleave (virtual      (default 2 under 1f1b)
                     stages per device)
           | "fp32" | "bf16" | "bf16r"             (compute precision,
                                                    default fp32; bf16r
                                                    also reduces in bf16)
           | "qmom"                                (bf16 optimizer moments)

``MeshSpec`` is the axis geometry; ``MeshPlan`` (built by ``plan_mesh``)
is the *composition plan* the hybrid engine executes: per-leaf tensor
shard dimensions assigned by ``core/parallelism.py``'s role rules, the
per-device local block shapes, the data-axis fused-bucket plan shared
with ``core/comm_scheduler`` (the same plan the pure data-parallel engine
executes), and the ZeRO shard sizes over the data axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.comm_scheduler import LayerCost
from repro.core.parallelism import model_axis_dim

AXES = ("data", "tensor", "stage")

OPTIMIZERS = ("sgd", "adamw")

SCHEDULES = ("gpipe", "1f1b")

PRECISIONS = ("fp32", "bf16", "bf16r")


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Axis geometry of a hybrid mesh: ``size == data * tensor * stage``."""
    data: int = 1
    tensor: int = 1
    stage: int = 1

    def __post_init__(self):
        for name in ("data", "tensor", "stage"):
            if getattr(self, name) < 1:
                raise ValueError(f"mesh {name} axis must be >= 1")

    @property
    def size(self) -> int:
        return self.data * self.tensor * self.stage

    @property
    def is_trivial(self) -> bool:
        """True when the mesh is pure data parallelism (t == s == 1)."""
        return self.tensor == 1 and self.stage == 1

    def spec(self) -> str:
        return f"d{self.data}.t{self.tensor}.s{self.stage}"

    @classmethod
    def parse(cls, text: str) -> "MeshSpec":
        """Parse a pure axis spec (``d2.t2.s2``).  Non-geometry tokens
        (z/m/sgd/adamw) are rejected — silently dropping a ZeRO level
        from ``Strategy(mesh="d4.z3")`` would train un-sharded."""
        fields, named = parse_suffix(text)
        extras = [k for k in ("zero", "optimizer", "micro_batches",
                              "schedule", "interleave", "precision",
                              "moments") if named[k]]
        if extras:
            raise ValueError(
                f"mesh spec {text!r} carries non-axis tokens ({extras}); "
                "a mesh is dN.tN.sN only — pass zero/optimizer/"
                "micro_batches as Strategy fields, or use the full spec "
                "string suffix (Strategy.parse)")
        return fields["mesh"]


def parse_suffix(text: str) -> Tuple[Dict[str, Any], Dict[str, bool]]:
    """Parse a mesh suffix into Strategy fields.

    Returns ``(fields, named)``: ``fields`` has mesh/zero/optimizer/
    micro_batches/schedule/interleave/precision/moments defaults filled
    in, ``named`` records which were explicitly present (so Strategy
    keyword defaults do not clobber spec-named values and vice versa)."""
    axes = {"d": 1, "t": 1, "s": 1}
    zero, optimizer, micro = 0, "sgd", 0
    schedule, interleave, precision, moments = "gpipe", 0, "fp32", "float32"
    named = {"mesh": False, "zero": False, "optimizer": False,
             "micro_batches": False, "schedule": False, "interleave": False,
             "precision": False, "moments": False}
    # word tokens first: "1f1b"/"bf16" start with a digit/axis letter, so
    # they must be name-matched before the head-char dispatch below
    words = {tok: ("optimizer",) for tok in OPTIMIZERS}
    words.update({tok: ("schedule",) for tok in SCHEDULES})
    words.update({tok: ("precision",) for tok in PRECISIONS})
    words["qmom"] = ("moments",)
    seen = set()
    for tok in text.split("."):
        tok = tok.strip()
        if not tok:
            raise ValueError(f"bad mesh suffix {text!r}: empty token")
        # all names of one dimension share one slot — "sgd.adamw" (or
        # "gpipe.1f1b") is a contradiction, not a last-wins override
        key = words[tok][0] if tok in words else tok[0]
        if key in seen:
            raise ValueError(f"bad mesh suffix {text!r}: duplicate {key!r}")
        if tok in words:
            seen.add(key)
            named[key] = True
            if key == "optimizer":
                optimizer = tok
            elif key == "schedule":
                schedule = tok
            elif key == "precision":
                precision = tok
            else:                       # qmom
                moments = "bfloat16"
            continue
        head, val = tok[0], tok[1:]
        if head not in ("d", "t", "s", "z", "m", "v") or not val.isdigit():
            raise ValueError(
                f"bad mesh suffix {text!r}: token {tok!r} (want dN/tN/sN/"
                f"zL/mK/vK/sgd/adamw/gpipe/1f1b/fp32/bf16/bf16r/qmom)")
        seen.add(head)
        if head in axes:
            axes[head], named["mesh"] = int(val), True
        elif head == "z":
            zero, named["zero"] = int(val), True
        elif head == "v":
            interleave, named["interleave"] = int(val), True
        else:
            micro, named["micro_batches"] = int(val), True
    fields = dict(mesh=MeshSpec(axes["d"], axes["t"], axes["s"]),
                  zero=zero, optimizer=optimizer, micro_batches=micro,
                  schedule=schedule, interleave=interleave,
                  precision=precision, moments=moments)
    return fields, named


def suffix_spec(mesh: MeshSpec, zero: int = 0, optimizer: str = "sgd",
                micro_batches: int = 0, schedule: str = "gpipe",
                interleave: int = 0, precision: str = "fp32",
                moments: str = "float32") -> str:
    """Canonical mesh suffix (inverse of ``parse_suffix``); empty string
    when every dimension is at its default."""
    parts: List[str] = []
    if not mesh.is_trivial:
        parts.append(mesh.spec())
    if zero:
        parts.append(f"z{zero}")
    if micro_batches:
        parts.append(f"m{micro_batches}")
    if schedule != "gpipe":
        parts.append(schedule)
    if interleave:
        parts.append(f"v{interleave}")
    if precision != "fp32":
        parts.append(precision)
    if moments != "float32":
        parts.append("qmom")
    if optimizer != "sgd":
        parts.append(optimizer)
    return ".".join(parts)


# ------------------------------------------------------------------ planning
@dataclasses.dataclass
class MeshPlan:
    """The executable composition plan for one mesh:

    - ``tensor_dims``: per (stacked) leaf — a flat list aligned with
      ``jax.tree.leaves`` order — the dimension index sharded over the
      tensor axis (``core/parallelism.py`` role rules), or None.
    - ``local_example``: per-device block shapes (stage-sliced,
      tensor-sliced) — the structure gradients/EF state take on a device.
    - ``buckets``/``order``/``fused``: the data-axis fused-bucket plan and
      issue order (same planner as the pure data-parallel engine).
    - ``bucket_sizes``/``shard_sizes``: per-bucket flat length and padded
      per-data-rank ZeRO shard length.
    - ``micro``: pipeline micro-batches per step.
    """
    mesh: MeshSpec
    staged: bool
    tensor_dims: List[Optional[int]]    # flat, tree_leaves order
    local_example: Any                  # pytree of np zeros (block shapes)
    buckets: List[List[int]]
    order: List[int]
    fused: List[LayerCost]
    bucket_sizes: List[int]
    shard_sizes: List[int]
    micro: int

    @property
    def n_local_params(self) -> int:
        return sum(int(x.size) for x in jax.tree.leaves(self.local_example))


def _local_block_shape(shape: Tuple[int, ...], staged: bool,
                       mesh: MeshSpec, t_dim: Optional[int],
                       name: str) -> Tuple[int, ...]:
    """Per-device block shape of one (stacked) leaf: the leading layer
    dim is divided over the stage axis (each stage device holds a
    contiguous chunk of layers), the tensor role dim over the tensor
    axis."""
    if staged:
        if not shape or shape[0] < mesh.stage or shape[0] % mesh.stage:
            raise ValueError(
                f"staged leaf {name!r} has {shape[0] if shape else 0} "
                f"stacked layers; the stage axis ({mesh.stage}) must "
                f"divide the layer count")
        shape = (shape[0] // mesh.stage,) + shape[1:]
    if mesh.tensor > 1:
        if t_dim is None:
            raise ValueError(
                f"leaf {name!r} has no model-parallel dimension under the "
                f"role rules of core/parallelism.py; a tensor axis of "
                f"{mesh.tensor} needs every leaf to be shardable")
        if shape[t_dim] % mesh.tensor:
            raise ValueError(
                f"leaf {name!r} dim {t_dim} ({shape[t_dim]}) not divisible "
                f"by tensor axis {mesh.tensor}")
        shape = tuple(n // mesh.tensor if i == t_dim else n
                      for i, n in enumerate(shape))
    return shape


def plan_mesh(params, mesh: MeshSpec, *, staged: bool,
              bucket_mb: float = 4.0, order: str = "tictac",
              micro_batches: int = 0, back_s_per_byte: float = 2e-12,
              seed: int = 0) -> MeshPlan:
    """Build the MeshPlan for ``params`` (stacked per-stage leaves when
    ``staged``).  Pure planning — no device state is touched."""
    # imported here: train.data_parallel imports nothing from this package,
    # so the shared bucket planner stays the single source of truth
    from repro.train.data_parallel import _plan_buckets

    flat, _ = jax.tree_util.tree_flatten_with_path(params)

    def leaf_tensor_dim(path, leaf):
        ndim = np.ndim(leaf)
        if staged:       # classify without the leading stacked-stage dim
            td = model_axis_dim(path, ndim - 1)
            return None if td is None else td + 1
        return model_axis_dim(path, ndim)

    t_dims = [leaf_tensor_dim(path, leaf) for path, leaf in flat]
    if staged:
        heads = {int(np.shape(leaf)[0]) if np.shape(leaf) else 0
                 for _, leaf in flat}
        if len(heads) != 1:
            raise ValueError(
                f"staged leaves disagree on the stacked layer count "
                f"({sorted(heads)}); every leaf needs the same leading "
                "layer dim")
    locals_ = [np.zeros(_local_block_shape(tuple(np.shape(leaf)), staged,
                                           mesh, td, jax.tree_util.keystr(p)),
                        np.float32)
               for (p, leaf), td in zip(flat, t_dims)]
    treedef = jax.tree.structure(params)
    local_example = jax.tree.unflatten(treedef, locals_)
    buckets, order_idx, fused = _plan_buckets(
        local_example, bucket_mb, order, back_s_per_byte, seed)
    sizes = [int(x.size) for x in locals_]
    bucket_sizes = [sum(sizes[i] for i in b) for b in buckets]
    shard_sizes = [-(-n // mesh.data) for n in bucket_sizes]
    micro = micro_batches or (2 * mesh.stage if mesh.stage > 1 else 1)
    return MeshPlan(mesh=mesh, staged=staged, tensor_dims=t_dims,
                    local_example=local_example, buckets=buckets,
                    order=order_idx, fused=fused, bucket_sizes=bucket_sizes,
                    shard_sizes=shard_sizes, micro=micro)
