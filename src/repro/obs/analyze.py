"""Trace analytics: turning recorded Chrome traces into answers
(docs/observability.md, "Analysis & SLOs").

The recorder (obs/trace.py) writes events; this module reads them back
and produces the three accountings every perf conversation needs:

  * ``step_attribution``  — where each training step's time went:
    compute vs comm vs snapshot vs stall, from the ``train/loop`` step
    spans, the ``compute`` spans inside them, the CommPlan ``exchange``
    spans, and the ``elastic/events`` snapshot spans.
  * ``overlap_efficiency`` — achieved bucket-issue concurrency relative
    to the two modeled bounds CommPlan stamps on every exchange span
    (``modeled_no_overlap_us`` / ``modeled_tictac_overlap_us``).
  * ``pipeline_accounting`` — measured GPipe bubble fraction per step
    from the per-stage/per-tick spans ``parallel/engine.py`` emits,
    against the analytic ``(s-1)/(m+s-1)``.

plus the serve-side extraction (``request_latencies``) the SLO monitor
(obs/slo.py) evaluates.  Everything here is stdlib-only, pure host-side,
and operates on the *serialized* trace dict — the same object
``load_trace`` returns — so analysis works equally on live recorders
(``rec.to_chrome()``) and files written months ago.

Durations use the ``wall_s`` args when the trace carries them (the
normal case) and fall back to the deterministic virtual-tick extent for
wall-stripped traces; every result records which ``basis`` it used.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

# attribution taxonomy (docs/observability.md): every step-window second
# lands in exactly one of these buckets, stall being the residual
ATTRIBUTION_CATEGORIES = ("compute", "comm", "snapshot", "stall")


# ------------------------------------------------------- event access
def resolve_events(trace: dict) -> List[dict]:
    """The trace's non-metadata events with pid/tid resolved back to the
    *names* the recorder used (``M`` metadata carries them; serialized
    pids/tids are integers).  Raw recorder dicts whose pids are already
    names pass through unchanged."""
    pmap: Dict[Any, str] = {}
    tmap: Dict[Tuple[Any, Any], str] = {}
    events = trace.get("traceEvents", [])
    for ev in events:
        if ev.get("ph") != "M":
            continue
        if ev.get("name") == "process_name":
            pmap[ev.get("pid")] = ev.get("args", {}).get("name")
        elif ev.get("name") == "thread_name":
            tmap[(ev.get("pid"), ev.get("tid"))] = \
                ev.get("args", {}).get("name")
    out = []
    for ev in events:
        if ev.get("ph") == "M":
            continue
        pid, tid = ev.get("pid"), ev.get("tid")
        out.append(dict(ev, pid=pmap.get(pid, pid),
                        tid=tmap.get((pid, tid), tid)))
    return out


def paired_spans(trace: dict) -> List[dict]:
    """B/E pairs as span records, sorted by begin tick.  Each record
    carries both clocks (``ts0/ts1`` ticks, ``wall0/wall1`` seconds when
    present), the begin args, the end args, and the nesting ``depth``.
    Unmatched events are skipped — ``validate_trace(strict=False)`` is
    the tool that *reports* them."""
    stacks: Dict[Tuple[Any, Any], List[dict]] = {}
    spans: List[dict] = []
    for ev in resolve_events(trace):
        ph = ev.get("ph")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks.setdefault(key, []).append(ev)
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                continue
            b = stack.pop()
            bargs, eargs = b.get("args", {}), ev.get("args", {})
            spans.append(dict(
                name=b.get("name"), pid=ev.get("pid"), tid=ev.get("tid"),
                depth=len(stack), ts0=b.get("ts"), ts1=ev.get("ts"),
                wall0=bargs.get("wall_s"), wall1=eargs.get("wall_s"),
                args=bargs, end_args=eargs))
    spans.sort(key=lambda s: (s["ts0"] is None, s["ts0"]))
    return spans


def find_instants(trace: dict, name: Optional[str] = None) -> List[dict]:
    return [ev for ev in resolve_events(trace) if ev.get("ph") == "i"
            and (name is None or ev.get("name") == name)]


def find_counters(trace: dict, name: str) -> List[dict]:
    return [ev for ev in resolve_events(trace)
            if ev.get("ph") == "C" and ev.get("name") == name]


def _has_wall(span: dict) -> bool:
    return span.get("wall0") is not None and span.get("wall1") is not None


def _edges(span: dict, basis: str) -> Tuple[float, float]:
    if basis == "wall":
        return float(span["wall0"]), float(span["wall1"])
    return float(span["ts0"]), float(span["ts1"])


def _clipped(spans: Sequence[dict], lo: float, hi: float,
             basis: str) -> float:
    """Total duration of ``spans`` clipped to the window [lo, hi]."""
    total = 0.0
    for s in spans:
        a, b = _edges(s, basis)
        total += max(0.0, min(b, hi) - max(a, lo))
    return total


# --------------------------------------------------- step attribution
def step_attribution(trace: dict, basis: str = "auto") -> Optional[dict]:
    """Per-step time attribution over the ``train/loop`` step spans.

    Each step's accounting **window** runs from the previous step's end
    to this step's end (the first step starts at its own begin), so
    between-step host work — snapshot commits, batch assembly — is
    charged to the step that waited for it.  Within the window:

      compute   ``compute`` spans on the train track (fused dispatch)
      comm      ``exchange`` spans (the CommPlan bucket schedule)
      snapshot  ``snapshot`` spans from the elastic track
      stall     the unattributed residual (host glue, data, dispatch)

    ``attributed_pct`` is 100 * (compute+comm+snapshot+stall) / window —
    above 100 means double-counting (overlapping spans), the failure
    mode the >=95..105 acceptance band guards.  ``known_pct`` excludes
    the residual: how much of the window instrumented spans *explain*.
    Returns None when the trace has no step spans."""
    spans = paired_spans(trace)
    steps = [s for s in spans if s["name"] == "step"
             and s["pid"] == "train" and s["tid"] == "loop"]
    if not steps:
        return None
    if basis == "auto":
        basis = "wall" if all(_has_wall(s) for s in steps) else "ticks"
    train = [s for s in spans if s["pid"] == "train"]
    compute = [s for s in train if s["name"] == "compute"]
    comm = [s for s in train if s["name"] == "exchange"]
    snaps = [s for s in spans if s["name"] == "snapshot"]

    rows: List[dict] = []
    prev_end: Optional[float] = None
    for st in steps:
        t0, t1 = _edges(st, basis)
        w0 = prev_end if prev_end is not None else t0
        w0 = min(w0, t0)
        prev_end = t1
        total = t1 - w0
        parts = {
            "compute": _clipped(compute, w0, t1, basis),
            "comm": _clipped(comm, w0, t1, basis),
            "snapshot": _clipped(snaps, w0, t1, basis),
        }
        known = sum(parts.values())
        stall = max(0.0, total - known)
        row = dict(step=st["args"].get("clock_t", st["args"].get("step")),
                   total=total, span=t1 - t0, stall=stall, **parts)
        row["attributed_pct"] = (100.0 * (known + stall) / total
                                 if total > 0 else 100.0)
        row["known_pct"] = 100.0 * known / total if total > 0 else 0.0
        rows.append(row)

    totals = {k: sum(r[k] for r in rows)
              for k in ATTRIBUTION_CATEGORIES + ("total",)}
    grand = totals["total"] or 1.0
    return dict(
        basis=basis, steps=rows, totals=totals,
        fractions={k: totals[k] / grand for k in ATTRIBUTION_CATEGORIES},
        attributed_pct_min=min(r["attributed_pct"] for r in rows),
        attributed_pct_max=max(r["attributed_pct"] for r in rows),
        known_pct_mean=sum(r["known_pct"] for r in rows) / len(rows))


# -------------------------------------------------- overlap efficiency
def overlap_efficiency(trace: dict) -> Optional[dict]:
    """Achieved bucket-issue concurrency vs the modeled bounds CommPlan
    stamps on each ``exchange`` span: ``modeled_no_overlap_us`` (serial
    buckets) and ``modeled_tictac_overlap_us`` (TicTac-ordered overlap,
    the best this plan can do).  Efficiency 1.0 means the executed issue
    order achieves the TicTac bound; 0.0 means no overlap at all.
    Returns None when no exchange span carries the model args (traces
    recorded before PR 9)."""
    ex = [s for s in paired_spans(trace) if s["name"] == "exchange"
          and "modeled_no_overlap_us" in s["args"]]
    if not ex:
        return None
    rows = []
    for s in ex:
        a = s["args"]
        no = float(a["modeled_no_overlap_us"])
        tictac = float(a["modeled_tictac_overlap_us"])
        issue = float(a.get("modeled_issue_overlap_us", tictac))
        eps = 1e-6 * max(no, 1.0)
        denom = no - tictac
        rows.append(dict(
            step=a.get("clock_t"), no_overlap_us=no,
            tictac_overlap_us=tictac, issue_overlap_us=issue,
            n_buckets=a.get("n_buckets"),
            in_bounds=(tictac - eps <= issue <= no + eps),
            efficiency=((no - issue) / denom) if denom > eps else 1.0))
    return dict(
        exchanges=rows,
        all_in_bounds=all(r["in_bounds"] for r in rows),
        efficiency_mean=sum(r["efficiency"] for r in rows) / len(rows))


# ------------------------------------------------- pipeline accounting
def pipeline_accounting(trace: dict) -> Optional[dict]:
    """Measured GPipe bubble fraction from the per-stage/per-tick spans
    (``pipeline/stage<s>`` tracks, one span per tick named ``mb<k>`` or
    ``bubble``) against the analytic ``(s-1)/(m+s-1)`` each ``pipe``
    span carries.  Returns None when the trace has no pipeline spans."""
    spans = paired_spans(trace)
    pipes = [s for s in spans if s["name"] == "pipe"
             and s["pid"] == "pipeline"]
    if not pipes:
        return None
    cells = [s for s in spans if s["pid"] == "pipeline"
             and str(s["tid"]).startswith("stage")]
    rows = []
    for p in pipes:
        a = p["args"]
        inside = [c for c in cells
                  if p["ts0"] <= c["ts0"] and c["ts1"] <= p["ts1"]]
        bubble = sum(1 for c in inside if c["name"] == "bubble")
        active = sum(1 for c in inside if str(c["name"]).startswith("mb"))
        slots = bubble + active
        measured = bubble / slots if slots else 0.0
        analytic = float(a.get("analytic_bubble", 0.0))
        rows.append(dict(
            step=a.get("clock_t"), stages=a.get("stages"),
            micro=a.get("micro"), ticks=a.get("ticks"),
            bubble_ticks=bubble, active_ticks=active,
            measured_bubble=measured, analytic_bubble=analytic,
            rel_err=(abs(measured - analytic) / analytic
                     if analytic else abs(measured))))
    return dict(pipes=rows,
                rel_err_max=max(r["rel_err"] for r in rows),
                measured_bubble_mean=(sum(r["measured_bubble"]
                                          for r in rows) / len(rows)))


# ------------------------------------------------------ serve lifecycle
def request_latencies(trace: dict) -> List[dict]:
    """Per-request latency rows from the serve lifecycle tracks
    (``serve/req<rid>``): TTFT = decode-begin clock minus arrival, TPOT
    = decode clock extent per generated token after the first.  All on
    the deterministic ``serve_iter`` clock — the numbers obs/slo.py
    evaluates objectives over."""
    spans = [s for s in paired_spans(trace) if s["pid"] == "serve"
             and str(s["tid"]).startswith("req")]
    done = {ev["args"].get("rid"): ev["args"].get("clock_t")
            for ev in find_instants(trace, "done")}
    by_rid: Dict[Any, Dict[str, dict]] = {}
    for s in spans:
        rid = s["args"].get("rid")
        by_rid.setdefault(rid, {})[s["name"]] = s
    rows = []
    for rid in sorted(by_rid, key=lambda r: (r is None, r)):
        life = by_rid[rid]
        q, d = life.get("queued"), life.get("decode")
        if q is None or d is None:
            continue
        arrival = float(q["args"].get("arrival", 0.0))
        first_t = float(d["args"].get("clock_t", 0.0))
        generated = int(d["end_args"].get("generated", 1))
        finish_t = float(done.get(rid, first_t))
        rows.append(dict(
            rid=rid, arrival=arrival, first_token_t=first_t,
            finish_t=finish_t, generated=generated,
            ttft=first_t - arrival,
            tpot=((finish_t - first_t) / (generated - 1)
                  if generated > 1 else 0.0)))
    return rows


def serve_summary(trace: dict) -> Optional[dict]:
    """Latency percentiles, stall count, and KV-pool saturation from a
    traced serve episode.  Returns None when the trace has no request
    lifecycles."""
    from repro.obs.metrics import percentile
    reqs = request_latencies(trace)
    if not reqs:
        return None
    kv = find_counters(trace, "kv_pages")
    saturated = sum(1 for ev in kv if ev["args"].get("free") == 0)
    return dict(
        requests=len(reqs),
        ttft_p50=percentile([r["ttft"] for r in reqs], 50),
        ttft_p99=percentile([r["ttft"] for r in reqs], 99),
        tpot_p50=percentile([r["tpot"] for r in reqs], 50),
        tpot_p99=percentile([r["tpot"] for r in reqs], 99),
        admission_stalls=len(find_instants(trace, "admission_stall")),
        slo_burn_alerts=len(find_instants(trace, "slo_burn")),
        kv_samples=len(kv),
        kv_saturated_frac=(saturated / len(kv)) if kv else 0.0)


# ------------------------------------------------------------ analysis
def analyze(trace: dict) -> dict:
    """Every section this module can extract from ``trace`` — sections
    the trace has no events for are None.  ``validation`` always runs
    (strict=False: structural problems are reported, not raised)."""
    from repro.obs.trace import validate_trace
    return dict(
        validation=validate_trace(trace, strict=False),
        attribution=step_attribution(trace),
        overlap=overlap_efficiency(trace),
        pipeline=pipeline_accounting(trace),
        serve=serve_summary(trace))
