"""Human-readable trace report: ``python -m repro.obs.report trace.json``
(docs/observability.md, "Analysis & SLOs").

Renders every section ``obs.analyze.analyze`` extracts — step-time
attribution, comm overlap efficiency, pipeline bubbles, serve latency —
as aligned text; ``--json`` dumps the raw analysis dict instead, and
``--slo SPEC`` (repeatable) additionally evaluates serve objectives via
``obs.slo.evaluate_trace``.  The launchers expose the same rendering as
``--report`` after a traced run.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence

from repro.obs.analyze import ATTRIBUTION_CATEGORIES, analyze


def _fmt_t(seconds: float, basis: str) -> str:
    if basis == "ticks":
        return f"{seconds:10.0f}tk"
    return f"{seconds * 1e3:10.3f}ms"


def render(trace: dict, slos: Sequence[str] = ()) -> str:
    a = analyze(trace)
    out: List[str] = []
    val = a["validation"]
    out.append(f"trace: {val['events']} events, {val['spans']} spans, "
               f"{val['instants']} instants, {val['counters']} counter "
               f"samples, depth {val['max_depth']}")
    for err in val.get("errors", []):
        out.append(f"  STRUCTURE: {err}")

    attr = a["attribution"]
    if attr:
        out.append(f"\nstep attribution ({attr['basis']} basis, "
                   f"{len(attr['steps'])} steps):")
        out.append("  step      total    compute       comm   snapshot"
                   "      stall  attributed")
        for r in attr["steps"]:
            out.append(
                f"  {str(r['step']):>4} " +
                " ".join(_fmt_t(r[k], attr["basis"])
                         for k in ("total",) + ATTRIBUTION_CATEGORIES)
                + f"  {r['attributed_pct']:6.1f}%")
        fr = attr["fractions"]
        out.append("  totals: " + "  ".join(
            f"{k} {100 * fr[k]:.1f}%" for k in ATTRIBUTION_CATEGORIES))

    ov = a["overlap"]
    if ov:
        out.append(f"\ncomm overlap efficiency "
                   f"(mean {ov['efficiency_mean']:.3f}, bounds "
                   f"{'OK' if ov['all_in_bounds'] else 'VIOLATED'}):")
        for r in ov["exchanges"]:
            out.append(
                f"  step {str(r['step']):>4}: no-overlap "
                f"{r['no_overlap_us']:.1f}us >= issue "
                f"{r['issue_overlap_us']:.1f}us >= tictac "
                f"{r['tictac_overlap_us']:.1f}us  "
                f"eff {r['efficiency']:.3f}")

    pp = a["pipeline"]
    if pp:
        out.append(f"\npipeline bubbles (max rel err "
                   f"{pp['rel_err_max']:.3f}):")
        for r in pp["pipes"]:
            out.append(
                f"  step {str(r['step']):>4}: S={r['stages']} "
                f"M={r['micro']} ticks={r['ticks']}  measured "
                f"{r['measured_bubble']:.4f} vs analytic "
                f"{r['analytic_bubble']:.4f} "
                f"({r['bubble_ticks']}/{r['bubble_ticks'] + r['active_ticks']}"
                f" stage-ticks idle)")

    sv = a["serve"]
    if sv:
        out.append(f"\nserve: {sv['requests']} requests  "
                   f"ttft p50/p99 {sv['ttft_p50']:.2f}/{sv['ttft_p99']:.2f}"
                   f"  tpot p50/p99 {sv['tpot_p50']:.2f}/"
                   f"{sv['tpot_p99']:.2f}  stalls {sv['admission_stalls']}"
                   f"  kv saturation {100 * sv['kv_saturated_frac']:.0f}%")
        if sv["slo_burn_alerts"]:
            out.append(f"  slo_burn alerts on trace: "
                       f"{sv['slo_burn_alerts']}")

    if slos:
        from repro.obs.slo import evaluate_trace
        ev = evaluate_trace(trace, slos)
        out.append(f"\nSLO evaluation ({ev['observations']} observations,"
                   f" {len(ev['alerts'])} alert transition(s)):")
        for r in ev["evaluation"]:
            out.append(
                f"  {r['objective']:>16}: burn long/short "
                f"{r['burn_long']:.2f}/{r['burn_short']:.2f}"
                f"{'  FIRING' if r['firing'] else ''}")
        for al in ev["alerts"]:
            out.append(f"  alert at t={al['t']}: "
                       + ", ".join(al["objectives"]))

    if not any((attr, ov, pp, sv)):
        out.append("\n(no analyzable sections: trace has no train, "
                   "pipeline, or serve spans)")
    return "\n".join(out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Analyze a recorded Chrome trace "
                    "(docs/observability.md).")
    ap.add_argument("trace", help="trace JSON written by obs.tracing")
    ap.add_argument("--json", action="store_true",
                    help="dump the raw analysis dict as JSON")
    ap.add_argument("--slo", action="append", default=[],
                    metavar="SPEC",
                    help="evaluate a serve objective, e.g. ttft_p99<8 "
                         "(repeatable)")
    args = ap.parse_args(argv)
    from repro.obs.trace import load_trace
    trace = load_trace(args.trace)
    if args.json:
        out = analyze(trace)
        if args.slo:
            from repro.obs.slo import evaluate_trace
            out["slo"] = evaluate_trace(trace, args.slo)
        print(json.dumps(out, sort_keys=True, default=str))
    else:
        print(render(trace, slos=args.slo))
    return 0


if __name__ == "__main__":
    sys.exit(main())
