"""Counter / gauge / histogram registry with JSONL export
(docs/observability.md), plus the nearest-rank ``percentile`` helper
every latency aggregation in the repo shares (``serve/request.py``
re-exports it for compatibility).

The registry is deliberately tiny and dependency-free: metrics are
host-side Python scalars updated outside jit, so registering and
updating them never touches a traced value.

    reg = MetricsRegistry()
    reg.counter("requests").inc()
    reg.gauge("kv_free_pages").set(13)
    reg.histogram("ttft").observe(2.0)
    print("\n".join(reg.to_jsonl()))      # one JSON object per metric
"""
from __future__ import annotations

import json
from typing import Dict, List, Sequence, Union


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile over ``values`` (``q`` in [0, 100]), no
    numpy dependency in the hot accounting path.  Edge cases: an empty
    sample returns ``nan`` (there is no order statistic to report), a
    singleton sample returns its one value for every ``q``."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q!r} outside [0, 100]")
    xs = sorted(float(v) for v in values)
    if not xs:
        return float("nan")
    if len(xs) == 1:
        return xs[0]
    k = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


class Counter:
    """Monotonically increasing count (requests served, stalls, bytes)."""
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, n: Union[int, float] = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += n

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """A value that goes up and down (pool occupancy, replica count)."""
    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Histogram:
    """Sample distribution with nearest-rank percentile summaries
    (latencies, step times).  Keeps raw samples — these registries live
    for one run, not for months."""
    __slots__ = ("samples",)
    kind = "histogram"

    def __init__(self):
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return sum(self.samples)

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def snapshot(self, qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
        out: Dict[str, float] = {"count": float(self.count)}
        if self.samples:
            out.update(sum=self.sum, min=min(self.samples),
                       max=max(self.samples),
                       mean=self.sum / self.count)
        for q in qs:
            out[f"p{q:g}"] = self.percentile(q)
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named metrics, get-or-create semantics, kind-checked: asking for
    an existing name as a different kind is a bug, not a new metric."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind: str):
        m = self._metrics.get(name)
        if m is None:
            m = _KINDS[kind]()
            self._metrics[name] = m
        elif m.kind != kind:
            raise ValueError(f"metric {name!r} is a {m.kind}, not a {kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str) -> Histogram:
        return self._get(name, "histogram")

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # ----------------------------------------------------------- export
    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {name: self._metrics[name].snapshot()
                for name in self.names()}

    def to_jsonl(self, **common) -> List[str]:
        """One JSON object per metric (``{"metric": name, "kind": ...,
        **snapshot, **common}``) — the ``BENCH_*.json`` row convention."""
        lines = []
        for name in self.names():
            m = self._metrics[name]
            row = dict(metric=name, kind=m.kind, **m.snapshot(), **common)
            lines.append(json.dumps(row, sort_keys=True))
        return lines

    def export_jsonl(self, path: str, **common) -> None:
        with open(path, "w") as f:
            for line in self.to_jsonl(**common):
                f.write(line + "\n")
