"""Counter / gauge / histogram registry with JSONL export
(docs/observability.md), plus the nearest-rank ``percentile`` helper
every latency aggregation in the repo shares (``serve/request.py``
re-exports it for compatibility).

The registry is deliberately tiny and dependency-free: metrics are
host-side Python scalars updated outside jit, so registering and
updating them never touches a traced value.

    reg = MetricsRegistry()
    reg.counter("requests").inc()
    reg.gauge("kv_free_pages").set(13)
    reg.histogram("ttft").observe(2.0)
    print("\n".join(reg.to_jsonl()))      # one JSON object per metric
"""
from __future__ import annotations

import json
import random
from typing import Dict, List, Optional, Sequence, Union


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile over ``values`` (``q`` in [0, 100]), no
    numpy dependency in the hot accounting path.  Edge cases: an empty
    sample returns ``nan`` (there is no order statistic to report), a
    singleton sample returns its one value for every ``q``."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q!r} outside [0, 100]")
    xs = sorted(float(v) for v in values)
    if not xs:
        return float("nan")
    if len(xs) == 1:
        return xs[0]
    k = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


class Counter:
    """Monotonically increasing count (requests served, stalls, bytes)."""
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, n: Union[int, float] = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += n

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """A value that goes up and down (pool occupancy, replica count)."""
    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Histogram:
    """Sample distribution with nearest-rank percentile summaries
    (latencies, step times), bounded memory.

    At most ``max_samples`` raw samples are retained (default
    ``DEFAULT_MAX_SAMPLES``).  Below the cap, percentiles are **exact**.
    Above it, retained samples are a uniform reservoir (Vitter's
    Algorithm R) driven by a fixed-seed PRNG, so for a given observation
    sequence the result is **deterministic** — two same-seed runs
    snapshot identically.  ``count`` / ``sum`` / ``min`` / ``max`` /
    ``mean`` stay exact regardless of the cap."""
    __slots__ = ("samples", "max_samples", "_n", "_sum", "_min", "_max",
                 "_rng")
    kind = "histogram"
    DEFAULT_MAX_SAMPLES = 4096

    def __init__(self, max_samples: Optional[int] = None):
        cap = (self.DEFAULT_MAX_SAMPLES if max_samples is None
               else int(max_samples))
        if cap < 1:
            raise ValueError(f"max_samples must be >= 1, got {cap}")
        self.samples: List[float] = []
        self.max_samples = cap
        self._n = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._rng = random.Random(0)

    def observe(self, v: float) -> None:
        v = float(v)
        self._n += 1
        self._sum += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        if len(self.samples) < self.max_samples:
            self.samples.append(v)
        else:
            # Algorithm R: keep each of the n samples with prob cap/n
            j = self._rng.randrange(self._n)
            if j < self.max_samples:
                self.samples[j] = v

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def snapshot(self, qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
        out: Dict[str, float] = {"count": float(self.count)}
        if self._n:
            out.update(sum=self._sum, min=self._min, max=self._max,
                       mean=self._sum / self._n)
        if self._n > len(self.samples):
            # percentiles below are over the reservoir, not every sample
            out["retained"] = float(len(self.samples))
        for q in qs:
            out[f"p{q:g}"] = self.percentile(q)
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named metrics, get-or-create semantics, kind-checked: asking for
    an existing name as a different kind is a bug, not a new metric."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind: str):
        m = self._metrics.get(name)
        if m is None:
            m = _KINDS[kind]()
            self._metrics[name] = m
        elif m.kind != kind:
            raise ValueError(f"metric {name!r} is a {m.kind}, not a {kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str,
                  max_samples: Optional[int] = None) -> Histogram:
        """``max_samples`` bounds the retained reservoir and only takes
        effect when the histogram is first created."""
        h = self._metrics.get(name)
        if h is None and max_samples is not None:
            h = Histogram(max_samples)
            self._metrics[name] = h
            return h
        return self._get(name, "histogram")

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # ----------------------------------------------------------- export
    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {name: self._metrics[name].snapshot()
                for name in self.names()}

    def to_jsonl(self, **common) -> List[str]:
        """One JSON object per metric (``{"metric": name, "kind": ...,
        **snapshot, **common}``) — the ``BENCH_*.json`` row convention."""
        lines = []
        for name in self.names():
            m = self._metrics[name]
            row = dict(metric=name, kind=m.kind, **m.snapshot(), **common)
            lines.append(json.dumps(row, sort_keys=True))
        return lines

    def export_jsonl(self, path: str, **common) -> None:
        with open(path, "w") as f:
            for line in self.to_jsonl(**common):
                f.write(line + "\n")
