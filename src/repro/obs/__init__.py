"""Unified observability plane (docs/observability.md).

``repro.obs.trace`` — structured tracing: spans / instants / counters
recorded in Chrome trace-event JSON (loadable in Perfetto or
``chrome://tracing``) on *dual clocks*: a deterministic virtual tick
timeline plus wall-clock annotations, so traces from seeded runs are
reproducible byte-for-byte once the wall fields are stripped.  The
default recorder is a no-op — instrumented hot paths cost nothing when
tracing is off.

``repro.obs.metrics`` — a counter / gauge / histogram registry with
JSONL export, and the nearest-rank ``percentile`` helper every latency
aggregation in the repo shares.

``repro.obs.analyze`` / ``repro.obs.report`` — the analysis layer over
recorded traces: step-time attribution (compute / comm / snapshot /
stall), comm overlap efficiency vs the modeled bounds, pipeline-bubble
accounting, serve latency extraction, and the
``python -m repro.obs.report trace.json`` CLI.

``repro.obs.slo`` — declarative serve objectives (``ttft_p99<8``) with
multi-window burn-rate alerting, wired into the serve engine and
autoscaler.

``repro.obs.regress`` — the cross-PR ``BENCH_pr<N>.json`` regression
gate behind ``tools/bench_regress.py`` / ``make bench-regress``.
"""
from repro.obs.analyze import (analyze, overlap_efficiency,
                               pipeline_accounting, request_latencies,
                               serve_summary, step_attribution)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentile)
from repro.obs.slo import Objective, SLOMonitor, evaluate_trace
from repro.obs.trace import (NullRecorder, TraceRecorder, emit_sched_trace,
                             get_recorder, load_trace, set_recorder,
                             strip_wall, tracing, validate_trace)

__all__ = [
    "TraceRecorder", "NullRecorder", "get_recorder", "set_recorder",
    "tracing", "load_trace", "strip_wall", "validate_trace",
    "emit_sched_trace",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "percentile",
    "analyze", "step_attribution", "overlap_efficiency",
    "pipeline_accounting", "request_latencies", "serve_summary",
    "Objective", "SLOMonitor", "evaluate_trace",
]
