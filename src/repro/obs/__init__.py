"""Unified observability plane (docs/observability.md).

``repro.obs.trace`` — structured tracing: spans / instants / counters
recorded in Chrome trace-event JSON (loadable in Perfetto or
``chrome://tracing``) on *dual clocks*: a deterministic virtual tick
timeline plus wall-clock annotations, so traces from seeded runs are
reproducible byte-for-byte once the wall fields are stripped.  The
default recorder is a no-op — instrumented hot paths cost nothing when
tracing is off.

``repro.obs.metrics`` — a counter / gauge / histogram registry with
JSONL export, and the nearest-rank ``percentile`` helper every latency
aggregation in the repo shares.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentile)
from repro.obs.trace import (NullRecorder, TraceRecorder, emit_sched_trace,
                             get_recorder, load_trace, set_recorder,
                             strip_wall, tracing, validate_trace)

__all__ = [
    "TraceRecorder", "NullRecorder", "get_recorder", "set_recorder",
    "tracing", "load_trace", "strip_wall", "validate_trace",
    "emit_sched_trace",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "percentile",
]
