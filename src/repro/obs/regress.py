"""Cross-PR benchmark regression gate (docs/observability.md).

ROADMAP mandates a per-PR ``BENCH_pr<N>.json`` snapshot — JSON-line rows
of the PR's headline benchmark.  Until now those snapshots were
write-only; this module diffs them so drift fails loudly
(``make bench-regress`` / ``tools/bench_regress.py``).

Rows are **keyed** by their identity fields — ``bench`` plus every
string/bool field (strategy spec, codec, backend, policy, ...) plus a
whitelist of integer shape fields — and compared only on the metrics in
``METRIC_BANDS``.  Each band declares how a metric may move:

  ("rel",  tol, "lower")    relative drift; fails when the new value is
                            worse (direction) by more than tol
  ("abs",  tol, dir)        absolute drift band
  ("range", (lo, hi), _)    the value itself must sit inside [lo, hi]
                            (applied to current rows only — e.g. the
                            tracing-overhead sanity band)

Wall-clock metrics (``wall_s``, ``*_step_us``, ``us_per_call_interp``)
are deliberately *not* banded: they measure the host the bench ran on,
not the code.  Everything banded here is deterministic (virtual clocks,
modeled times, measured wire bytes, seeded losses).

The newest snapshot is "current" by default; each of its keyed rows is
compared against the most recent older snapshot containing the same
key.  Keys that appear in only one snapshot are skipped (benches come
and go), but every comparison that *can* run, runs.  Stdlib-only.
"""
from __future__ import annotations

import glob
import json
import os
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

# integer fields that identify a bench cell rather than measure it
ID_INT_FIELDS = frozenset({
    "workers", "slots", "tp", "page_size", "requests", "bucket_passes",
    "stages", "micro", "max_new_tokens", "interleave",
})

# metric -> (kind, tolerance, direction).  direction "lower" = smaller
# is better (regression = grew), "higher" = larger is better.
METRIC_BANDS: Dict[str, Tuple[str, Any, Optional[str]]] = {
    "wire_bytes_per_step": ("rel", 0.01, "lower"),
    "loss_last": ("abs", 0.75, "lower"),
    "modeled_no_overlap_us": ("rel", 0.25, "lower"),
    "modeled_tictac_overlap_us": ("rel", 0.25, "lower"),
    "p50_first_token": ("rel", 0.10, "lower"),
    "p99_first_token": ("rel", 0.10, "lower"),
    "p50_per_token": ("rel", 0.10, "lower"),
    "p99_per_token": ("rel", 0.10, "lower"),
    "tokens_per_s": ("rel", 0.10, "higher"),
    "tpu_roofline_us": ("rel", 0.01, "lower"),
    "traced_overhead_pct": ("range", (-5.0, 50.0), None),
}

_BENCH_RE = re.compile(r"BENCH_pr(\d+)\.json$")


def row_key(row: dict) -> Tuple:
    """The identity of a bench row: every string/bool field plus the
    whitelisted shape ints, sorted for stability."""
    return tuple(sorted(
        (k, v) for k, v in row.items()
        if isinstance(v, (str, bool)) or k in ID_INT_FIELDS))


def load_rows(path: str) -> List[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line.startswith("{"):
                rows.append(json.loads(line))
    return rows


def find_bench_files(root: str) -> List[str]:
    """Committed snapshots sorted by PR number."""
    paths = glob.glob(os.path.join(root, "BENCH_pr*.json"))
    keyed = []
    for p in paths:
        m = _BENCH_RE.search(os.path.basename(p))
        if m:
            keyed.append((int(m.group(1)), p))
    return [p for _, p in sorted(keyed)]


def _check_pair(key: Tuple, metric: str, old: float, new: float,
                band: Tuple, tag_old: str, tag_new: str) -> Optional[dict]:
    kind, tol, direction = band
    if kind == "range":
        return None                      # range checks are per-row
    worse = (new - old) if direction == "lower" else (old - new)
    if kind == "rel":
        scale = abs(old) if old else 1.0
        drift = worse / scale
    else:
        drift = worse
    if drift > tol:
        return dict(key=dict(key), metric=metric, old=old, new=new,
                    drift=round(drift, 6), tol=tol, kind=kind,
                    direction=direction, old_snapshot=tag_old,
                    new_snapshot=tag_new)
    return None


def _check_range(key: Tuple, metric: str, value: float, band: Tuple,
                 tag: str) -> Optional[dict]:
    lo, hi = band[1]
    if not lo <= value <= hi:
        return dict(key=dict(key), metric=metric, old=None, new=value,
                    drift=None, tol=[lo, hi], kind="range",
                    direction=None, old_snapshot=None, new_snapshot=tag)
    return None


def compare(lineage: Sequence[Tuple[str, Sequence[dict]]],
            current: Optional[Tuple[str, Sequence[dict]]] = None) -> dict:
    """``lineage`` is [(tag, rows), ...] oldest-first.  ``current``
    defaults to the newest lineage entry (which is then excluded from
    the history it is compared against).  Returns the gate report:
    ``passed``, the ``violations`` list, and coverage counts."""
    lineage = list(lineage)
    if current is None:
        if not lineage:
            raise ValueError("no bench snapshots to compare")
        current = lineage[-1]
        lineage = lineage[:-1]
    cur_tag, cur_rows = current

    history: List[Tuple[str, Dict[Tuple, dict]]] = [
        (tag, {row_key(r): r for r in rows}) for tag, rows in lineage]

    violations: List[dict] = []
    compared = range_checked = 0
    for row in cur_rows:
        key = row_key(row)
        baseline = None
        for tag, keyed in reversed(history):
            if key in keyed:
                baseline = (tag, keyed[key])
                break
        for metric, band in METRIC_BANDS.items():
            if metric not in row or not isinstance(row[metric],
                                                   (int, float)):
                continue
            if band[0] == "range":
                range_checked += 1
                v = _check_range(key, metric, float(row[metric]), band,
                                 cur_tag)
                if v:
                    violations.append(v)
                continue
            if baseline is None or metric not in baseline[1]:
                continue
            compared += 1
            v = _check_pair(key, metric, float(baseline[1][metric]),
                            float(row[metric]), band, baseline[0],
                            cur_tag)
            if v:
                violations.append(v)
    return dict(passed=not violations, violations=violations,
                compared=compared, range_checked=range_checked,
                current=cur_tag, snapshots=[t for t, _ in history],
                current_rows=len(cur_rows))


def run_gate(root: str, current_path: Optional[str] = None) -> dict:
    """The CLI entry: discover ``BENCH_pr<N>.json`` under ``root``,
    compare the newest (or ``current_path``) against the lineage."""
    paths = find_bench_files(root)
    if not paths:
        raise FileNotFoundError(f"no BENCH_pr<N>.json under {root}")
    lineage = [(os.path.basename(p), load_rows(p)) for p in paths]
    current = None
    if current_path is not None:
        current = (os.path.basename(current_path), load_rows(current_path))
    return compare(lineage, current)


def format_report(report: dict) -> str:
    lines = [f"bench-regress: {report['current']} vs "
             f"{len(report['snapshots'])} older snapshot(s) "
             f"({report['compared']} metric comparisons, "
             f"{report['range_checked']} range checks)"]
    for v in report["violations"]:
        ident = {k: val for k, val in v["key"].items()
                 if k in ("bench", "strategy", "kernel", "policy",
                          "backend", "shape")}
        if v["kind"] == "range":
            lines.append(
                f"  FAIL {v['metric']}={v['new']} outside {v['tol']} "
                f"[{v['new_snapshot']}] {ident}")
        else:
            lines.append(
                f"  FAIL {v['metric']}: {v['old']} -> {v['new']} "
                f"(drift {v['drift']} > {v['tol']} {v['kind']}, "
                f"{v['old_snapshot']} -> {v['new_snapshot']}) {ident}")
    lines.append("bench-regress: " +
                 ("OK" if report["passed"] else
                  f"{len(report['violations'])} violation(s)"))
    return "\n".join(lines)
