"""Structured tracing on dual clocks (docs/observability.md).

The recorder collects **spans** (begin/end pairs), **instants**, and
**counter samples** and serializes them as Chrome trace-event JSON — the
format Perfetto and ``chrome://tracing`` load directly.

Dual clocks
-----------
The primary timestamp (the trace-event ``ts`` field) is a **virtual
tick**: a monotonic per-event sequence number.  It is a pure function of
the host-side event order, so two runs with the same seed produce the
same tick timeline — traces are *reproducible*.  Each event additionally
carries

  * ``args.clock_domain`` / ``args.clock_t`` — the emitting subsystem's
    own deterministic clock (``train_step`` index, ``serve_iter`` virtual
    iteration, ``sched_time``), and
  * ``args.wall_s`` — wall seconds since the recorder started, the only
    non-deterministic field.  ``strip_wall`` removes every ``wall*`` arg
    so seeded traces can be compared byte-for-byte.

Zero overhead when disabled
---------------------------
The module-level recorder defaults to ``NullRecorder`` whose methods are
no-ops and whose ``span`` returns one shared null context manager.
Instrumented hot paths guard with ``rec.enabled`` (a plain attribute
read), so tracing off costs one global lookup per step.

Usage::

    from repro.obs.trace import tracing
    with tracing("out.json") as rec:
        trainer.fit(...)                  # instrumented spine records

This module is dependency-free (stdlib only) so every subsystem can
import it without cycles.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

# the reserved args prefix for non-deterministic fields (wall clocks)
_WALL_PREFIX = "wall"


class _NullSpan:
    """One shared, allocation-free context manager for disabled tracing."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """The default recorder: every method is a no-op.  Hot paths check
    ``enabled`` before doing any argument construction."""

    enabled = False

    def begin(self, name: str, **kw) -> None:
        pass

    def end(self, **kw) -> None:
        pass

    def instant(self, name: str, **kw) -> None:
        pass

    def counter(self, name: str, values: Dict[str, float], **kw) -> None:
        pass

    def span(self, name: str, **kw):
        return _NULL_SPAN


class _Span:
    __slots__ = ("rec", "pid", "tid")

    def __init__(self, rec: "TraceRecorder", pid: str, tid: str):
        self.rec, self.pid, self.tid = rec, pid, tid

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        self.rec.end(pid=self.pid, tid=self.tid)
        return False


class TraceRecorder:
    """Collects trace events on the virtual tick clock.

    ``pid`` / ``tid`` are *names* (subsystem / track); they are mapped to
    the integer ids Chrome wants at serialization time, with ``M``
    metadata events carrying the names.  Spans with the same (pid, tid)
    nest by begin/end order — emit sub-spans on their parent's track.
    """

    enabled = True

    def __init__(self):
        self.events: List[dict] = []
        self._tick = 0
        self._t0 = time.perf_counter()
        # per-(pid, tid) open-span stack, for early validation
        self._open: Dict[Tuple[str, str], List[str]] = {}

    # ------------------------------------------------------------- clock
    def _next(self) -> int:
        t = self._tick
        self._tick += 1
        return t

    def _args(self, clock: Optional[Tuple[str, Any]],
              args: Dict[str, Any]) -> Dict[str, Any]:
        out = dict(args)
        if clock is not None:
            out["clock_domain"] = clock[0]
            out["clock_t"] = clock[1]
        out["wall_s"] = round(time.perf_counter() - self._t0, 6)
        return out

    # ----------------------------------------------------------- events
    def begin(self, name: str, *, pid: str = "main", tid: str = "main",
              cat: str = "", clock: Optional[Tuple[str, Any]] = None,
              **args) -> None:
        self._open.setdefault((pid, tid), []).append(name)
        self.events.append(dict(name=name, cat=cat, ph="B",
                                ts=self._next(), pid=pid, tid=tid,
                                args=self._args(clock, args)))

    def end(self, *, pid: str = "main", tid: str = "main", **args) -> None:
        stack = self._open.get((pid, tid), [])
        if not stack:
            raise ValueError(f"end() without begin() on track "
                             f"({pid!r}, {tid!r})")
        name = stack.pop()
        self.events.append(dict(name=name, cat="", ph="E",
                                ts=self._next(), pid=pid, tid=tid,
                                args=self._args(None, args)))

    def span(self, name: str, *, pid: str = "main", tid: str = "main",
             cat: str = "", clock: Optional[Tuple[str, Any]] = None,
             **args) -> _Span:
        self.begin(name, pid=pid, tid=tid, cat=cat, clock=clock, **args)
        return _Span(self, pid, tid)

    def instant(self, name: str, *, pid: str = "main", tid: str = "main",
                cat: str = "", clock: Optional[Tuple[str, Any]] = None,
                **args) -> None:
        self.events.append(dict(name=name, cat=cat, ph="i",
                                ts=self._next(), pid=pid, tid=tid, s="t",
                                args=self._args(clock, args)))

    def counter(self, name: str, values: Dict[str, float], *,
                pid: str = "main", cat: str = "",
                clock: Optional[Tuple[str, Any]] = None) -> None:
        args = self._args(clock, {k: float(v) for k, v in values.items()})
        self.events.append(dict(name=name, cat=cat, ph="C",
                                ts=self._next(), pid=pid, tid=name,
                                args=args))

    # ---------------------------------------------------- serialization
    def to_chrome(self, include_wall: bool = True) -> dict:
        """The Chrome trace-event JSON object.  pid/tid names become
        stable integer ids (first-appearance order — deterministic) with
        ``M`` metadata events naming them."""
        pids: Dict[str, int] = {}
        tids: Dict[Tuple[str, str], int] = {}
        out: List[dict] = []
        for ev in self.events:
            pid = pids.setdefault(ev["pid"], len(pids) + 1)
            tid = tids.setdefault((ev["pid"], ev["tid"]),
                                  len(tids) + 1)
            args = ev["args"]
            if not include_wall:
                args = {k: v for k, v in args.items()
                        if not k.startswith(_WALL_PREFIX)}
            rec = dict(ev, pid=pid, tid=tid, args=args)
            out.append(rec)
        meta: List[dict] = []
        for name, pid in pids.items():
            meta.append(dict(name="process_name", ph="M", ts=0, pid=pid,
                             tid=0, args={"name": name}))
        for (pname, tname), tid in tids.items():
            meta.append(dict(name="thread_name", ph="M", ts=0,
                             pid=pids[pname], tid=tid,
                             args={"name": tname}))
        return {
            "traceEvents": meta + out,
            "displayTimeUnit": "ms",
            "otherData": {
                "clock": "virtual ticks (deterministic); wall seconds in "
                         "args.wall_s",
            },
        }

    def to_bytes(self, include_wall: bool = True) -> bytes:
        return json.dumps(self.to_chrome(include_wall), sort_keys=True,
                          separators=(",", ":")).encode()

    def save(self, path: str, include_wall: bool = True) -> None:
        with open(path, "wb") as f:
            f.write(self.to_bytes(include_wall))


# ----------------------------------------------------- module recorder
_NULL = NullRecorder()
_recorder: Any = _NULL


def get_recorder():
    """The process-wide recorder every instrumented call site consults.
    Defaults to the no-op ``NullRecorder``."""
    return _recorder


def set_recorder(rec) -> Any:
    """Install ``rec`` (None restores the no-op default); returns the
    previous recorder so callers can restore it."""
    global _recorder
    prev = _recorder
    _recorder = rec if rec is not None else _NULL
    return prev


@contextlib.contextmanager
def tracing(path: Optional[str] = None,
            recorder: Optional[TraceRecorder] = None):
    """Enable tracing for the block; on exit restore the previous
    recorder and (when ``path`` is given) write the Chrome trace JSON."""
    rec = recorder if recorder is not None else TraceRecorder()
    prev = set_recorder(rec)
    try:
        yield rec
    finally:
        set_recorder(prev)
        if path is not None:
            rec.save(path)


# ---------------------------------------------------------- inspection
def load_trace(path: str) -> dict:
    with open(path, "rb") as f:
        return json.loads(f.read())


def strip_wall(trace: dict) -> dict:
    """Drop every non-deterministic ``wall*`` arg — what the seeded-run
    byte-identity comparison operates on."""
    events = []
    for ev in trace.get("traceEvents", []):
        args = {k: v for k, v in ev.get("args", {}).items()
                if not k.startswith(_WALL_PREFIX)}
        events.append(dict(ev, args=args))
    return dict(trace, traceEvents=events)


def canonical_bytes(trace: dict) -> bytes:
    """Deterministic serialization of a (typically wall-stripped) trace."""
    return json.dumps(trace, sort_keys=True, separators=(",", ":")).encode()


def validate_trace(trace: dict, strict: bool = True) -> Dict[str, Any]:
    """Structural validation of a Chrome trace-event object: ``ts`` is
    globally non-decreasing and every ``E`` matches the innermost open
    ``B`` on its (pid, tid) track.  With ``strict`` (the default) the
    first violation raises ``ValueError``; with ``strict=False`` every
    violation is collected into the returned ``errors`` list instead —
    analysis of a damaged trace should report, not crash.  Returns
    summary stats (span/instant/counter counts, max nesting depth, span
    names, errors)."""
    errors: List[str] = []

    def fail(msg: str) -> None:
        if strict:
            raise ValueError(msg)
        errors.append(msg)

    events = trace.get("traceEvents")
    if not isinstance(events, list):
        fail("not a Chrome trace: missing traceEvents list")
        events = []
    stacks: Dict[Tuple[Any, Any], List[str]] = {}
    last_ts = None
    spans = instants = counters = 0
    max_depth = 0
    names: set = set()
    for ev in events:
        ph = ev.get("ph")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if ts is None:
            fail(f"event missing ts: {ev}")
        elif last_ts is not None and ts < last_ts:
            fail(f"ts went backwards: {ts} < {last_ts}")
        if ts is not None:
            last_ts = ts
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks.setdefault(key, []).append(ev.get("name"))
            names.add(ev.get("name"))
            max_depth = max(max_depth, len(stacks[key]))
        elif ph == "E":
            stack = stacks.get(key, [])
            if not stack:
                fail(f"E without B on track {key}: {ev}")
                continue
            if stack.pop() != ev.get("name"):
                fail(f"E name mismatch on track {key}: {ev}")
            spans += 1
        elif ph == "i":
            instants += 1
            names.add(ev.get("name"))
        elif ph == "C":
            counters += 1
            names.add(ev.get("name"))
        else:
            fail(f"unknown phase {ph!r}: {ev}")
    unclosed = {k: v for k, v in stacks.items() if v}
    if unclosed:
        fail(f"unclosed spans: {unclosed}")
    return dict(events=len(events), spans=spans, instants=instants,
                counters=counters, max_depth=max_depth,
                names=sorted(n for n in names if n is not None),
                errors=errors)


def find_spans(trace: dict, name: str) -> List[dict]:
    """All ``B`` events with ``name`` (convenience for tests/smoke)."""
    return [ev for ev in trace.get("traceEvents", [])
            if ev.get("ph") == "B" and ev.get("name") == name]


# ------------------------------------------------------- sched bridge
def emit_sched_trace(rec, trace: Iterable, *, pid: str = "sched",
                     clock_domain: str = "sched_time") -> None:
    """Re-emit a ``sched.simulator`` allocation ``TraceEvent`` stream
    (any iterable of objects with ``t / jid / kind / gpus`` fields) onto
    the shared timeline: one track per job, a span per running interval
    (start/resume → suspend/finish), an instant per decision.  Jobs
    still running when the stream ends are closed with a ``truncated``
    end so the trace stays well-formed."""
    if not rec.enabled:
        return
    open_jobs: Dict[int, str] = {}
    for ev in trace:
        tid = f"job{ev.jid}"
        rec.instant(ev.kind, pid=pid, tid=tid, cat="sched",
                    clock=(clock_domain, ev.t), jid=ev.jid, gpus=ev.gpus)
        if ev.kind in ("start", "resume"):
            if ev.jid not in open_jobs:
                rec.begin("running", pid=pid, tid=tid, cat="sched",
                          clock=(clock_domain, ev.t), jid=ev.jid,
                          gpus=ev.gpus)
                open_jobs[ev.jid] = tid
        elif ev.kind in ("suspend", "finish"):
            if ev.jid in open_jobs:
                rec.end(pid=pid, tid=tid, t=ev.t)
                del open_jobs[ev.jid]
    for jid, tid in sorted(open_jobs.items()):
        rec.end(pid=pid, tid=tid, truncated=True)
