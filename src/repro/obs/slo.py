"""Serve SLOs: declarative objectives + multi-window burn-rate alerts
(docs/observability.md, "Analysis & SLOs"; docs/serving.md).

An **objective** is a one-line spec over the serve plane's deterministic
iteration clock:

    "ttft_p99<8"        99% of requests see first token within 8 iters
    "tpot_p50<1.5"      median per-token latency under 1.5 iters
    "stall_rate<0.1"    at most 10% of engine iterations admission-stall
    "error_rate<0.01"   at most 1% of completions error

Quantile objectives get an **error budget** of ``1 - q/100`` (p99 ->
1%); rate objectives budget the rate bound directly.  An observation is
*bad* when it exceeds the threshold (for rate metrics, when it is
nonzero).

Alerting follows the SRE multi-window **burn rate** rule: with
``burn = bad_fraction / budget`` measured over a window, an objective is
*firing* when both the long window (sustained) and the short window
(still happening) burn faster than ``factor``.  Burning on one window
alone is ignored — the long window alone is old news, the short window
alone is noise.

``ServeEngine`` feeds a monitor live (``ServeEngine(..., slo=mon)``)
and emits an ``slo_burn`` instant on each transition into firing; the
recorded alert times can then drive ``Autoscaler.schedule(...,
burn_times=...)`` so a burning SLO forces a scale-up even when the
arrival-rate signal alone would not.  ``evaluate_trace`` replays the
same objectives over an already-recorded trace.  Stdlib-only.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

_SPEC = re.compile(
    r"^(?P<metric>[a-z_]+?)_(?:p(?P<q>\d+(?:\.\d+)?)|(?P<rate>rate))"
    r"\s*<=?\s*(?P<value>[0-9.eE+-]+)$")


@dataclasses.dataclass(frozen=True)
class Objective:
    """One parsed SLO line.  ``budget`` is the allowed bad fraction;
    ``threshold`` is the per-observation bad cutoff (0 for rates: any
    nonzero observation is bad)."""
    metric: str
    spec: str
    budget: float
    threshold: float

    @staticmethod
    def parse(spec: str) -> "Objective":
        m = _SPEC.match(spec.strip())
        if not m:
            raise ValueError(
                f"bad SLO spec {spec!r} (want e.g. 'ttft_p99<8' or "
                f"'stall_rate<0.1')")
        value = float(m.group("value"))
        if m.group("rate"):
            if not 0.0 < value <= 1.0:
                raise ValueError(f"rate bound must be in (0, 1]: {spec!r}")
            return Objective(m.group("metric"), spec.strip(),
                             budget=value, threshold=0.0)
        q = float(m.group("q"))
        if not 0.0 < q < 100.0:
            raise ValueError(f"quantile must be in (0, 100): {spec!r}")
        return Objective(m.group("metric"), spec.strip(),
                         budget=1.0 - q / 100.0, threshold=value)

    def bad(self, value: float) -> bool:
        return value > self.threshold


class SLOMonitor:
    """Accumulates per-metric observations on a monotonic clock and
    evaluates multi-window burn rates per objective."""

    def __init__(self, objectives: Sequence[Union[str, Objective]],
                 long_window: float = 64.0, short_window: float = 8.0,
                 factor: float = 2.0):
        self.objectives: List[Objective] = [
            o if isinstance(o, Objective) else Objective.parse(o)
            for o in objectives]
        if not self.objectives:
            raise ValueError("SLOMonitor needs at least one objective")
        self.long_window = float(long_window)
        self.short_window = float(short_window)
        self.factor = float(factor)
        self._obs: Dict[str, List[Tuple[float, float]]] = {}

    def observe(self, metric: str, t: float, value: float = 1.0) -> None:
        self._obs.setdefault(metric, []).append((float(t), float(value)))

    def burn_rate(self, obj: Objective, now: float,
                  window: float) -> float:
        """bad_fraction / budget over ``(now - window, now]``; 0.0 when
        the window holds no observations (no evidence, no alarm)."""
        xs = self._obs.get(obj.metric, ())
        lo = now - window
        n = bad = 0
        for t, v in xs:
            if lo < t <= now:
                n += 1
                bad += obj.bad(v)
        return (bad / n) / obj.budget if n else 0.0

    def evaluate(self, now: float) -> List[dict]:
        rows = []
        for obj in self.objectives:
            long = self.burn_rate(obj, now, self.long_window)
            short = self.burn_rate(obj, now, self.short_window)
            rows.append(dict(
                objective=obj.spec, metric=obj.metric, budget=obj.budget,
                burn_long=long, burn_short=short,
                firing=(long >= self.factor and short >= self.factor)))
        return rows

    def firing(self, now: float) -> List[dict]:
        return [r for r in self.evaluate(now) if r["firing"]]


def evaluate_trace(trace: dict,
                   objectives: Sequence[Union[str, Objective]],
                   long_window: float = 64.0, short_window: float = 8.0,
                   factor: float = 2.0) -> dict:
    """Replay ``objectives`` over a recorded serve trace: request TTFT /
    TPOT from the lifecycle spans (keyed to *finish* time — the moment
    the number became known), stall samples from ``admission_stall``
    instants and the iteration-sampled counter tracks.  Returns the
    final evaluation plus every alert transition on the trace clock."""
    from repro.obs.analyze import (find_counters, find_instants,
                                   request_latencies)
    mon = SLOMonitor(objectives, long_window=long_window,
                     short_window=short_window, factor=factor)
    events: List[Tuple[float, str, float]] = []
    for r in request_latencies(trace):
        events.append((r["finish_t"], "ttft", r["ttft"]))
        events.append((r["finish_t"], "tpot", r["tpot"]))
    stall_ts = {ev["args"].get("clock_t")
                for ev in find_instants(trace, "admission_stall")}
    # one stall sample per engine iteration (counters fire once each)
    for ev in find_counters(trace, "slots"):
        t = ev["args"].get("clock_t")
        if t is not None:
            events.append((float(t), "stall",
                           1.0 if t in stall_ts else 0.0))
    events.sort(key=lambda e: e[0])
    alerts: List[dict] = []
    was_firing = False
    now = 0.0
    for t, metric, value in events:
        mon.observe(metric, t, value)
        now = t
        firing = mon.firing(now)
        if firing and not was_firing:
            alerts.append(dict(t=now,
                               objectives=[f["objective"] for f in firing]))
        was_firing = bool(firing)
    return dict(evaluation=mon.evaluate(now), alerts=alerts,
                observations=len(events))
