"""Sharded npz checkpoints for arbitrary pytrees.

Layout: <dir>/manifest.json (treedef + leaf metadata + shard map) and
<dir>/shard_<i>.npz.  Large leaves are split across shards so no single
file exceeds ``shard_bytes`` — the layout a multi-host save would produce
with one shard per host.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]


def save_checkpoint(path: str, tree, step: int = 0,
                    shard_bytes: int = 512 * 1024 * 1024) -> Dict:
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    names = _leaf_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": [], "shards": 0}
    shard: Dict[str, np.ndarray] = {}
    shard_size = 0
    shard_idx = 0

    def flush():
        nonlocal shard, shard_size, shard_idx
        if shard:
            np.savez(os.path.join(path, f"shard_{shard_idx}.npz"), **shard)
            shard_idx += 1
            shard, shard_size = {}, 0

    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        key = name.replace("/", "__")
        if shard_size + arr.nbytes > shard_bytes:
            flush()
        shard[key] = arr
        shard_size += arr.nbytes
        manifest["leaves"].append({"name": name, "key": key,
                                   "shard": shard_idx,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    flush()
    manifest["shards"] = shard_idx
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return manifest


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (a pytree or eval_shape result)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_shard: Dict[int, List[dict]] = {}
    for rec in manifest["leaves"]:
        by_shard.setdefault(rec["shard"], []).append(rec)
    arrays: Dict[str, np.ndarray] = {}
    for si, recs in by_shard.items():
        with np.load(os.path.join(path, f"shard_{si}.npz")) as z:
            for rec in recs:
                arrays[rec["name"]] = z[rec["key"]]
    names = _leaf_paths(like)
    leaves = [arrays[n] for n in names]
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves), manifest["step"]
