"""Sharded npz checkpoints for arbitrary pytrees.

Layout: <dir>/manifest.json (treedef + leaf metadata + shard map) and
<dir>/shard_<i>.npz.  Large leaves are split across shards so no single
file exceeds ``shard_bytes`` — the layout a multi-host save would produce
with one shard per host.

Writes are atomic: shards and manifest are staged into a sibling temp
directory which is then renamed into place with ``os.replace``, so a crash
mid-save can never leave a torn checkpoint for recovery to load.  The
manifest carries an optional ``extra`` JSON blob (``read_manifest``) —
the elastic trainer stores engine bookkeeping (worker count, tick/update
counters) there next to the array state.

Incremental saves: pass ``incremental_from=<previous checkpoint dir>``
and every shard whose leaf composition AND content hashes are unchanged
since that checkpoint is *hard-linked* from it instead of re-serialized
(falling back to a copy on filesystems without links).  The manifest
records per-leaf sha256 content hashes (``hash``) and the count of
linked shards (``linked_shards``); restores are byte-for-byte identical
either way, and atomicity is unchanged — links are staged into the same
temp directory.  The elastic trainer uses this for periodic cadence
snapshots, keeping crash/preemption commits full (docs/comm.md §
snapshots)."""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]


def _leaf_hash(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _prev_shard_map(prev_dir: Optional[str]) -> Dict[int, List[dict]]:
    """shard index -> ordered leaf records of the previous manifest, or
    {} when there is no usable previous checkpoint."""
    if not prev_dir or not is_valid_checkpoint(prev_dir):
        return {}
    by_shard: Dict[int, List[dict]] = {}
    for rec in read_manifest(prev_dir)["leaves"]:
        by_shard.setdefault(rec["shard"], []).append(rec)
    return by_shard


def _link_or_copy(src: str, dst: str) -> None:
    try:
        os.link(src, dst)
    except OSError:
        shutil.copy2(src, dst)


def _write_checkpoint(path: str, tree, step: int, shard_bytes: int,
                      extra: Optional[Dict],
                      prev_dir: Optional[str] = None,
                      hash_leaves: bool = False) -> Dict:
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    names = _leaf_paths(tree)
    prev_shards = _prev_shard_map(prev_dir)
    manifest: Dict[str, Any] = {"step": step, "leaves": [], "shards": 0,
                                "linked_shards": 0}
    if extra is not None:
        manifest["extra"] = extra
    shard: Dict[str, np.ndarray] = {}
    shard_recs: List[dict] = []
    shard_size = 0
    shard_idx = 0

    def flush():
        nonlocal shard, shard_recs, shard_size, shard_idx
        if not shard:
            return
        # hash-skip: when this shard's composition (keys, shapes, dtypes,
        # content hashes) matches the previous checkpoint's shard of the
        # same index, link the old file instead of re-serializing it
        prev = prev_shards.get(shard_idx)
        same = (prev is not None and len(prev) == len(shard_recs)
                and all(p.get("hash") and r.get("hash")
                        and p["key"] == r["key"]
                        and p["hash"] == r["hash"]
                        and p["shape"] == r["shape"]
                        and p["dtype"] == r["dtype"]
                        for p, r in zip(prev, shard_recs)))
        fname = f"shard_{shard_idx}.npz"
        if same:
            _link_or_copy(os.path.join(prev_dir, fname),
                          os.path.join(path, fname))
            manifest["linked_shards"] += 1
        else:
            np.savez(os.path.join(path, fname), **shard)
        shard_idx += 1
        shard, shard_recs, shard_size = {}, [], 0

    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        key = name.replace("/", "__")
        if shard_size + arr.nbytes > shard_bytes:
            flush()
        shard[key] = arr
        shard_size += arr.nbytes
        rec = {"name": name, "key": key, "shard": shard_idx,
               "shape": list(arr.shape), "dtype": str(arr.dtype)}
        if hash_leaves:
            rec["hash"] = _leaf_hash(arr)
        shard_recs.append(rec)
        manifest["leaves"].append(rec)
    flush()
    manifest["shards"] = shard_idx
    # manifest last: its presence is the per-directory commit marker
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return manifest


def save_checkpoint(path: str, tree, step: int = 0,
                    shard_bytes: int = 512 * 1024 * 1024,
                    extra: Optional[Dict] = None,
                    incremental_from: Optional[str] = None,
                    hash_leaves: Optional[bool] = None) -> Dict:
    """Atomically write ``tree`` to the checkpoint directory ``path``.

    All files are staged into ``<path>.tmp.<pid>`` and swapped in with one
    ``os.replace`` — a reader either sees the complete old checkpoint, no
    checkpoint, or the complete new one, never a torn mix.  When
    overwriting, the existing checkpoint is renamed aside (not deleted)
    before the swap, so even a crash mid-swap leaves the old data
    recoverable at ``<path>.old.<pid>`` (a base being overwritten in
    place stays linkable: renames preserve the inodes the staged links
    point at).

    ``incremental_from`` names a previously-committed checkpoint whose
    unchanged shards are hard-linked instead of rewritten (see the module
    docstring); restores are bitwise-identical either way.
    ``hash_leaves`` opts a snapshot into per-leaf content hashes so a
    *later* save can link against it — it defaults to on exactly when
    ``incremental_from`` is given; pass ``hash_leaves=True`` on full
    saves that should serve as future incremental bases (the elastic
    trainer does), and leave plain saves unhashed (no sha256 cost)."""
    path = os.path.abspath(path)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    if incremental_from is not None:
        incremental_from = os.path.abspath(incremental_from)
    if hash_leaves is None:
        hash_leaves = incremental_from is not None
    tmp = f"{path}.tmp.{os.getpid()}"
    old = f"{path}.old.{os.getpid()}"
    shutil.rmtree(tmp, ignore_errors=True)
    try:
        manifest = _write_checkpoint(tmp, tree, step, shard_bytes, extra,
                                     prev_dir=incremental_from,
                                     hash_leaves=hash_leaves)
        if os.path.isdir(path):
            shutil.rmtree(old, ignore_errors=True)
            os.rename(path, old)
        os.replace(tmp, path)
        shutil.rmtree(old, ignore_errors=True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return manifest


def read_manifest(path: str) -> Dict:
    """The checkpoint's manifest (step, leaf metadata, ``extra`` blob).
    Raises FileNotFoundError for a missing or uncommitted checkpoint."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def is_valid_checkpoint(path: str) -> bool:
    """True iff ``path`` holds a committed (manifest-bearing) checkpoint."""
    return os.path.isfile(os.path.join(path, "manifest.json"))


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (a pytree or eval_shape result)."""
    manifest = read_manifest(path)
    by_shard: Dict[int, List[dict]] = {}
    for rec in manifest["leaves"]:
        by_shard.setdefault(rec["shard"], []).append(rec)
    arrays: Dict[str, np.ndarray] = {}
    for si, recs in by_shard.items():
        with np.load(os.path.join(path, f"shard_{si}.npz")) as z:
            for rec in recs:
                arrays[rec["name"]] = z[rec["key"]]
    names = _leaf_paths(like)
    leaves = [arrays[n] for n in names]
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves), manifest["step"]
