"""Sharded npz checkpoints for arbitrary pytrees.

Layout: <dir>/manifest.json (treedef + leaf metadata + shard map) and
<dir>/shard_<i>.npz.  Large leaves are split across shards so no single
file exceeds ``shard_bytes`` — the layout a multi-host save would produce
with one shard per host.

Writes are atomic: shards and manifest are staged into a sibling temp
directory which is then renamed into place with ``os.replace``, so a crash
mid-save can never leave a torn checkpoint for recovery to load.  The
manifest carries an optional ``extra`` JSON blob (``read_manifest``) —
the elastic trainer stores engine bookkeeping (worker count, tick/update
counters) there next to the array state.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]


def _write_checkpoint(path: str, tree, step: int, shard_bytes: int,
                      extra: Optional[Dict]) -> Dict:
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    names = _leaf_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": [], "shards": 0}
    if extra is not None:
        manifest["extra"] = extra
    shard: Dict[str, np.ndarray] = {}
    shard_size = 0
    shard_idx = 0

    def flush():
        nonlocal shard, shard_size, shard_idx
        if shard:
            np.savez(os.path.join(path, f"shard_{shard_idx}.npz"), **shard)
            shard_idx += 1
            shard, shard_size = {}, 0

    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        key = name.replace("/", "__")
        if shard_size + arr.nbytes > shard_bytes:
            flush()
        shard[key] = arr
        shard_size += arr.nbytes
        manifest["leaves"].append({"name": name, "key": key,
                                   "shard": shard_idx,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    flush()
    manifest["shards"] = shard_idx
    # manifest last: its presence is the per-directory commit marker
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return manifest


def save_checkpoint(path: str, tree, step: int = 0,
                    shard_bytes: int = 512 * 1024 * 1024,
                    extra: Optional[Dict] = None) -> Dict:
    """Atomically write ``tree`` to the checkpoint directory ``path``.

    All files are staged into ``<path>.tmp.<pid>`` and swapped in with one
    ``os.replace`` — a reader either sees the complete old checkpoint, no
    checkpoint, or the complete new one, never a torn mix.  When
    overwriting, the existing checkpoint is renamed aside (not deleted)
    before the swap, so even a crash mid-swap leaves the old data
    recoverable at ``<path>.old.<pid>``."""
    path = os.path.abspath(path)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    old = f"{path}.old.{os.getpid()}"
    shutil.rmtree(tmp, ignore_errors=True)
    try:
        manifest = _write_checkpoint(tmp, tree, step, shard_bytes, extra)
        if os.path.isdir(path):
            shutil.rmtree(old, ignore_errors=True)
            os.rename(path, old)
        os.replace(tmp, path)
        shutil.rmtree(old, ignore_errors=True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return manifest


def read_manifest(path: str) -> Dict:
    """The checkpoint's manifest (step, leaf metadata, ``extra`` blob).
    Raises FileNotFoundError for a missing or uncommitted checkpoint."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def is_valid_checkpoint(path: str) -> bool:
    """True iff ``path`` holds a committed (manifest-bearing) checkpoint."""
    return os.path.isfile(os.path.join(path, "manifest.json"))


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (a pytree or eval_shape result)."""
    manifest = read_manifest(path)
    by_shard: Dict[int, List[dict]] = {}
    for rec in manifest["leaves"]:
        by_shard.setdefault(rec["shard"], []).append(rec)
    arrays: Dict[str, np.ndarray] = {}
    for si, recs in by_shard.items():
        with np.load(os.path.join(path, f"shard_{si}.npz")) as z:
            for rec in recs:
                arrays[rec["name"]] = z[rec["key"]]
    names = _leaf_paths(like)
    leaves = [arrays[n] for n in names]
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves), manifest["step"]
