"""ModelDB/ModelHub-style model registry (survey §3.5.2, [177, 116]):
tracking, indexing, and querying of trained models + their metadata."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional


class ModelRegistry:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._index_path = os.path.join(root, "index.json")
        self._index: List[Dict[str, Any]] = []
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                self._index = json.load(f)

    def _persist(self):
        with open(self._index_path, "w") as f:
            json.dump(self._index, f, indent=1)

    def register(self, name: str, checkpoint_path: str, *,
                 arch: str = "", hyperparams: Optional[Dict] = None,
                 metrics: Optional[Dict] = None, parent: Optional[str] = None,
                 timestamp: Optional[float] = None) -> str:
        version = sum(1 for r in self._index if r["name"] == name)
        rec = {"id": f"{name}:v{version}", "name": name, "version": version,
               "checkpoint": checkpoint_path, "arch": arch,
               "hyperparams": hyperparams or {}, "metrics": metrics or {},
               "parent": parent,
               "created": timestamp if timestamp is not None else time.time()}
        self._index.append(rec)
        self._persist()
        return rec["id"]

    def get(self, model_id: str) -> Dict[str, Any]:
        for r in self._index:
            if r["id"] == model_id:
                return r
        raise KeyError(model_id)

    def query(self, *, name: Optional[str] = None, arch: Optional[str] = None,
              min_metric: Optional[Dict[str, float]] = None
              ) -> List[Dict[str, Any]]:
        out = []
        for r in self._index:
            if name and r["name"] != name:
                continue
            if arch and r["arch"] != arch:
                continue
            if min_metric and any(r["metrics"].get(k, float("-inf")) < v
                                  for k, v in min_metric.items()):
                continue
            out.append(r)
        return out

    def lineage(self, model_id: str) -> List[str]:
        chain = []
        cur: Optional[str] = model_id
        while cur:
            rec = self.get(cur)
            chain.append(cur)
            cur = rec["parent"]
        return chain

    def best(self, name: str, metric: str, maximize: bool = True
             ) -> Optional[Dict[str, Any]]:
        cands = [r for r in self.query(name=name) if metric in r["metrics"]]
        if not cands:
            return None
        return (max if maximize else min)(
            cands, key=lambda r: r["metrics"][metric])
