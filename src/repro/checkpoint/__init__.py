"""Model-data management (survey §3.5.2): sharded checkpoints + a
ModelDB-style registry."""
from repro.checkpoint.store import (is_valid_checkpoint, load_checkpoint,
                                    read_manifest, save_checkpoint)
from repro.checkpoint.registry import ModelRegistry

__all__ = ["save_checkpoint", "load_checkpoint", "read_manifest",
           "is_valid_checkpoint", "ModelRegistry"]
