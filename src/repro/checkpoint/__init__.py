"""Model-data management (survey §3.5.2): sharded checkpoints + a
ModelDB-style registry."""
from repro.checkpoint.store import save_checkpoint, load_checkpoint
from repro.checkpoint.registry import ModelRegistry

__all__ = ["save_checkpoint", "load_checkpoint", "ModelRegistry"]
