"""Qwen2-VL 7B — VLM backbone with M-RoPE [arXiv:2409.12191].

The vision encoder (ViT + merger) is a STUB per the brief: `input_specs`
provides precomputed patch embeddings of shape (batch, n_patches, d_model)
that the backbone merges into the token stream.  M-RoPE splits each rotary
half-dim (head_dim/2 = 64) into (temporal, height, width) = (16, 24, 24)
sections driven by 3-row position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,        # GQA
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # sums to head_dim // 2
    use_bias=True,                 # qwen2 uses qkv bias
    tie_embeddings=False,
    source="arXiv:2409.12191 (Qwen2-VL)",
)
