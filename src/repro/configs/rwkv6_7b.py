"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay [arXiv:2404.05892].

Time-mixing keeps a per-head (head_size x head_size) state updated with a
data-dependent decay w_t, so decode state is O(1) in sequence length:
`long_500k` runs with constant memory.  64 heads of size 64 (d_model 4096).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,           # attention-free
    num_kv_heads=0,
    head_dim=0,
    attn_type="none",
    d_ff=14336,
    vocab_size=65536,
    act="relu_sq",         # RWKV channel-mix uses squared ReLU
    norm="layernorm",
    block_pattern=("rwkv",),
    rwkv_head_size=64,
    tie_embeddings=False,
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)
