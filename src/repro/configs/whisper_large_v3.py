"""Whisper large-v3 — encoder-decoder ASR backbone [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the brief:
`input_specs` provides precomputed frame embeddings of shape
(batch, 1500, d_model) for the encoder.  The decoder is a standard
transformer with learned positions and cross-attention.
`long_500k` is skipped for this arch (30 s / 448-token context model;
see DESIGN.md §3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,          # decoder layers
    encoder_layers=32,
    is_encoder_decoder=True,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,        # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,       # padded to the model-axis multiple at build time
    act="gelu",
    norm="layernorm",
    use_bias=True,
    learned_positions=True,
    max_source_positions=1500,
    tie_embeddings=True,
    source="arXiv:2212.04356 (Whisper); large-v3 model card",
)
