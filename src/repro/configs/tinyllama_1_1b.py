"""TinyLlama 1.1B — llama2-architecture small dense model [arXiv:2401.02385]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,        # GQA
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2401.02385 (TinyLlama)",
)
