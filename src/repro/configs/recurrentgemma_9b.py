"""RecurrentGemma 9B — Griffin: RG-LRU + local attention, 2:1 [arXiv:2402.19427].

Block pattern is (recurrent, recurrent, local-attention) cycled over 38 layers
(Griffin's "temporal mixing blocks in a ratio of 2:1").  Local attention uses
MQA (kv=1) with a 2048-token window, making `long_500k` decode sub-quadratic
with a constant-size state: RG-LRU hidden + a ring-buffer window cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,        # MQA
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    act="swiglu",          # Griffin uses GeGLU; gated-MLP structure identical
    norm="rmsnorm",
    window=2048,
    block_pattern=("rglru", "rglru", "local"),
    lru_width=4096,
    conv_width=4,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
)
