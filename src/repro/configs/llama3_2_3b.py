"""Llama 3.2 3B — small llama3 dense model [hf:meta-llama/Llama-3.2-1B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,        # GQA
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B (scaled per assignment)",
)
