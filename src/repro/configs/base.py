"""Unified model configuration covering the six assigned architecture families.

Every assigned architecture (dense / moe / vlm / audio / hybrid / ssm) is expressed
as a `ModelConfig`.  The survey's techniques (sync models, compression, PS vs
allreduce, federated) are model-agnostic and configured separately in
`repro.core`; this config only describes the network.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # ---- attention ----
    num_heads: int = 0               # query heads; 0 => attention-free (ssm)
    num_kv_heads: int = 0
    head_dim: int = 0
    attn_type: str = "gqa"           # gqa | mla | none
    window: int = 0                  # >0 => sliding-window (local) attention
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) half-dims
    # ---- MLP / MoE ----
    act: str = "swiglu"              # swiglu | gelu
    moe: bool = False
    num_experts: int = 0             # routed experts
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    first_k_dense: int = 0           # leading dense layers before the MoE stack
    capacity_factor: float = 1.0
    router_aux_coef: float = 0.01
    # ---- MLA (deepseek) ----
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # ---- hybrid / ssm ----
    block_pattern: Tuple[str, ...] = ("attn",)   # per-layer block kinds, cycled
    lru_width: int = 0               # RG-LRU state width (recurrentgemma)
    conv_width: int = 4              # temporal conv in recurrent block
    rwkv_head_size: int = 64
    # ---- encoder-decoder (whisper) ----
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    max_source_positions: int = 1500
    # ---- misc ----
    attn_backend: str = "auto"       # kernel backend seam: auto|kernel|ref
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    use_bias: bool = False
    tie_embeddings: bool = True
    max_position_embeddings: int = 1_048_576
    learned_positions: bool = False  # whisper decoder
    source: str = ""                 # citation for the config

    # ------------------------------------------------------------------ helpers
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Concrete per-layer block kind for each of num_layers layers."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def padded_vocab(self, shards: int) -> int:
        """Vocab padded to a multiple of the model-axis shard count."""
        v = self.vocab_size
        return ((v + shards - 1) // shards) * shards

    def param_count(self) -> int:
        """Analytic total parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, ff, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d
        per_layer = []
        for kind in self.layer_kinds:
            p = 2 * d  # norms
            if kind == "attn" or kind == "local":
                if self.attn_type == "mla":
                    r, q_heads = self.kv_lora_rank, self.num_heads
                    p += d * (r + self.qk_rope_dim)
                    p += r * q_heads * (self.qk_nope_dim + self.v_head_dim)
                    p += d * q_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    p += q_heads * self.v_head_dim * d
                else:
                    hd = self.head_dim
                    p += d * self.num_heads * hd           # q
                    p += 2 * d * self.num_kv_heads * hd    # k, v
                    p += self.num_heads * hd * d           # o
            elif kind == "rglru":
                w = self.lru_width or d
                p += 2 * d * w + w * d                     # in/out projections
                p += self.conv_width * w + 3 * w           # conv + gates
            elif kind == "rwkv":
                H = d // self.rwkv_head_size
                p += 6 * d * d + H * self.rwkv_head_size   # r,k,v,g,o,w + ln
            if kind == "rwkv":
                p += 2 * d * ff                            # channel mix (k, v)
            elif self.moe and kind != "rwkv":
                p += d * self.num_experts                  # router
                e_ff = self.moe_d_ff
                n_e = self.num_experts + self.num_shared_experts
                p += n_e * 3 * d * e_ff
            else:
                mult = 3 if self.act == "swiglu" else 2
                p += mult * d * ff
            per_layer.append(p)
        n += sum(per_layer)
        if self.is_encoder_decoder:
            # encoder self-attn + mlp, decoder cross-attn already excluded above;
            # approximate: encoder layers mirror decoder self-attn+mlp, plus
            # decoder cross-attention.
            hd = self.head_dim
            enc = self.encoder_layers * (
                2 * d + d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d + 2 * d * ff
            )
            cross = self.num_layers * (
                d + d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d
            )
            n += enc + cross
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: routed experts counted at top-k)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        # remove inactive routed experts
        e_ff = self.moe_d_ff
        n_moe_layers = self.num_layers - self.first_k_dense
        inactive = (self.num_experts - self.experts_per_token)
        full -= n_moe_layers * inactive * 3 * self.d_model * e_ff
        return int(full)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 128),
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            max_source_positions=min(self.max_source_positions, 16),
        )
        if self.num_heads:
            heads = min(self.num_heads, 4)
            kv = max(1, min(self.num_kv_heads, heads))
            small.update(num_heads=heads, num_kv_heads=kv,
                         head_dim=min(self.head_dim or 32, 32))
        if self.moe:
            small.update(num_experts=min(self.num_experts, 4),
                         experts_per_token=min(self.experts_per_token, 2),
                         num_shared_experts=min(self.num_shared_experts, 1),
                         moe_d_ff=min(self.moe_d_ff, 64),
                         first_k_dense=min(self.first_k_dense, 1))
        if self.attn_type == "mla":
            small.update(kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=16,
                         v_head_dim=32, q_lora_rank=0)
        if self.lru_width:
            small.update(lru_width=128)
        if self.family == "ssm":
            small.update(rwkv_head_size=32)
        if self.is_encoder_decoder:
            small.update(encoder_layers=min(self.encoder_layers, 2))
        if self.window:
            small.update(window=8)
        if self.mrope_sections:
            # sections sum to head_dim//2 = 16
            small.update(mrope_sections=(4, 6, 6))
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str         # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
