"""StableLM 2 1.6B — dense, MHA (kv == q heads) [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,       # full MHA
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    act="swiglu",
    norm="layernorm",      # stablelm-2 uses LayerNorm
    rope_theta=10_000.0,
    use_bias=False,
    tie_embeddings=False,
    source="hf:stabilityai/stablelm-2-1_6b",
)
