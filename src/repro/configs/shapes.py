"""The four assigned input shapes (see system brief)."""
from repro.configs.base import (  # re-export
    INPUT_SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, InputShape,
)

__all__ = ["INPUT_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
           "LONG_500K", "InputShape"]
