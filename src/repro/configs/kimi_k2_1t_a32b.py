"""Kimi K2 — trillion-parameter MoE, 384 routed experts top-8 [arXiv:2501.kimi2].

Assignment spec: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384e top-8.  We read d_ff=2048 as the per-expert (and shared-expert) hidden
dim, matching K2's moe_intermediate_size.  Layer 0 is dense (as in K2), with a
dense d_ff equal to the activated expert width (8 x 2048 = 16384).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,        # GQA
    head_dim=128,
    d_ff=16384,            # dense prefix layer width (~= top_k * moe_d_ff)
    vocab_size=163840,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=50_000.0,
    moe=True,
    num_experts=384,
    num_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    first_k_dense=1,
    capacity_factor=1.0,
    tie_embeddings=False,
    source="arXiv:2501.kimi2 (Kimi K2 paper-table)",
)
