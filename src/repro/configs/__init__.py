"""Architecture registry: ``--arch <id>`` resolves through here."""
from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES

from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek
from repro.configs.qwen2_vl_7b import CONFIG as _qwen2vl
from repro.configs.stablelm_1_6b import CONFIG as _stablelm
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma
from repro.configs.rwkv6_7b import CONFIG as _rwkv6
from repro.configs.command_r_35b import CONFIG as _commandr
from repro.configs.llama3_2_3b import CONFIG as _llama32

ARCHS = {c.name: c for c in [
    _tinyllama, _kimi, _whisper, _deepseek, _qwen2vl,
    _stablelm, _recurrentgemma, _rwkv6, _commandr, _llama32,
]}

# (arch, shape) pairs that are architecturally meaningless — see DESIGN.md §3.
SKIPS = {
    ("whisper-large-v3", "long_500k"):
        "encoder-decoder ASR with 30s/448-token context; 500k decode is N/A",
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def all_pairs(include_skips: bool = False):
    for a in ARCHS:
        for s in INPUT_SHAPES:
            if not include_skips and (a, s) in SKIPS:
                continue
            yield a, s
