"""DeepSeek-V2-Lite 16B — MLA + fine-grained MoE [arXiv:2405.04434].

Assignment spec: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6, MLA kv_lora=512, "2 shared + 160 routed top-6".
Note: the assignment's "160 routed" matches DeepSeek-V2 (full), while 64e
matches V2-Lite; we follow the V2-Lite model card (64 routed + 2 shared,
top-6), which is consistent with the "deepseek-v2-lite-16b" identity and the
64e field.  MLA dims follow the model card: q/k nope 128, rope 64, v 128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,       # MLA: kv heads == q heads after up-projection
    head_dim=192,          # qk_nope (128) + qk_rope (64)
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=0,         # V2-Lite has no q compression
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    d_ff=10944,            # dense prefix layer width (model card)
    vocab_size=102400,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    moe=True,
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    first_k_dense=1,
    capacity_factor=1.0,
    tie_embeddings=False,
    source="arXiv:2405.04434 (DeepSeek-V2); hf:deepseek-ai/DeepSeek-V2-Lite",
)
