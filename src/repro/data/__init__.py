"""Training-data management (survey §3.5.1): deterministic synthetic
sources, sharded loading, prefetch, epoch caching, and federated
partitioning."""
from repro.data.pipeline import (LMDataConfig, make_lm_batches,
                                 ShardedLoader, synthetic_lm_batch)
from repro.data.partition import dirichlet_partition, iid_partition

__all__ = ["LMDataConfig", "make_lm_batches", "ShardedLoader",
           "synthetic_lm_batch", "dirichlet_partition", "iid_partition"]
