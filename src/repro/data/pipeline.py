"""Deterministic synthetic data pipeline with sharded loading + prefetch.

The survey (§3.5.1, Ozeri et al. [136], Hoard [142]) identifies training-data
provisioning bandwidth as a scalability bottleneck.  This pipeline has the
production structure — per-worker shards, background prefetch, epoch-level
caching — over a deterministic synthetic source (counter-based hashing), so
every experiment is bit-reproducible without external datasets.

The synthetic LM stream has learnable structure (a noisy Markov chain over
the vocab) so loss curves actually descend.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    batch_size: int = 8
    seed: int = 0
    markov_order: int = 1        # structure strength of the synthetic stream


def _markov_tokens(rng: np.random.RandomState, cfg: LMDataConfig,
                   n_rows: int) -> np.ndarray:
    """Noisy deterministic chain: next = (3 * cur + 7) % V with eps noise."""
    V = cfg.vocab_size
    toks = np.empty((n_rows, cfg.seq_len + 1), dtype=np.int32)
    cur = rng.randint(0, V, size=n_rows)
    for t in range(cfg.seq_len + 1):
        toks[:, t] = cur
        noise = rng.random(n_rows) < 0.1
        nxt = (3 * cur + 7) % V
        cur = np.where(noise, rng.randint(0, V, size=n_rows), nxt)
    return toks


def synthetic_lm_batch(cfg: LMDataConfig, step: int, worker: int = 0
                       ) -> Dict[str, jnp.ndarray]:
    rng = np.random.RandomState((cfg.seed * 1_000_003 + step) * 31 + worker)
    toks = _markov_tokens(rng, cfg, cfg.batch_size)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def make_lm_batches(cfg: LMDataConfig) -> Callable[[int, int], Dict]:
    """(step, worker) -> batch; the non-overlapping-chunks contract of data
    parallelism (survey §3.2.1) holds by construction of the seed."""
    return lambda step, worker=0: synthetic_lm_batch(cfg, step, worker)


class ShardedLoader:
    """Background-prefetching loader over a deterministic batch function.

    Mirrors the structure of a production input pipeline: a reader thread
    fills a bounded queue (the "data server" of Project Adam / Facebook's
    preprocessing tier) while the trainer consumes."""

    def __init__(self, batch_fn: Callable[[int], Any], prefetch: int = 4,
                 num_steps: Optional[int] = None):
        self._fn = batch_fn
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._num = num_steps
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = 0
        while not self._stop.is_set():
            if self._num is not None and step >= self._num:
                self._q.put(None)
                return
            self._q.put(self._fn(step))
            step += 1

    def __iter__(self) -> Iterator[Any]:
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


class EpochCache:
    """Hoard-style [142] local cache: materialize one epoch once, serve all
    subsequent epochs (and co-scheduled jobs) from memory."""

    def __init__(self, batch_fn: Callable[[int], Any], steps_per_epoch: int):
        self._fn = batch_fn
        self._steps = steps_per_epoch
        self._cache: Dict[int, Any] = {}

    def __call__(self, step: int):
        k = step % self._steps
        if k not in self._cache:
            self._cache[k] = self._fn(k)
        return self._cache[k]

    @property
    def hit_ratio_after(self):
        return len(self._cache)
