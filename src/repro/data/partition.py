"""Federated data partitioning (survey §3.3.1(3)): IID vs non-IID splits.

Non-IID uses the standard Dirichlet(alpha) label-skew construction: lower
alpha => each client's label distribution is more concentrated, reproducing
the regime where Nilsson et al. [130] find FedAvg degrades vs centralized.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def make_classification_data(n: int, dim: int, n_classes: int, seed: int = 0
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Gaussian blobs; linearly separable-ish so small MLPs converge fast."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(n_classes, dim) * 3.0
    y = rng.randint(0, n_classes, size=n)
    X = centers[y] + rng.randn(n, dim)
    return X.astype(np.float32), y.astype(np.int32)


def iid_partition(n: int, num_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(idx, num_clients)]


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0,
                        min_per_client: int = 2) -> List[np.ndarray]:
    """Label-skewed non-IID partition via per-class Dirichlet proportions."""
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    client_idx: List[List[int]] = [[] for _ in range(num_clients)]
    for c in range(n_classes):
        idx_c = np.where(labels == c)[0]
        rng.shuffle(idx_c)
        props = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx_c, cuts)):
            client_idx[client].extend(part.tolist())
    # ensure no client is empty
    for ci in range(num_clients):
        while len(client_idx[ci]) < min_per_client:
            donor = int(np.argmax([len(x) for x in client_idx]))
            client_idx[ci].append(client_idx[donor].pop())
    return [np.sort(np.array(ix, dtype=np.int64)) for ix in client_idx]


def stream_assignment(n_streams: int, num_workers: int,
                      seed: int = 0) -> List[List[int]]:
    """Deterministic worker→streams map for elastic resizing.

    A job keeps ``n_streams`` logical data streams (one per worker at its
    nominal size); when the scheduler resizes it to ``num_workers``, each
    worker slot covers an ordered list of streams: its own at nominal
    size, one ``iid_partition`` part when shrunk (the M workers *cover*
    all N streams, rotating within their part), round-robin wrap when
    grown beyond the stream count.  Pure in (n_streams, num_workers,
    seed), so the sim and device backends repartition identically."""
    if num_workers == n_streams:
        return [[s] for s in range(n_streams)]
    if num_workers < n_streams:
        parts = iid_partition(n_streams, num_workers, seed)
        return [[int(s) for s in p] for p in parts]
    return [[w % n_streams] for w in range(num_workers)]


def label_skew(partitions: List[np.ndarray], labels: np.ndarray) -> float:
    """Mean total-variation distance of client label dists from global."""
    n_classes = int(labels.max()) + 1
    global_p = np.bincount(labels, minlength=n_classes) / len(labels)
    tvs = []
    for part in partitions:
        p = np.bincount(labels[part], minlength=n_classes) / max(len(part), 1)
        tvs.append(0.5 * np.abs(p - global_p).sum())
    return float(np.mean(tvs))
