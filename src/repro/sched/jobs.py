"""DL training jobs with the DL-specific structure the survey highlights
(§3.4.2): diminishing-returns loss curves, known epoch times, and
scale-out efficiency."""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Job:
    jid: int
    arrival: float
    num_gpus: int                 # requested degree of data parallelism
    epochs: int
    epoch_time_1gpu: float        # seconds per epoch on 1 GPU
    scaling_alpha: float = 0.9    # parallel efficiency exponent: t = t1 / n^a
    loss0: float = 5.0
    loss_floor: float = 1.0
    loss_decay: float = 0.15      # loss(e) = floor + (l0-floor) e^{-decay e}

    # runtime state (filled by the simulator)
    start: Optional[float] = None
    finish: Optional[float] = None
    epochs_done: float = 0.0

    def epoch_time(self, n_gpus: int) -> float:
        return self.epoch_time_1gpu / (max(n_gpus, 1) ** self.scaling_alpha)

    def loss_at(self, epochs: float) -> float:
        return (self.loss_floor + (self.loss0 - self.loss_floor)
                * math.exp(-self.loss_decay * epochs))

    def marginal_progress(self) -> float:
        """Loss improvement of the next epoch — the Optimus/SLAQ quality
        signal (early epochs are worth more)."""
        return self.loss_at(self.epochs_done) - self.loss_at(self.epochs_done + 1)

    @property
    def remaining_epochs(self) -> float:
        return self.epochs - self.epochs_done

    def remaining_time(self, n_gpus: Optional[int] = None) -> float:
        return self.remaining_epochs * self.epoch_time(n_gpus or self.num_gpus)


def make_trace(n_jobs: int, n_gpus_cluster: int, seed: int = 0,
               mean_interarrival: float = 60.0) -> List[Job]:
    rng = np.random.RandomState(seed)
    jobs = []
    t = 0.0
    for j in range(n_jobs):
        t += rng.exponential(mean_interarrival)
        jobs.append(Job(
            jid=j,
            arrival=t,
            num_gpus=int(rng.choice([1, 2, 4, 8],
                                    p=[0.4, 0.3, 0.2, 0.1])),
            epochs=int(rng.randint(5, 40)),
            epoch_time_1gpu=float(rng.uniform(30, 300)),
            scaling_alpha=float(rng.uniform(0.7, 0.95)),
            loss_decay=float(rng.uniform(0.05, 0.3)),
        ))
    return jobs
