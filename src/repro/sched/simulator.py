"""Discrete-event multi-tenant scheduling simulator (survey §3.4.2).

Events: job arrival, job finish, re-schedule quantum.  The policy reorders
the queue at every event; `gandiva=True` adds time-slicing (suspend/resume
at a fixed quantum — Gandiva's introspective primitive) so more jobs make
early progress (which is where the DL loss curves earn the most).

Outputs per policy: makespan, average JCT, mean time-to-90%-quality —
the metrics the survey's scheduling papers optimize.

Every allocation decision is also recorded as a ``TraceEvent`` stream
(start/suspend/resume/finish with the granted GPU count), and
``elastic=True`` lets a queued job start *shrunk* (largest power-of-two
share of the free GPUs) instead of waiting for its full request — so a
sliced-out job may resume at a different size.  The trace is what
``repro.elastic.events.plan_from_sched_trace`` converts into an elastic
training plan, closing the scheduler↔trainer loop.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, NamedTuple, Optional

from repro.sched.cluster import Cluster
from repro.sched.jobs import Job
from repro.sched.policies import GANDIVA_SLICE, POLICIES


class TraceEvent(NamedTuple):
    """One allocation decision: job ``jid`` started / was suspended /
    resumed / finished at time ``t`` holding ``gpus`` GPUs."""
    t: float
    jid: int
    kind: str               # start | suspend | resume | finish
    gpus: int


@dataclasses.dataclass
class SimResult:
    policy: str
    makespan: float
    avg_jct: float
    avg_queue_delay: float
    mean_t90: float          # mean time until 90% of final quality reached
    events: int
    trace: List[TraceEvent] = dataclasses.field(default_factory=list)


def simulate(jobs: List[Job], cluster: Cluster, policy: str = "fifo",
             gandiva: bool = False, quantum: float = GANDIVA_SLICE,
             elastic: bool = False) -> SimResult:
    order_fn = POLICIES[policy]
    jobs = [dataclasses.replace(j) for j in jobs]      # fresh copies
    for j in jobs:
        j.start, j.finish, j.epochs_done = None, None, 0.0

    # event heap: (time, seq, kind, jid)
    ev: List = []
    seq = 0
    for j in jobs:
        heapq.heappush(ev, (j.arrival, seq, "arrive", j.jid)); seq += 1
    by_id = {j.jid: j for j in jobs}
    queue: List[Job] = []
    running: Dict[int, dict] = {}       # jid -> {rate, last_update, gpus}
    t90: Dict[int, float] = {}
    trace: List[TraceEvent] = []
    started: set = set()
    now = 0.0
    n_events = 0

    def progress_to(t: float):
        for jid, st in running.items():
            j = by_id[jid]
            dt = t - st["last"]
            j.epochs_done = min(j.epochs,
                                j.epochs_done + dt / st["sec_per_epoch"])
            st["last"] = t
            if jid not in t90 and j.epochs_done >= 0.9 * j.epochs:
                frac = j.epochs_done / j.epochs
                t90[jid] = t if frac >= 0.9 else t
        # t90 approximation: first event time at/after crossing

    def try_start():
        nonlocal seq
        for j in order_fn(queue, now):
            n = j.num_gpus
            slowdown = cluster.try_alloc(j.jid, n)
            if slowdown is None and elastic and cluster.free_gpus > 0:
                # elastic shrink: run now on the largest power-of-two
                # share of the free GPUs instead of queueing for the full
                # request (the job resumes resized — the trainer reshards)
                n = 1
                while n * 2 <= min(cluster.free_gpus, j.num_gpus):
                    n *= 2
                slowdown = cluster.try_alloc(j.jid, n)
            if slowdown is None:
                continue
            queue.remove(j)
            if j.start is None:
                j.start = now
            spe = j.epoch_time(n) * slowdown
            running[j.jid] = {"sec_per_epoch": spe, "last": now, "gpus": n}
            trace.append(TraceEvent(
                now, j.jid,
                "start" if j.jid not in started else "resume", n))
            started.add(j.jid)
            eta = now + j.remaining_epochs * spe
            heapq.heappush(ev, (eta, seq, "finish", j.jid)); seq += 1
            if gandiva:
                heapq.heappush(ev, (now + quantum, seq, "slice", j.jid))
                seq += 1

    while ev:
        now, _, kind, jid = heapq.heappop(ev)
        n_events += 1
        j = by_id[jid]
        progress_to(now)
        if kind == "arrive":
            queue.append(j)
            try_start()
        elif kind == "finish":
            if jid not in running:
                continue                    # stale event (job was sliced out)
            if j.remaining_epochs > 1e-6:
                continue                    # stale eta from before a slice
            st = running.pop(jid)
            cluster.release(jid)
            j.finish = now
            t90.setdefault(jid, now)
            trace.append(TraceEvent(now, jid, "finish", st["gpus"]))
            try_start()
        elif kind == "slice":
            if jid not in running or j.remaining_epochs <= 1e-6:
                continue
            # suspend and requeue (Gandiva suspend-resume)
            st = running.pop(jid)
            cluster.release(jid)
            queue.append(j)
            trace.append(TraceEvent(now, jid, "suspend", st["gpus"]))
            try_start()

    done = [j for j in jobs if j.finish is not None]
    makespan = max((j.finish for j in done), default=0.0)
    avg_jct = (sum(j.finish - j.arrival for j in done) / len(done)
               if done else float("inf"))
    avg_qd = (sum((j.start or j.arrival) - j.arrival for j in done)
              / len(done) if done else 0.0)
    mean_t90 = (sum(t90[j.jid] - j.arrival for j in done if j.jid in t90)
                / max(1, len(done)))
    return SimResult(policy + ("+gandiva" if gandiva else ""), makespan,
                     avg_jct, avg_qd, mean_t90, n_events, trace)
