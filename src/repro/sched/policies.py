"""Multi-tenant scheduling policies (survey §3.4.2).

Each policy orders the waiting queue and may resize jobs:
  fifo     : arrival order (the YARN/Borg baseline)
  srtf     : shortest remaining time first
  optimus  : maximize marginal progress per GPU-second [Peng et al., 141]
  gandiva  : fifo + time-slicing oversubscription [Xiao et al., 195]
  slaq     : max-min quality fairness [Zhang et al., 205]
"""
from __future__ import annotations

from typing import List

from repro.sched.jobs import Job


def fifo(queue: List[Job], now: float) -> List[Job]:
    return sorted(queue, key=lambda j: j.arrival)


def srtf(queue: List[Job], now: float) -> List[Job]:
    return sorted(queue, key=lambda j: j.remaining_time())


def optimus(queue: List[Job], now: float) -> List[Job]:
    def utility(j: Job) -> float:
        dt = j.epoch_time(j.num_gpus) * j.num_gpus   # GPU-seconds per epoch
        return -(j.marginal_progress() / max(dt, 1e-9))
    return sorted(queue, key=utility)


def slaq(queue: List[Job], now: float) -> List[Job]:
    # serve the job whose current loss is worst (max-min quality)
    return sorted(queue, key=lambda j: -j.loss_at(j.epochs_done))


POLICIES = {"fifo": fifo, "srtf": srtf, "optimus": optimus, "slaq": slaq}
GANDIVA_SLICE = 60.0   # time-slice quantum (s) for the gandiva variant
