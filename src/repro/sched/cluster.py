"""Cluster model: nodes x GPUs with locality (survey §3.4.2, Jeon et al.
[78]: locality + interference are first-order scheduler concerns)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Cluster:
    n_nodes: int = 4
    gpus_per_node: int = 8
    # fragmentation penalty: cross-node jobs run this much slower
    cross_node_penalty: float = 1.15

    def __post_init__(self):
        self.free: List[int] = [self.gpus_per_node] * self.n_nodes
        self.alloc: Dict[int, List[Tuple[int, int]]] = {}

    @property
    def total_gpus(self) -> int:
        return self.n_nodes * self.gpus_per_node

    @property
    def free_gpus(self) -> int:
        return sum(self.free)

    def try_alloc(self, jid: int, n: int) -> Optional[float]:
        """Allocate n GPUs; prefer single-node packing (locality).  Returns
        the slowdown factor (1.0 local, penalty if spread), or None."""
        if n > self.free_gpus:
            return None
        # best-fit single node
        candidates = [i for i in range(self.n_nodes) if self.free[i] >= n]
        if candidates:
            node = min(candidates, key=lambda i: self.free[i])
            self.free[node] -= n
            self.alloc[jid] = [(node, n)]
            return 1.0
        # spread across nodes (fragmented)
        left = n
        parts = []
        for i in sorted(range(self.n_nodes), key=lambda i: -self.free[i]):
            take = min(self.free[i], left)
            if take:
                self.free[i] -= take
                parts.append((i, take))
                left -= take
            if not left:
                break
        self.alloc[jid] = parts
        return self.cross_node_penalty

    def release(self, jid: int):
        for node, n in self.alloc.pop(jid, []):
            self.free[node] += n
