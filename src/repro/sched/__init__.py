"""Resource scheduling & elasticity (survey §3.4): a discrete-event
multi-tenant GPU-cluster simulator with pluggable policies."""
from repro.sched.jobs import Job, make_trace
from repro.sched.cluster import Cluster
from repro.sched.policies import POLICIES
from repro.sched.simulator import SimResult, TraceEvent, simulate

__all__ = ["Job", "make_trace", "Cluster", "POLICIES", "simulate",
           "SimResult", "TraceEvent"]
