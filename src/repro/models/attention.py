"""GQA / MQA / MHA attention with full, sliding-window, and cross variants.

Pure-jnp reference math is the oracle; the Pallas kernels in
``repro.kernels.flash_attention`` are routed in through the kernel backend
seam.  ``attention_forward`` / ``attention_decode`` take a ``backend``
argument (default: the model config's ``attn_backend`` field, ``"auto"``)
resolved by ``repro.kernels.backend.resolve_backend`` — ``kernel`` runs the
flash forward (with a reference-math VJP for training) and the streaming
decode kernel; ``ref`` keeps the jnp expressions below bit-for-bit.
Cross-attention (``kv_x`` / ``cross_kv``) always uses the reference path:
its keys come from a different sequence length and carry the decode
sharding hints the kernel does not model.

Cache layouts
-------------
full   : {"k": [B, Smax, KV, hd], "v": [B, Smax, KV, hd]}  write at position t
window : {"k": [B, W,    KV, hd], "v": ...}                ring buffer, write at t % W
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as FA
from repro.kernels.backend import kernel_interpret, resolve_backend
from repro.models.common import dense, dense_init, apply_rope, apply_mrope

NEG_INF = -1e9


def attn_init(key, cfg, dtype=jnp.float32, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, H * hd, cfg.use_bias, dtype),
        "wk": dense_init(ks[1], d, KV * hd, cfg.use_bias, dtype),
        "wv": dense_init(ks[2], d, KV * hd, cfg.use_bias, dtype),
        "wo": dense_init(ks[3], H * hd, d, cfg.use_bias, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _sdpa(q, k, v, mask, decode_hints: bool = False):
    """q [B,Sq,H,hd] k/v [B,Sk,H,hd] mask [B,1,Sq,Sk] or broadcastable."""
    from repro.core.parallelism import attn_decode_constraint
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    if decode_hints:
        scores = attn_decode_constraint(scores, "scores")
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    if decode_hints:
        out = attn_decode_constraint(out, "out")
    return out


def _causal_mask(sq, sk, offset=0):
    """query i (global pos offset+i) may see key j<=offset+i."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    return (kj <= qi)[None, None]


def _window_mask(sq, sk, window, offset=0):
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    return ((kj <= qi) & (kj > qi - window))[None, None]


def attention_forward(p, x, positions, cfg, *, causal=True, window=0,
                      kv_x=None, use_rope=True, backend=None):
    """Training / prefill / encoder forward.

    kv_x: if given, cross-attention keys/values come from kv_x (no rope).
    backend: kernel backend ("auto" | "kernel" | "ref"); None reads the
    config's ``attn_backend``.  The kernel path feeds the *unrepeated* k/v
    straight to the flash kernel (GQA folds in the BlockSpec index map).
    Returns (out, cache) where cache has the full k/v (for prefill reuse).
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if backend is None:
        backend = getattr(cfg, "attn_backend", "auto")
    src = x if kv_x is None else kv_x
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], src), KV, hd)
    v = _split_heads(dense(p["wv"], src), KV, hd)
    if use_rope and kv_x is None:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    if kv_x is None and resolve_backend(backend) == "kernel":
        out = FA.attention_grad(q, k, v, causal=causal,
                                window=window if causal else 0,
                                interpret=kernel_interpret())
    else:
        kr = _repeat_kv(k, H // KV)
        vr = _repeat_kv(v, H // KV)
        sq, sk = q.shape[1], kr.shape[1]
        if kv_x is not None:
            mask = jnp.ones((1, 1, sq, sk), dtype=bool)
        elif not causal:
            mask = jnp.ones((1, 1, sq, sk), dtype=bool)
        elif window:
            mask = _window_mask(sq, sk, window)
        else:
            mask = _causal_mask(sq, sk)
        out = _sdpa(q, kr, vr, mask)
    out = dense(p["wo"], out.reshape(out.shape[:2] + (H * hd,)))
    return out, {"k": k, "v": v}


def init_cache(cfg, batch: int, max_len: int, dtype, window: int = 0):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    L = window if window else max_len
    return {"k": jnp.zeros((batch, L, KV, hd), dtype=dtype),
            "v": jnp.zeros((batch, L, KV, hd), dtype=dtype)}


def attention_decode(p, x, pos, cache, cfg, *, window=0, cross_kv=None,
                     use_rope=True, backend=None):
    """One-token decode step.  x [B,1,d]; pos scalar int32 (same for batch).

    window > 0 -> ring-buffer cache of that length (sub-quadratic decode).
    cross_kv -> (k, v) precomputed encoder keys/values; cache unused.
    backend: kernel backend seam (None reads the config's ``attn_backend``);
    the kernel path streams the cache through ``flash_decode``.
    Returns (out [B,1,d], new_cache).
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if backend is None:
        backend = getattr(cfg, "attn_backend", "auto")
    B = x.shape[0]
    q = _split_heads(dense(p["wq"], x), H, hd)
    if cross_kv is not None:
        kr = _repeat_kv(cross_kv["k"], H // KV)
        vr = _repeat_kv(cross_kv["v"], H // KV)
        mask = jnp.ones((1, 1, 1, kr.shape[1]), dtype=bool)
        out = _sdpa(q, kr, vr, mask, decode_hints=True)
        out = dense(p["wo"], out.reshape(B, 1, H * hd))
        return out, cache

    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    posb = jnp.broadcast_to(jnp.asarray(pos)[None, None], (B, 1))
    if use_rope:
        if cfg.mrope_sections:
            pos3 = jnp.broadcast_to(jnp.asarray(pos)[None, None, None],
                                    (B, 3, 1))
            q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, posb, cfg.rope_theta)
            k = apply_rope(k, posb, cfg.rope_theta)

    from repro.core.parallelism import attn_decode_constraint
    L = cache["k"].shape[1]
    slot = (pos % window) if window else pos
    k = attn_decode_constraint(k, "cache4d")
    v = attn_decode_constraint(v, "cache4d")
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    ck = attn_decode_constraint(ck, "cache4d")
    cv = attn_decode_constraint(cv, "cache4d")
    if resolve_backend(backend) == "kernel":
        out = FA.decode(q, ck, cv, jnp.asarray(pos, jnp.int32),
                        window=window, interpret=kernel_interpret())
    else:
        idx = jnp.arange(L)
        if window:
            # slot j holds global position p_j with p_j % W == j and
            # p_j <= pos; valid iff pos - p_j < W <=> p_j > pos - W, >= 0.
            age = (pos - idx) % window        # steps since slot was written
            mask1d = (pos - age) >= 0
        else:
            mask1d = idx <= pos
        out = _gqa_decode_sdpa(q, ck, cv, mask1d)
    out = dense(p["wo"], out.reshape(B, 1, H * hd))
    return out, {"k": ck, "v": cv}


def _gqa_decode_sdpa(q, ck, cv, mask1d):
    """Grouped-query decode attention WITHOUT materializing repeated K/V.

    q [B,1,H,hd]; ck/cv [B,L,KV,hd]; mask1d [L].  The repeat-free grouped
    einsum keeps the cache in its stored layout — on TPU this avoids an
    H/KV-fold HBM blow-up, and under GSPMD it stops the partitioner from
    replicating the repeated cache (EXPERIMENTS.md §Perf iter 4)."""
    from repro.core.parallelism import attn_decode_constraint
    B, _, H, hd = q.shape
    KV = ck.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    qg = attn_decode_constraint(qg, "q5d")
    scores = jnp.einsum("bqkgd,blkd->bkgql", qg.astype(jnp.float32),
                        ck.astype(jnp.float32))       # [B,KV,G,1,L]
    scores = attn_decode_constraint(scores, "scores5d")
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask1d[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgql,blkd->bqkgd", probs.astype(cv.dtype), cv)
    out = attn_decode_constraint(out, "out5d")
    return out.reshape(B, 1, H, hd)
