"""Unified decoder-only LM covering dense / moe / vlm / hybrid / ssm families.

Layer stacks are grouped into homogeneous segments and executed with
``jax.lax.scan`` so that compile time and HLO size stay bounded for the
61-layer / trillion-parameter dry-run configs.  Heterogeneous block patterns
(recurrentgemma's rglru-rglru-local) scan over *groups* of the pattern.

Everything is eval_shape friendly: the multi-pod dry-run abstract-inits the
params with ``jax.eval_shape`` and lowers against ShapeDtypeStructs only.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (cross_entropy, dense, mlp_apply, mlp_init,
                                 norm_apply, norm_init)
from repro.models.moe import moe_apply, moe_init


# --------------------------------------------------------------- segment plan
def plan_segments(cfg: ModelConfig) -> List[Tuple[str, Any]]:
    """Returns [("plain", sig) | ("scan", (sig, ...), n_groups), ...] where a
    sig is (kind, use_moe)."""
    sigs = []
    for i, kind in enumerate(cfg.layer_kinds):
        use_moe = bool(cfg.moe and i >= cfg.first_k_dense
                       and kind in ("attn", "local"))
        sigs.append((kind, use_moe))
    segments: List[Tuple[str, Any]] = []
    i = 0
    # plain prefix (dense-before-MoE layers)
    while i < len(sigs) and cfg.moe and i < cfg.first_k_dense:
        segments.append(("plain", sigs[i]))
        i += 1
    pat_len = len(cfg.block_pattern)
    remaining = sigs[i:]
    pattern = tuple(remaining[:pat_len]) if remaining else ()
    n_groups = 0
    while (n_groups + 1) * pat_len <= len(remaining) and all(
            remaining[n_groups * pat_len + j] == pattern[j]
            for j in range(pat_len)):
        n_groups += 1
    if n_groups:
        segments.append(("scan", pattern, n_groups))
        i += n_groups * pat_len
    for sig in sigs[i:]:
        segments.append(("plain", sig))
    return segments


# ------------------------------------------------------------------ layer ops
def _layer_init(key, cfg: ModelConfig, sig, dtype):
    kind, use_moe = sig
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": norm_init(cfg.norm, cfg.d_model),
                         "ln2": norm_init(cfg.norm, cfg.d_model)}
    if kind in ("attn", "local"):
        if cfg.attn_type == "mla":
            p["mixer"] = mla_mod.mla_init(ks[0], cfg, dtype)
        else:
            p["mixer"] = attn.attn_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.rglru_init(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["mixer"] = rwkv_mod.rwkv_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        pass  # channel mix lives inside rwkv params
    elif use_moe:
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.use_bias, dtype)
    return p


def _layer_forward(p, cfg: ModelConfig, sig, x, positions, state=None,
                   window_override=0):
    """Full-sequence forward for one layer.  Returns (x, aux, new_state).
    state is only used/returned for stateful kinds (cache build in prefill)."""
    kind, use_moe = sig
    aux = jnp.float32(0.0)
    h = norm_apply(cfg.norm, p["ln1"], x, cfg.norm_eps)
    new_state = None
    if kind in ("attn", "local"):
        if cfg.attn_type == "mla":
            out, new_state = mla_mod.mla_forward(p["mixer"], h, positions, cfg)
        else:
            window = cfg.window if kind == "local" else window_override
            out, new_state = attn.attention_forward(
                p["mixer"], h, positions, cfg, causal=True, window=window)
    elif kind == "rglru":
        out, (h_last, conv_buf) = rglru_mod.rglru_forward(p["mixer"], h)
        new_state = {"h": h_last, "conv": conv_buf}
    elif kind == "rwkv":
        out, new_state_tm = rwkv_mod.time_mix_forward(p["mixer"], h, cfg)
        x = x + out
        h2 = norm_apply(cfg.norm, p["ln2"], x, cfg.norm_eps)
        out2, shift_cm = rwkv_mod.channel_mix_forward(p["mixer"], h2, cfg)
        new_state = {"S": new_state_tm["S"], "shift_tm": new_state_tm["shift"],
                     "shift_cm": shift_cm}
        return x + out2, aux, new_state
    x = x + out
    h = norm_apply(cfg.norm, p["ln2"], x, cfg.norm_eps)
    if use_moe:
        out, aux = moe_apply(p["moe"], h, cfg)
    else:
        out = mlp_apply(p["mlp"], h, cfg.act)
    return x + out, aux, new_state


def _layer_decode(p, cfg: ModelConfig, sig, x, pos, cache, window_override=0,
                  tp_axis=None):
    """One-token decode for one layer.  Returns (x, new_cache).

    tp_axis: when set (tensor-parallel decode under shard_map), the mixer
    and MLP outputs are row-parallel partial products — sum them across
    the tensor axis with ``tensor_reduce`` before each residual add.
    Only plain GQA attention layers support this (the serving engine
    gates admission accordingly)."""
    kind, use_moe = sig
    if tp_axis is not None and (use_moe or kind not in ("attn", "local")
                                or cfg.attn_type == "mla"):
        raise ValueError(
            f"tensor-parallel decode supports dense GQA layers only "
            f"(got kind={kind}, moe={use_moe}, attn_type={cfg.attn_type})")
    if tp_axis is not None:
        from repro.parallel.staged import tensor_copy, tensor_reduce
        t_copy, t_reduce = tensor_copy(tp_axis), tensor_reduce(tp_axis)
    else:
        t_copy = t_reduce = lambda y: y
    if kind == "rwkv":
        return rwkv_mod.rwkv_block_decode(
            p["mixer"], p["mixer"], p["ln1"], p["ln2"], cfg, x, cache)
    h = norm_apply(cfg.norm, p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "local"):
        if cfg.attn_type == "mla":
            out, new_cache = mla_mod.mla_decode(p["mixer"], h, pos, cache, cfg)
        else:
            window = cfg.window if kind == "local" else window_override
            out, new_cache = attn.attention_decode(
                p["mixer"], t_copy(h), pos, cache, cfg, window=window)
            out = t_reduce(out)
    elif kind == "rglru":
        out, new_cache = rglru_mod.rglru_decode(p["mixer"], h, cache)
    else:
        raise ValueError(kind)
    x = x + out
    h = norm_apply(cfg.norm, p["ln2"], x, cfg.norm_eps)
    if use_moe:
        out, _ = moe_apply(p["moe"], h, cfg)
    else:
        out = t_reduce(mlp_apply(p["mlp"], t_copy(h), cfg.act))
    return x + out, new_cache


def _layer_cache(cfg: ModelConfig, sig, batch, max_len, dtype,
                 window_override=0):
    kind, _ = sig
    if kind in ("attn", "local"):
        if cfg.attn_type == "mla":
            return mla_mod.mla_init_cache(cfg, batch, max_len, dtype)
        window = cfg.window if kind == "local" else window_override
        return attn.init_cache(cfg, batch, max_len, dtype, window=window)
    if kind == "rglru":
        return rglru_mod.rglru_init_state(cfg, batch, dtype)
    if kind == "rwkv":
        return rwkv_mod.rwkv_init_state(cfg, batch, dtype)
    raise ValueError(kind)


# ----------------------------------------------------------------- model init
def init_params(key, cfg: ModelConfig, dtype=jnp.float32,
                vocab_pad_multiple: int = 1):
    vpad = cfg.padded_vocab(vocab_pad_multiple)
    segs = plan_segments(cfg)
    keys = jax.random.split(key, len(segs) + 2)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (vpad, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (cfg.d_model, vpad))
                             / np.sqrt(cfg.d_model)).astype(dtype)
    for si, seg in enumerate(segs):
        k = keys[2 + si]
        if seg[0] == "plain":
            params["segments"].append(_layer_init(k, cfg, seg[1], dtype))
        else:
            _, pattern, n_groups = seg

            def group_init(gk, _pattern=pattern):
                gks = jax.random.split(gk, len(_pattern))
                return tuple(_layer_init(gks[j], cfg, _pattern[j], dtype)
                             for j in range(len(_pattern)))
            params["segments"].append(
                jax.vmap(group_init)(jax.random.split(k, n_groups)))
    return params


# ------------------------------------------------------------------- forward
def forward(params, cfg: ModelConfig, tokens, positions=None,
            vision_embeds=None, compute_dtype=jnp.bfloat16,
            return_cache: bool = False, cache_len: int = 0,
            remat: bool = False, unroll: bool = False,
            window_override: int = 0):
    """Full-sequence forward.  Returns (logits, aux, caches|None).

    tokens [B, S] int32.  positions: [B, S] (or [B, 3, S] for M-RoPE).
    vision_embeds [B, P, d]: merged into the leading P token slots (vlm stub).
    window_override: sliding-window mask for plain attention layers — the
    prefill-side twin of ``decode_step``'s ring-buffer override, so a
    windowed serve's batched prefill attends exactly what its decode would.
    """
    B, S = tokens.shape
    segs = plan_segments(cfg)
    x = params["embed"].astype(compute_dtype)[tokens]
    if vision_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(compute_dtype), (0, 0, 0))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[:, None], (B, 3, S))
    aux_total = jnp.float32(0.0)
    caches: List[Any] = []

    seg_i = 0
    for seg in segs:
        p_seg = params["segments"][seg_i]
        seg_i += 1
        if seg[0] == "plain":
            x, aux, st = _layer_forward(p_seg, cfg, seg[1], x, positions,
                                        window_override=window_override)
            aux_total = aux_total + aux
            if return_cache:
                caches.append(st)
        else:
            _, pattern, n_groups = seg

            def body(carry, g_params, _pattern=pattern):
                xc, auxc = carry
                sts = []
                for j, sig in enumerate(_pattern):
                    xc, aux_j, st_j = _layer_forward(
                        g_params[j], cfg, sig, xc, positions,
                        window_override=window_override)
                    auxc = auxc + aux_j
                    sts.append(st_j)
                return (xc, auxc), tuple(sts)

            if remat and not return_cache:
                body = jax.checkpoint(body)   # per-layer-group activation remat
            if unroll:
                # analysis-only path: XLA cost_analysis counts while-loop
                # bodies once, so the roofline dry-run unrolls the stack
                seg_states_l = []
                carry = (x, aux_total)
                for gi in range(n_groups):
                    g_params = jax.tree.map(lambda a, _g=gi: a[_g], p_seg)
                    carry, sts = body(carry, g_params)
                    seg_states_l.append(sts)
                (x, aux_total) = carry
                seg_states = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *seg_states_l)
            else:
                (x, aux_total), seg_states = jax.lax.scan(
                    body, (x, aux_total), p_seg)
            if return_cache:
                caches.append(seg_states)
    x = norm_apply(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(compute_dtype).T
    else:
        logits = dense({"w": params["lm_head"]}, x)
    return logits, aux_total, (caches if return_cache else None)


def loss_fn(params, cfg: ModelConfig, batch, compute_dtype=jnp.bfloat16,
            remat: bool = False, unroll: bool = False):
    """Next-token CE + MoE aux.  batch: {tokens, labels[, mask, positions,
    vision_embeds]}."""
    logits, aux, _ = forward(
        params, cfg, batch["tokens"], positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"), compute_dtype=compute_dtype,
        remat=remat, unroll=unroll)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"),
                       vocab_size=cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, window_override: int = 0):
    """Cache pytree mirroring the segment plan.  For scan segments the
    per-layer caches are stacked on a leading group axis."""
    segs = plan_segments(cfg)
    caches: List[Any] = []
    for seg in segs:
        if seg[0] == "plain":
            caches.append(_layer_cache(cfg, seg[1], batch, max_len, dtype,
                                       window_override))
        else:
            _, pattern, n_groups = seg
            group = tuple(_layer_cache(cfg, sig, batch, max_len, dtype,
                                       window_override) for sig in pattern)
            caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape),
                group))
    return caches


def decode_step(params, cfg: ModelConfig, caches, token, pos,
                compute_dtype=jnp.bfloat16, window_override: int = 0,
                unroll: bool = False, tp_axis: Optional[str] = None):
    """One decode step.  token [B, 1] int32; pos scalar int32 (position of
    this token).  Returns (logits [B, 1, Vpad], new_caches).

    tp_axis: tensor-parallel decode (serving).  Inside a ``shard_map``
    over mesh axis ``tp_axis`` with head-sharded attention weights and
    column/row-sharded MLP weights, each rank computes its head/ff shard
    and the two row-parallel products (wo, w_down) are combined with
    ``tensor_reduce`` before the residual adds — Megatron's f/g pair from
    ``repro.parallel.staged``, reused for inference."""
    segs = plan_segments(cfg)
    x = params["embed"].astype(compute_dtype)[token]
    new_caches: List[Any] = []
    for seg, p_seg, c_seg in zip(segs, params["segments"], caches):
        if seg[0] == "plain":
            x, nc = _layer_decode(p_seg, cfg, seg[1], x, pos, c_seg,
                                  window_override, tp_axis)
            new_caches.append(nc)
        else:
            _, pattern, n_groups = seg

            def body(xc, inp, _pattern=pattern):
                g_params, g_cache = inp
                ncs = []
                for j, sig in enumerate(_pattern):
                    xc, nc_j = _layer_decode(g_params[j], cfg, sig, xc, pos,
                                             g_cache[j], window_override,
                                             tp_axis)
                    ncs.append(nc_j)
                return xc, tuple(ncs)

            if unroll:
                caches_l = []
                for gi in range(n_groups):
                    inp = jax.tree.map(lambda a, _g=gi: a[_g],
                                       (p_seg, c_seg))
                    x, ncs = body(x, inp)
                    caches_l.append(ncs)
                seg_caches = jax.tree.map(lambda *xs: jnp.stack(xs),
                                          *caches_l)
            else:
                x, seg_caches = jax.lax.scan(body, x, (p_seg, c_seg))
            new_caches.append(seg_caches)
    x = norm_apply(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(compute_dtype).T
    else:
        logits = dense({"w": params["lm_head"]}, x)
    return logits, new_caches


def prefill(params, cfg: ModelConfig, tokens, positions=None,
            vision_embeds=None, compute_dtype=jnp.bfloat16,
            unroll: bool = False, window_override: int = 0):
    """Prefill: forward over the prompt, returning last-token logits and the
    populated caches (full-length attention caches / final recurrent states)."""
    logits, _, caches = forward(params, cfg, tokens, positions=positions,
                                vision_embeds=vision_embeds,
                                compute_dtype=compute_dtype,
                                return_cache=True, unroll=unroll,
                                window_override=window_override)
    return logits[:, -1:], caches


def _state_to_cache(cfg: ModelConfig, sig, st, max_len: int, dtype,
                    window_override: int = 0):
    """Convert one layer's prefill state into its ``init_cache`` decode
    layout.  Leaves may carry leading stacked dims (scan groups) — the
    sequence axis is located from the *end* per kind, so the same rule
    maps plain and group-stacked states."""
    kind, _ = sig
    if kind in ("attn", "local"):
        if cfg.attn_type == "mla":
            seq_from_end, window = 2, 0          # [.., B, S, r]
        else:
            seq_from_end = 3                     # [.., B, S, KV, hd]
            window = cfg.window if kind == "local" else window_override
        L = window if window else max_len

        def fill(a):
            ax = a.ndim - seq_from_end
            S = a.shape[ax]
            if not window and S > max_len:
                raise ValueError(f"prompt length {S} > max_len {max_len}")
            # position t lives at slot t (full) / t % window (ring buffer);
            # only the last `window` positions survive in a ring cache
            start = max(0, S - window) if window else 0
            ts = np.arange(start, S)
            slots = ts % window if window else ts
            am = jnp.moveaxis(a.astype(dtype), ax, 0)
            om = jnp.zeros((L,) + am.shape[1:], dtype=dtype)
            om = om.at[slots].set(am[ts])
            return jnp.moveaxis(om, 0, ax)

        return jax.tree.map(fill, st)
    # recurrent kinds (rglru / rwkv): the final forward state *is* the
    # decode cache — align each leaf's dtype with the init_cache template
    # (e.g. rwkv keeps its S matrix in float32 regardless of cache dtype)
    tmpl = _layer_cache(cfg, sig, 1, max_len, dtype, window_override)
    return jax.tree.map(lambda t, s: s.astype(t.dtype), tmpl, st)


def cache_from_prefill(cfg: ModelConfig, fwd_caches, max_len: int,
                       dtype=jnp.bfloat16, window_override: int = 0):
    """Cache-page plumbing for the serving plane: convert the states of
    ``forward(..., return_cache=True)`` / ``prefill`` into the decode-cache
    pytree ``init_cache`` lays out (attention k/v scattered to their
    full-length or ring-buffer slots, recurrent states passed through), so
    a prompt is consumed by ONE batched forward pass instead of a
    token-by-token warm-up loop."""
    segs = plan_segments(cfg)
    out: List[Any] = []
    for seg, st in zip(segs, fwd_caches):
        if seg[0] == "plain":
            out.append(_state_to_cache(cfg, seg[1], st, max_len, dtype,
                                       window_override))
        else:
            _, pattern, _n = seg
            out.append(tuple(
                _state_to_cache(cfg, pattern[j], st[j], max_len, dtype,
                                window_override)
                for j in range(len(pattern))))
    return out
