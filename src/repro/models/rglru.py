"""Griffin recurrent block: temporal conv + RG-LRU (arXiv:2402.19427).

The RG-LRU recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
linear first-order recurrence, so training uses ``jax.lax.associative_scan``
(TPU-native log-depth scan; the GPU paper's custom recurrence kernel adapts to
an associative scan here — DESIGN.md §2).  Decode carries (h, conv buffer),
constant in sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense, dense_init

_C = 8.0  # Griffin's fixed scaling constant


def rglru_init(key, cfg, dtype=jnp.float32):
    d, w, cw = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.conv_width
    ks = jax.random.split(key, 6)
    # Lambda parametrized so a = exp(-C * softplus(lam) * sigmoid(rg)) starts
    # near the Griffin init (a^C in [0.9, 0.999]).
    lam0 = np.log(np.expm1(-np.log(np.random.RandomState(0).uniform(
        0.9, 0.999, size=(w,)) ** (1.0 / _C))))
    return {
        "w_x": dense_init(ks[0], d, w, False, dtype),       # conv branch in-proj
        "w_gate_branch": dense_init(ks[1], d, w, False, dtype),  # gelu branch
        "w_out": dense_init(ks[2], w, d, False, dtype),
        "conv_w": (jax.random.normal(ks[3], (cw, w)) / np.sqrt(cw)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype=dtype),
        "w_rg": dense_init(ks[4], w, w, False, dtype),      # recurrence gate
        "w_ig": dense_init(ks[5], w, w, False, dtype),      # input gate
        "lam": jnp.asarray(lam0, dtype=jnp.float32),
    }


def _causal_conv(p, u, buf=None):
    """u [B, S, w]; width-cw causal conv.  buf [B, cw-1, w] is the decode
    context (last cw-1 inputs); returns (y, new_buf)."""
    cw = p["conv_w"].shape[0]
    if buf is None:
        buf = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), dtype=u.dtype)
    ext = jnp.concatenate([buf, u], axis=1)                 # [B, cw-1+S, w]
    y = sum(ext[:, i:i + u.shape[1], :] * p["conv_w"][i].astype(u.dtype)
            for i in range(cw))
    y = y + p["conv_b"].astype(u.dtype)
    new_buf = ext[:, -(cw - 1):, :]
    return y, new_buf


def _gates(p, u):
    r = jax.nn.sigmoid(dense(p["w_rg"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_ig"], u).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r             # [B, S, w]
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (
        i * u.astype(jnp.float32))
    return a, gated_in


def rglru_forward(p, x, h0=None, conv_buf=None):
    """Full-sequence forward.  x [B, S, d] -> (out, (h_last, conv_buf))."""
    gelu_branch = jax.nn.gelu(dense(p["w_gate_branch"], x))
    u = dense(p["w_x"], x)
    u, new_buf = _causal_conv(p, u, conv_buf)
    a, b = _gates(p, u)
    if h0 is not None:
        # fold initial state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(jnp.float32), b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    out = dense(p["w_out"], (h.astype(x.dtype) * gelu_branch))
    return out, (h[:, -1].astype(x.dtype), new_buf)


def rglru_init_state(cfg, batch: int, dtype):
    w, cw = cfg.lru_width or cfg.d_model, cfg.conv_width
    return {"h": jnp.zeros((batch, w), dtype=dtype),
            "conv": jnp.zeros((batch, cw - 1, w), dtype=dtype)}


def rglru_decode(p, x, state):
    """One-token step.  x [B, 1, d]."""
    gelu_branch = jax.nn.gelu(dense(p["w_gate_branch"], x))
    u = dense(p["w_x"], x)
    u, new_conv = _causal_conv(p, u, state["conv"])
    a, b = _gates(p, u)                                     # [B, 1, w]
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
    out = dense(p["w_out"], (h[:, None].astype(x.dtype) * gelu_branch))
    return out, {"h": h.astype(state["h"].dtype), "conv": new_conv}
