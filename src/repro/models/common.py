"""Shared functional building blocks (pure-jnp, eval_shape friendly).

All modules are (init, apply) pairs over plain dict pytrees so that
``jax.eval_shape`` can abstract-init trillion-parameter configs for the
multi-pod dry-run without allocating.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------- dense
def dense_init(key, in_dim: int, out_dim: int, use_bias: bool = False,
               dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    p = {"w": (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32)
               * scale).astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype=dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------- norm
def norm_init(kind: str, dim: int, dtype=jnp.float32):
    p = {"scale": jnp.ones((dim,), dtype=dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype=dtype)
    return p


def norm_apply(kind: str, p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    else:  # layernorm
        mu = jnp.mean(x32, -1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), -1, keepdims=True)
        x32 = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = x32 * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------- activation
def activation(name: str, x):
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------- RoPE
def _rope_cos_sin(positions, half_dim: int, theta: float):
    """positions [...]; returns cos/sin of shape positions.shape + (half_dim,)."""
    freqs = 1.0 / (theta ** (jnp.arange(half_dim, dtype=jnp.float32) / half_dim))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, positions, theta: float):
    """x [B, S, H, hd]; positions [B, S] -> rotated x (llama half-split style)."""
    hd = x.shape[-1]
    cos, sin = _rope_cos_sin(positions, hd // 2, theta)     # [B, S, hd/2]
    cos = cos[:, :, None, :].astype(jnp.float32)
    sin = sin[:, :, None, :].astype(jnp.float32)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float):
    """Qwen2-VL M-RoPE.  x [B,S,H,hd]; positions3 [B,3,S]; sections half-dims
    (t, h, w) summing to hd//2 — each frequency band is driven by its own
    position row (temporal / height / width)."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # select the position row per frequency band
    sec_ids = jnp.repeat(jnp.arange(len(sections)),
                         jnp.array(sections), total_repeat_length=half)  # [half]
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                    # [B, 3, S]
        jnp.broadcast_to(sec_ids[None, :, None],
                         (positions3.shape[0], half, positions3.shape[2])).astype(jnp.int32),
        axis=1)                                            # [B, half, S]
    angles = jnp.einsum("bfs,f->bsf", pos, freqs)          # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- loss
def cross_entropy(logits, labels, mask=None, vocab_size: int | None = None):
    """Mean next-token CE.  logits [..., Vpad]; labels [...] int32.

    ``vocab_size`` masks padded vocab entries (Vpad >= V)."""
    logits = logits.astype(jnp.float32)
    if vocab_size is not None and logits.shape[-1] > vocab_size:
        pad = logits.shape[-1] - vocab_size
        neg = jnp.full((pad,), -1e9, dtype=jnp.float32)
        logits = logits.at[..., vocab_size:].set(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ------------------------------------------------------------------ mlp
def mlp_init(key, d_model: int, d_ff: int, act: str, use_bias: bool,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"w_gate": dense_init(ks[0], d_model, d_ff, use_bias, dtype),
                "w_up": dense_init(ks[1], d_model, d_ff, use_bias, dtype),
                "w_down": dense_init(ks[2], d_ff, d_model, use_bias, dtype)}
    return {"w_up": dense_init(ks[0], d_model, d_ff, use_bias, dtype),
            "w_down": dense_init(ks[1], d_ff, d_model, use_bias, dtype)}


def mlp_apply(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x)
    else:
        h = activation(act, dense(p["w_up"], x))
    return dense(p["w_down"], h)
