"""Model zoo: unified decoder LM + whisper encoder-decoder.

`build_model(cfg)` returns a uniform functional API used by the trainer,
server, dry-run, and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable            # (key, dtype=..., vocab_pad_multiple=1) -> params
    loss_fn: Callable          # (params, batch) -> (loss, metrics)
    forward: Callable | None   # decoder-only full forward
    init_cache: Callable       # (batch, max_len, dtype, ...) -> caches
    decode_step: Callable      # (params, caches, token, pos) -> (logits, caches)
    prefill: Callable | None
    # prefill states -> init_cache decode layout (serving-plane plumbing)
    cache_from_prefill: Callable | None = None


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encoder_decoder:
        from repro.models import whisper as W
        return Model(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32, vocab_pad_multiple=1:
                W.init_params(key, cfg, dtype, vocab_pad_multiple),
            loss_fn=lambda params, batch, compute_dtype=jnp.bfloat16,
                remat=False, unroll=False:
                W.loss_fn(params, cfg, batch, compute_dtype, remat, unroll),
            forward=None,
            init_cache=lambda batch, max_len, dtype=jnp.bfloat16, **kw:
                W.init_cache(cfg, batch, max_len, dtype, **kw),
            decode_step=lambda params, caches, token, pos,
                compute_dtype=jnp.bfloat16, **kw:
                W.decode_step(params, cfg, caches, token, pos, compute_dtype,
                              **kw),
            prefill=None,
        )
    from repro.models import transformer as T
    return Model(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32, vocab_pad_multiple=1:
            T.init_params(key, cfg, dtype, vocab_pad_multiple),
        loss_fn=lambda params, batch, compute_dtype=jnp.bfloat16, remat=False,
            unroll=False:
            T.loss_fn(params, cfg, batch, compute_dtype, remat, unroll),
        forward=lambda params, tokens, **kw: T.forward(params, cfg, tokens, **kw),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16, **kw:
            T.init_cache(cfg, batch, max_len, dtype, **kw),
        decode_step=lambda params, caches, token, pos,
            compute_dtype=jnp.bfloat16, **kw:
            T.decode_step(params, cfg, caches, token, pos, compute_dtype, **kw),
        prefill=lambda params, tokens, **kw: T.prefill(params, cfg, tokens, **kw),
        cache_from_prefill=lambda fwd_caches, max_len, dtype=jnp.bfloat16, **kw:
            T.cache_from_prefill(cfg, fwd_caches, max_len, dtype, **kw),
    )
