"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV activations are compressed into a rank-`kv_lora_rank` latent c_kv plus a
shared (per-token, head-agnostic) rope key.  The decode cache stores only
(c_kv, k_rope): cache bytes per token = kv_lora_rank + qk_rope_dim, the
paper's headline 93% KV-cache reduction.

This is the "naive" formulation: K/V are re-materialized from the latent at
attention time (the absorbed-matmul variant is a hillclimb candidate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init, apply_rope
from repro.models.attention import _sdpa, _causal_mask


def mla_init(key, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    r, rd, nd, vd = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_q": dense_init(ks[0], d, H * (nd + rd), cfg.use_bias, dtype),
        "w_dkv": dense_init(ks[1], d, r, cfg.use_bias, dtype),
        "w_krope": dense_init(ks[2], d, rd, cfg.use_bias, dtype),
        "w_uk": dense_init(ks[3], r, H * nd, cfg.use_bias, dtype),
        "w_uv": dense_init(ks[4], r, H * vd, cfg.use_bias, dtype),
        "w_o": dense_init(ks[5], H * vd, d, cfg.use_bias, dtype),
    }


def _project_q(p, x, positions, cfg):
    H, nd, rd = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = dense(p["w_q"], x).reshape(x.shape[:2] + (H, nd + rd))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _expand_kv(p, c_kv, cfg):
    H, nd, vd = cfg.num_heads, cfg.qk_nope_dim, cfg.v_head_dim
    k_nope = dense(p["w_uk"], c_kv).reshape(c_kv.shape[:2] + (H, nd))
    v = dense(p["w_uv"], c_kv).reshape(c_kv.shape[:2] + (H, vd))
    return k_nope, v


def mla_forward(p, x, positions, cfg):
    """Training / prefill forward.  Returns (out, cache={c_kv, k_rope})."""
    H, rd, nd, vd = cfg.num_heads, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope = _project_q(p, x, positions, cfg)
    c_kv = dense(p["w_dkv"], x)                        # [B, S, r]
    k_rope = dense(p["w_krope"], x)[..., None, :]      # [B, S, 1, rd]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    k_nope, v = _expand_kv(p, c_kv, cfg)
    # scores: nope part per-head + shared rope part
    scale = 1.0 / jnp.sqrt(jnp.float32(nd + rd))
    s_nope = jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope[..., 0, :])
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    sq, sk = x.shape[1], x.shape[1]
    mask = _causal_mask(sq, sk)
    scores = jnp.where(mask, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    out = dense(p["w_o"], out.reshape(x.shape[:2] + (H * vd,)))
    return out, {"c_kv": c_kv, "k_rope": k_rope[..., 0, :]}


def mla_init_cache(cfg, batch: int, max_len: int, dtype):
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype=dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype=dtype)}


def mla_decode(p, x, pos, cache, cfg):
    """One-token decode.  Cache holds latents only."""
    H, rd, nd, vd = cfg.num_heads, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    B = x.shape[0]
    posb = jnp.broadcast_to(jnp.asarray(pos)[None, None], (B, 1))
    q_nope, q_rope = _project_q(p, x, posb, cfg)
    c_new = dense(p["w_dkv"], x)                       # [B, 1, r]
    kr_new = dense(p["w_krope"], x)[..., None, :]
    kr_new = apply_rope(kr_new, posb, cfg.rope_theta)[..., 0, :]
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    k_nope, v = _expand_kv(p, c_kv, cfg)
    scale = 1.0 / jnp.sqrt(jnp.float32(nd + rd))
    s_nope = jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    L = c_kv.shape[1]
    mask = (jnp.arange(L) <= pos)[None, None, None, :]
    scores = jnp.where(mask, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    out = dense(p["w_o"], out.reshape(B, 1, H * vd))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
