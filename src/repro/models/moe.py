"""Token-choice top-k MoE with sort-based capacity dispatch (TPU-friendly).

The survey's hybrid-parallelism discussion (§3.2.4) maps MoE onto the
"parameter dimension": experts are sharded over the `model` mesh axis and
token dispatch becomes the all-to-all the survey flags as the communication
bottleneck for parameter-heavy layers.

Dispatch is sort-based (MaxText-style, no [T, E, C] one-hot):
  assignments -> stable sort by expert id -> per-expert positions via
  cumulative counts -> scatter into an [E, C, d] buffer -> batched expert
  einsum -> gather back + weighted combine.  All shapes are static.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, mlp_init, mlp_apply


def moe_init(key, cfg, dtype=jnp.float32):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 2 + cfg.num_shared_experts)
    import numpy as np
    p = {
        "router": dense_init(ks[0], d, E, False, jnp.float32),  # router in fp32
        # stacked expert weights [E, d, ff] / [E, ff, d]
        "w_gate": (jax.random.normal(ks[1], (E, d, ff)) / np.sqrt(d)).astype(dtype),
        "w_up": (jax.random.normal(jax.random.fold_in(ks[1], 1), (E, d, ff))
                 / np.sqrt(d)).astype(dtype),
        "w_down": (jax.random.normal(jax.random.fold_in(ks[1], 2), (E, ff, d))
                   / np.sqrt(ff)).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[2], d, ff * cfg.num_shared_experts,
                               "swiglu", cfg.use_bias, dtype)
    return p


def _capacity(T: int, K: int, E: int, factor: float) -> int:
    c = int((T * K * factor + E - 1) // E)
    return max(c, 1)


def moe_apply(p, x, cfg):
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(T, K, E, cfg.capacity_factor)
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, K)                    # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)                                       # [E]
    one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- sort-based dispatch (gather formulation)
    # A scatter into the expert-sharded [E*C, d] buffer makes GSPMD
    # replicate + all-reduce the full buffer (measured: ~E*C*d bytes of
    # all-reduce per layer).  Instead index slot -> source token and GATHER:
    # slot (e, c) is filled by the c-th token routed to expert e.
    flat_e = expert_ids.reshape(-1)                               # [T*K]
    sort_idx = jnp.argsort(flat_e, stable=True)                   # [T*K]
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=E)                       # [E]
    starts = jnp.cumsum(counts) - counts                          # [E]
    from repro.core.parallelism import moe_constraint
    xt = moe_constraint(xt, "tokens")

    slot_c = jnp.arange(E * C) % C                                # [E*C]
    slot_e = jnp.arange(E * C) // C
    slot_valid = slot_c < counts[slot_e]
    slot_sorted_idx = jnp.minimum(starts[slot_e] + slot_c, T * K - 1)
    slot_token = sort_idx[slot_sorted_idx] // K                   # source token
    buf = jnp.where(slot_valid[:, None],
                    xt[slot_token], jnp.zeros((), dtype=x.dtype))
    buf = moe_constraint(buf.reshape(E, C, d), "experts")

    # ---- batched expert FFN (swiglu)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    out_buf = out_buf.reshape(E * C, d)

    # ---- combine: slot of the i-th sorted assignment (gather, no scatter)
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]               # [T*K]
    valid = pos_in_e < C
    dest = jnp.minimum(sorted_e * C + jnp.minimum(pos_in_e, C - 1),
                       E * C - 1)
    out_sorted = out_buf[dest] * valid[:, None].astype(x.dtype)
    inv = jnp.argsort(sort_idx)                                   # unsort perm
    out_flat = out_sorted[inv]                                    # [T*K, d]
    out = (out_flat.reshape(T, K, d)
           * gate.astype(x.dtype)[..., None]).sum(axis=1)         # [T, d]

    if "shared" in p:
        out = out + mlp_apply(p["shared"], xt, "swiglu")
    return out.reshape(B, S, d), aux
