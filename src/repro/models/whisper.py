"""Whisper large-v3 backbone: transformer encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv1d feature extractor is a STUB per the brief:
callers provide precomputed frame embeddings [B, n_frames, d_model] (the
output of the conv frontend) directly.  Everything downstream — sinusoidal
encoder positions, learned decoder positions, self/cross attention, decode
KV caches — is implemented.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (cross_entropy, dense, mlp_apply, mlp_init,
                                 norm_apply, norm_init)


def _sinusoids(length: int, channels: int):
    log_timescale = np.log(10_000.0) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    scaled = np.arange(length)[:, None] * inv[None, :]
    return jnp.asarray(np.concatenate([np.sin(scaled), np.cos(scaled)], 1),
                       dtype=jnp.float32)


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": norm_init(cfg.norm, cfg.d_model),
            "attn": attn.attn_init(ks[0], cfg, dtype),
            "ln2": norm_init(cfg.norm, cfg.d_model),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.use_bias, dtype)}


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": norm_init(cfg.norm, cfg.d_model),
            "self_attn": attn.attn_init(ks[0], cfg, dtype),
            "ln_x": norm_init(cfg.norm, cfg.d_model),
            "cross_attn": attn.attn_init(ks[1], cfg, dtype),
            "ln2": norm_init(cfg.norm, cfg.d_model),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.use_bias, dtype)}


def init_params(key, cfg: ModelConfig, dtype=jnp.float32,
                vocab_pad_multiple: int = 1):
    vpad = cfg.padded_vocab(vocab_pad_multiple)
    ks = jax.random.split(key, 4)
    return {
        "embed": (jax.random.normal(ks[0], (vpad, cfg.d_model))
                  * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(ks[1], (448, cfg.d_model))
                    * 0.01).astype(dtype),   # learned decoder positions
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(
            jax.random.split(ks[2], cfg.encoder_layers)),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(
            jax.random.split(ks[3], cfg.num_layers)),
        "enc_ln_post": norm_init(cfg.norm, cfg.d_model),
        "dec_ln_post": norm_init(cfg.norm, cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames, compute_dtype=jnp.bfloat16,
           remat: bool = False, unroll: bool = False):
    """frames [B, n_frames, d_model] (conv-frontend stub output)."""
    B, F, _ = frames.shape
    x = frames.astype(compute_dtype) + _sinusoids(
        F, cfg.d_model)[None].astype(compute_dtype)
    pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    def body(xc, p):
        h = norm_apply(cfg.norm, p["ln1"], xc, cfg.norm_eps)
        out, _ = attn.attention_forward(p["attn"], h, pos, cfg, causal=False,
                                        use_rope=False)
        xc = xc + out
        h = norm_apply(cfg.norm, p["ln2"], xc, cfg.norm_eps)
        return xc + mlp_apply(p["mlp"], h, cfg.act), None

    if remat:
        body = jax.checkpoint(body)
    if unroll:
        for li in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a, _l=li: a[_l],
                                        params["enc_layers"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return norm_apply(cfg.norm, params["enc_ln_post"], x, cfg.norm_eps)


def _dec_positions(params, start, length, batch, compute_dtype):
    idx = jnp.clip(start + jnp.arange(length), 0, params["dec_pos"].shape[0] - 1)
    return params["dec_pos"].astype(compute_dtype)[idx][None]


def decode_train(params, cfg: ModelConfig, tokens, enc_out,
                 compute_dtype=jnp.bfloat16, remat: bool = False,
                 unroll: bool = False):
    """Teacher-forced decoder forward.  tokens [B, S]."""
    B, S = tokens.shape
    x = params["embed"].astype(compute_dtype)[tokens]
    x = x + _dec_positions(params, 0, S, B, compute_dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(xc, p):
        h = norm_apply(cfg.norm, p["ln1"], xc, cfg.norm_eps)
        out, _ = attn.attention_forward(p["self_attn"], h, pos, cfg,
                                        causal=True, use_rope=False)
        xc = xc + out
        h = norm_apply(cfg.norm, p["ln_x"], xc, cfg.norm_eps)
        out, _ = attn.attention_forward(p["cross_attn"], h, pos, cfg,
                                        kv_x=enc_out)
        xc = xc + out
        h = norm_apply(cfg.norm, p["ln2"], xc, cfg.norm_eps)
        return xc + mlp_apply(p["mlp"], h, cfg.act), None

    if remat:
        body = jax.checkpoint(body)
    if unroll:
        for li in range(cfg.num_layers):
            x, _ = body(x, jax.tree.map(lambda a, _l=li: a[_l],
                                        params["dec_layers"]))
    else:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = norm_apply(cfg.norm, params["dec_ln_post"], x, cfg.norm_eps)
    return x @ params["embed"].astype(compute_dtype).T


def loss_fn(params, cfg: ModelConfig, batch, compute_dtype=jnp.bfloat16,
            remat: bool = False, unroll: bool = False):
    """batch: {frames [B,F,d], tokens [B,S], labels [B,S][, mask]}."""
    enc_out = encode(params, cfg, batch["frames"], compute_dtype, remat,
                     unroll)
    logits = decode_train(params, cfg, batch["tokens"], enc_out,
                          compute_dtype, remat, unroll)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"),
                       vocab_size=cfg.vocab_size)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               enc_frames: int = 1500):
    """Per decoder layer: self-attn KV cache + precomputed cross KV."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    L = cfg.num_layers
    return {
        "self": {"k": jnp.zeros((L, batch, max_len, KV, hd), dtype=dtype),
                 "v": jnp.zeros((L, batch, max_len, KV, hd), dtype=dtype)},
        "cross": {"k": jnp.zeros((L, batch, enc_frames, KV, hd), dtype=dtype),
                  "v": jnp.zeros((L, batch, enc_frames, KV, hd), dtype=dtype)},
    }


def build_cross_cache(params, cfg: ModelConfig, enc_out, dtype=jnp.bfloat16):
    """Precompute per-layer cross-attention K/V from encoder output."""
    def per_layer(p):
        k = dense(p["cross_attn"]["wk"], enc_out)
        v = dense(p["cross_attn"]["wv"], enc_out)
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        shp = enc_out.shape[:2] + (KV, hd)
        return k.reshape(shp).astype(dtype), v.reshape(shp).astype(dtype)

    ks, vs = jax.vmap(per_layer)(params["dec_layers"])
    return {"k": ks, "v": vs}


def decode_step(params, cfg: ModelConfig, cache, token, pos,
                compute_dtype=jnp.bfloat16, unroll: bool = False):
    """One decoder token.  token [B,1]; cache from init_cache (cross filled)."""
    B = token.shape[0]
    x = params["embed"].astype(compute_dtype)[token]
    x = x + _dec_positions(params, pos, 1, B, compute_dtype)

    def body(xc, inp):
        p, self_c, cross_c = inp
        h = norm_apply(cfg.norm, p["ln1"], xc, cfg.norm_eps)
        out, new_self = attn.attention_decode(
            p["self_attn"], h, pos, self_c, cfg, use_rope=False)
        xc = xc + out
        h = norm_apply(cfg.norm, p["ln_x"], xc, cfg.norm_eps)
        out, _ = attn.attention_decode(p["cross_attn"], h, pos, None, cfg,
                                       cross_kv=cross_c)
        xc = xc + out
        h = norm_apply(cfg.norm, p["ln2"], xc, cfg.norm_eps)
        return xc + mlp_apply(p["mlp"], h, cfg.act), new_self

    if unroll:
        selves = []
        for li in range(cfg.num_layers):
            inp = jax.tree.map(lambda a, _l=li: a[_l],
                               (params["dec_layers"], cache["self"],
                                cache["cross"]))
            x, ns = body(x, inp)
            selves.append(ns)
        new_self = jax.tree.map(lambda *xs: jnp.stack(xs), *selves)
    else:
        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], cache["self"], cache["cross"]))
    x = norm_apply(cfg.norm, params["dec_ln_post"], x, cfg.norm_eps)
    logits = x @ params["embed"].astype(compute_dtype).T
    return logits, {"self": new_self, "cross": cache["cross"]}
