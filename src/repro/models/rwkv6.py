"""RWKV-6 "Finch" time-mix + channel-mix (arXiv:2404.05892).

Structurally faithful: per-head (hs x hs) matrix state with *data-dependent
decay* w_t (Finch's headline feature) produced by a LoRA on the token-shifted
input, bonus term u, receptance/key/value/gate projections, and squared-ReLU
channel mix with receptance.  Simplification (noted in DESIGN.md): the
five-way ddlerp token-shift is reduced to a single learned lerp per stream —
the dynamic-decay recurrence itself is exact.

Training walks the sequence with ``jax.lax.scan`` (a chunked-parallel Pallas
formulation is a hillclimb candidate); decode is O(1) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense, dense_init, norm_init, norm_apply

_DECAY_LORA = 64


def rwkv_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    ks = jax.random.split(key, 10)
    p = {
        # token-shift lerp coefficients per stream
        "mu": {s: jnp.full((d,), 0.5, dtype=jnp.float32)
               for s in ("r", "k", "v", "g", "w")},
        "w_r": dense_init(ks[0], d, d, False, dtype),
        "w_k": dense_init(ks[1], d, d, False, dtype),
        "w_v": dense_init(ks[2], d, d, False, dtype),
        "w_g": dense_init(ks[3], d, d, False, dtype),
        "w_o": dense_init(ks[4], d, d, False, dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -5.0, dtype=jnp.float32),
        "wA": (jax.random.normal(ks[5], (d, _DECAY_LORA)) * 0.01).astype(jnp.float32),
        "wB": (jax.random.normal(ks[6], (_DECAY_LORA, d)) * 0.01).astype(jnp.float32),
        "u": (jax.random.normal(ks[7], (H, hs)) * 0.1).astype(jnp.float32),
        "gn": norm_init("layernorm", d),        # per-head group norm (flattened)
        # channel mix
        "cm_mu": {s: jnp.full((d,), 0.5, dtype=jnp.float32) for s in ("k", "r")},
        "cm_k": dense_init(ks[8], d, cfg.d_ff, False, dtype),
        "cm_v": dense_init(jax.random.fold_in(ks[8], 1), cfg.d_ff, d, False, dtype),
        "cm_r": dense_init(ks[9], d, d, False, dtype),
    }
    return p


def _token_shift(x, prev):
    """x [B,S,d]; prev [B,d] (last token of previous chunk) -> shifted x."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def _decay(p, xw):
    raw = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    return jnp.exp(-jnp.exp(raw))               # in (0, 1)


def time_mix_forward(p, x, cfg, state=None):
    """x [B,S,d]; state {"S": [B,H,hs,hs], "shift": [B,d]} or None.
    Returns (out, new_state)."""
    B, S, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs
    if state is None:
        state = {"S": jnp.zeros((B, H, hs, hs), dtype=jnp.float32),
                 "shift": jnp.zeros((B, d), dtype=x.dtype)}
    xx = _token_shift(x, state["shift"])
    r = dense(p["w_r"], _mix(x, xx, p["mu"]["r"])).reshape(B, S, H, hs)
    k = dense(p["w_k"], _mix(x, xx, p["mu"]["k"])).reshape(B, S, H, hs)
    v = dense(p["w_v"], _mix(x, xx, p["mu"]["v"])).reshape(B, S, H, hs)
    g = jax.nn.silu(dense(p["w_g"], _mix(x, xx, p["mu"]["g"])))
    w = _decay(p, _mix(x, xx, p["mu"]["w"])).reshape(B, S, H, hs)
    u = p["u"]

    def step(S_h, inp):
        r_t, k_t, v_t, w_t = inp                # [B,H,hs] each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       S_h + u[None, :, :, None] * kv)
        S_new = w_t.astype(jnp.float32)[..., None] * S_h + kv
        return S_new, y

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S_last, ys = jax.lax.scan(step, state["S"], (rs, ks_, vs, ws))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d)  # [B,S,d]
    y = norm_apply("layernorm", p["gn"], y.astype(x.dtype))
    out = dense(p["w_o"], y * g)
    return out, {"S": S_last, "shift": x[:, -1]}


def channel_mix_forward(p, x, cfg, shift=None):
    B, S, d = x.shape
    if shift is None:
        shift = jnp.zeros((B, d), dtype=x.dtype)
    xx = _token_shift(x, shift)
    k = dense(p["cm_k"], _mix(x, xx, p["cm_mu"]["k"]))
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid(dense(p["cm_r"], _mix(x, xx, p["cm_mu"]["r"])))
    return r * dense(p["cm_v"], k), x[:, -1]


def rwkv_init_state(cfg, batch: int, dtype):
    d, hs = cfg.d_model, cfg.rwkv_head_size
    H = d // hs
    return {"S": jnp.zeros((batch, H, hs, hs), dtype=jnp.float32),
            "shift_tm": jnp.zeros((batch, d), dtype=dtype),
            "shift_cm": jnp.zeros((batch, d), dtype=dtype)}


def rwkv_block_decode(p_tm, p_cm, ln1, ln2, cfg, x, st):
    """One-token step for a full rwkv block (time mix + channel mix).
    x [B,1,d]."""
    h, new_tm = time_mix_forward(
        p_tm, norm_apply("layernorm", ln1, x), cfg,
        {"S": st["S"], "shift": st["shift_tm"]})
    x = x + h
    h, new_shift_cm = channel_mix_forward(
        p_cm, norm_apply("layernorm", ln2, x), cfg, st["shift_cm"])
    x = x + h
    return x, {"S": new_tm["S"], "shift_tm": new_tm["shift"],
               "shift_cm": new_shift_cm}
