"""Segment codecs: the on-the-wire encodings that travel *inside* the
collective schedules of ``repro.comm.transport``.

A codec maps a flat fp32 segment (one ring chunk, one tree payload) to a
pytree of fixed-shape arrays — the *planes* — and back:

    planes = codec.encode(seg, key)     # seg: [L] f32, any L
    seg'   = codec.decode(planes)[:L]   # decode returns the row-padded
                                        # length; schedules slice to L

``encode_ef(seg, key)`` is the fused form every lossy transmission in the
transport actually calls: one pass that returns the planes *and* the
sender's error-feedback residual ``seg - decode(planes)[:L]``, so on the
kernel backend each segment is read from HBM once instead of
encode-then-decode-then-subtract.

Planes are what ``lax.ppermute`` / ``lax.all_gather`` actually move, so
the wire format is physical where jnp allows it: onebit signs are packed
32 per uint32 word (``repro.kernels.onebit.pack_bits``), terngrad digits
16 per word.  Segments are padded to whole ``LANE``-wide rows internally;
all data-dependent statistics (dgc's quantile threshold, terngrad's
clip/scale, onebit's bin means) are computed on the *unpadded* elements
so pad zeros cannot bias them — the same fix ``core/compression.py``
applies to the per-leaf roundtrip.

Every codec carries a ``backend`` (resolved at construction by
``repro.kernels.backend.resolve_backend``): ``kernel`` dispatches the
quantization math to its ``repro.kernels.*`` Pallas implementation
(interpret mode off-TPU), ``ref`` runs the original jnp expressions
in-line.  The two backends are expression-identical, so the emitted
planes — and therefore the measured wire bytes, including dgc's traced
``sent_elems`` — are bitwise the same; tests assert it.

``static_tx_bytes(L)`` is the host-side byte count of one encoded
segment, counted over the *unpadded* payload (pad rows carry no
information — a real wire format would not ship them; the row side
information is still charged per padded row) — for ``dgc`` it covers only the shape-static part (the packed
1-bit remainder plane); the value/index pairs of the sparse plane are
counted per transmission from the traced ``sent_elems`` (8 bytes each:
4 B value + 4 B index), which is how the measured accounting follows the
threshold's step-to-step payload changes.

The quantization math matches ``core/compression.py``'s per-worker
roundtrip (same kernel oracles, same two-bin Seide reconstruction), but
applied per *segment* rather than per parameter leaf — a reduce-scatter
hop quantizes the partial sum it forwards, and the hop's error lands in
the sender's error-feedback residual (see ``transport``).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import Compressor
from repro.kernels import onebit as K1
from repro.kernels import qsgd as KQ
from repro.kernels import terngrad as KT
from repro.kernels import topk as KK
from repro.kernels.backend import resolve_backend

LANE = 256          # encode rows are [ceil(L / LANE), LANE]


def _pad_rows(seg):
    """[L] -> ([R, LANE] rows, valid mask or None, L)."""
    L = seg.shape[0]
    pad = (-L) % LANE
    x = jnp.pad(seg.astype(jnp.float32), (0, pad)).reshape(-1, LANE)
    valid = ((jnp.arange(L + pad) < L).reshape(-1, LANE) if pad else None)
    return x, valid, L


def _rows_of(length: int) -> int:
    return -(-length // LANE)


def _two_bin_means(signs, c, valid=None):
    """Per-row positive/negative bin means of ``c`` under the transmitted
    sign plane — the 8 B/row side information of the Seide wire format."""
    pos = signs > 0
    neg = ~pos
    if valid is not None:
        pos = pos & valid
        neg = neg & valid
    npos = jnp.maximum(jnp.sum(pos, axis=-1, keepdims=True), 1)
    nneg = jnp.maximum(jnp.sum(neg, axis=-1, keepdims=True), 1)
    sp = jnp.sum(jnp.where(pos, c, 0.0), axis=-1, keepdims=True) / npos
    sn = jnp.sum(jnp.where(neg, -c, 0.0), axis=-1, keepdims=True) / nneg
    return sp, sn


class SegmentCodec:
    """Stateless segment encoder/decoder.  ``exact`` codecs (``none``)
    round-trip bit-identically, so the transport runs the legacy
    full-precision schedule for them."""

    name: str = "?"
    exact: bool = False
    lossy_ef: bool = False      # hop errors belong in an EF residual

    def __init__(self, backend: str = "auto"):
        self.backend = resolve_backend(backend)

    def encode(self, seg, key=None) -> Dict[str, Any]:
        raise NotImplementedError

    def decode(self, planes: Dict[str, Any]):
        raise NotImplementedError

    def encode_ef(self, seg, key=None) -> Tuple[Dict[str, Any], Any]:
        """Encode + the sender's EF residual in one call:
        ``(planes, seg - decode(planes)[:L])``.  Codecs with a fused
        kernel override this so the kernel backend reads ``seg`` once;
        the default is the unfused encode-decode-subtract (the ref
        math, bit-identical to what the schedules previously inlined)."""
        planes = self.encode(seg, key)
        return planes, seg - self.decode(planes)[:seg.shape[0]]

    def static_tx_bytes(self, length: int) -> int:
        """Shape-static wire bytes of one encoded length-``length``
        segment (excluding dgc's data-dependent value/index pairs)."""
        raise NotImplementedError

    def sent_elems(self, planes: Dict[str, Any]):
        """Traced count of data-dependent value/index pairs in ``planes``
        (0 for every shape-static codec)."""
        return jnp.zeros((), jnp.int32)


class NoneCodec(SegmentCodec):
    name = "none"
    exact = True

    def encode(self, seg, key=None):
        return {"x": seg}

    def decode(self, planes):
        return planes["x"]

    def static_tx_bytes(self, length: int) -> int:
        return 4 * length


class OnebitCodec(SegmentCodec):
    """1-bit signs (packed 32/word) + per-row two-bin means."""
    name = "onebit"
    lossy_ef = True

    def _rows(self, seg):
        """(signs, sp, sn, residual_rows, valid, L) via the fused kernel
        or the in-line jnp oracle — identical planes either way."""
        c, valid, L = _pad_rows(seg)
        if self.backend == "kernel":
            signs, sp, sn, _, new_e = K1.encode_ef(c, None, valid,
                                                   backend="kernel")
            return signs, sp, sn, new_e, L
        signs = jnp.where(c >= 0, jnp.int8(1), jnp.int8(-1))
        sp, sn = _two_bin_means(signs, c, valid)
        recon = jnp.where(signs > 0, sp, -sn)
        out = recon if valid is None else jnp.where(valid, recon, 0.0)
        return signs, sp, sn, c - out, L

    def encode(self, seg, key=None):
        signs, sp, sn, _, _ = self._rows(seg)
        return {"words": K1.pack_bits(signs), "sp": sp, "sn": sn}

    def encode_ef(self, seg, key=None):
        signs, sp, sn, new_e, L = self._rows(seg)
        planes = {"words": K1.pack_bits(signs), "sp": sp, "sn": sn}
        return planes, new_e.reshape(-1)[:L]

    def decode(self, planes):
        signs = K1.unpack_bits(planes["words"], LANE)
        return jnp.where(signs > 0, planes["sp"], -planes["sn"]).reshape(-1)

    def static_tx_bytes(self, length: int) -> int:
        return -(-length // 8) + 8 * _rows_of(length)


class TerngradCodec(SegmentCodec):
    """Stochastic ternary digits packed 16 per uint32 word + one scale."""
    name = "terngrad"

    def __init__(self, clip_sigma: float = 2.5, backend: str = "auto"):
        super().__init__(backend)
        self.clip_sigma = clip_sigma

    def encode(self, seg, key=None):
        g0 = seg.astype(jnp.float32)             # stats on unpadded data
        if self.clip_sigma:
            sigma = jnp.std(g0)
            g0 = jnp.clip(g0, -self.clip_sigma * sigma,
                          self.clip_sigma * sigma)
        s = jnp.max(jnp.abs(g0))
        c, _, _ = _pad_rows(g0)
        u = jax.random.uniform(key, c.shape)
        if self.backend == "kernel":
            tern = KT.ternarize(c, u, s, backend="kernel")
        else:
            p = jnp.abs(c) / jnp.maximum(s, 1e-30)
            b = (u < p).astype(jnp.int8)
            tern = jnp.sign(c).astype(jnp.int8) * b
        digits = (tern + 1).astype(jnp.uint32).reshape(-1, LANE // 16, 16)
        shifts = 2 * jnp.arange(16, dtype=jnp.uint32)
        words = jnp.sum(digits << shifts, axis=-1).astype(jnp.uint32)
        return {"words": words, "s": s}

    def decode(self, planes):
        words = planes["words"]
        shifts = 2 * jnp.arange(16, dtype=jnp.uint32)
        digits = (words[..., None] >> shifts) & jnp.uint32(3)
        tern = digits.astype(jnp.float32) - 1.0
        return (tern.reshape(words.shape[0], -1) * planes["s"]).reshape(-1)

    def static_tx_bytes(self, length: int) -> int:
        return -(-length // 4) + 4


class QsgdCodec(SegmentCodec):
    """s-level stochastic quantization: int8 levels + one l2 norm."""
    name = "qsgd"

    def __init__(self, s_levels: int = 127, backend: str = "auto"):
        super().__init__(backend)
        self.s_levels = s_levels

    def encode(self, seg, key=None):
        g32, _, _ = _pad_rows(seg)               # pad zeros don't move l2
        u = jax.random.uniform(key, g32.shape)
        if self.backend == "kernel":
            q, norm = KQ.quantize(g32, u, s_levels=self.s_levels,
                                  backend="kernel")
            return {"q": q, "norm": norm}
        norm = jnp.sqrt(jnp.sum(jnp.square(g32)))
        p = jnp.abs(g32) / jnp.maximum(norm, 1e-30) * self.s_levels
        lo = jnp.floor(p)
        lvl = jnp.clip(lo + (u < (p - lo)).astype(jnp.float32),
                       0, self.s_levels)
        return {"q": (jnp.sign(g32) * lvl).astype(jnp.int8), "norm": norm}

    def decode(self, planes):
        return (planes["q"].astype(jnp.float32)
                * (planes["norm"] / self.s_levels)).reshape(-1)

    def static_tx_bytes(self, length: int) -> int:
        return length + 4


class DgcCodec(SegmentCodec):
    """Threshold-sparse values + a 1-bit plane for the remainder.

    The values plane is a dense fp32 array (SPMD payloads are
    fixed-shape) but its *wire* size is the sparse accounting — 8 bytes
    per element above the threshold, counted per transmission from
    ``sent_elems`` because the quantile threshold moves with the data
    every step.  The untransmitted remainder rides the same packed 1-bit
    plane as ``onebit`` (masked out of the bin means)."""
    name = "dgc"
    lossy_ef = True

    def __init__(self, density: float = 0.01, backend: str = "auto"):
        super().__init__(backend)
        self.density = density

    def _planes(self, seg):
        # quantile threshold on the unpadded payload (kernels/topk owns
        # the selection rule; e=0 because segment EF lives in transport)
        th = KK.threshold_for_density(seg, jnp.zeros_like(seg),
                                      self.density)
        c, valid, L = _pad_rows(seg)
        if self.backend == "kernel":
            # kept != 0 <=> (|c| >= th) & (c != 0): the kernel's fused
            # select yields the same mask — and therefore the same traced
            # sent_elems accounting — as the explicit jnp predicate
            kept_raw, _ = KK.sparsify(c, jnp.zeros_like(c), th,
                                      backend="kernel")
            mask = kept_raw != 0.0
        else:
            # an exact zero never ships: the wire format is (index, value)
            # pairs, and when the threshold degenerates to 0 (a mostly-zero
            # segment) the zeros must not count as payload
            mask = (jnp.abs(c) >= th) & (c != 0.0)
        if valid is not None:
            mask = mask & valid
        kept = jnp.where(mask, c, 0.0)
        rem = c - kept
        unsent = ~mask if valid is None else (~mask & valid)
        if self.backend == "kernel":
            signs, sp, sn, rem_out, rem_e = K1.encode_ef(
                rem, None, unsent, backend="kernel")
        else:
            signs = jnp.where(rem >= 0, jnp.int8(1), jnp.int8(-1))
            sp, sn = _two_bin_means(signs, rem, valid=unsent)
            recon = jnp.where(signs > 0, sp, -sn)
            rem_out = jnp.where(unsent, recon, 0.0)
            rem_e = rem - rem_out
        planes = {"kept": kept, "mask": mask,
                  "words": K1.pack_bits(signs), "sp": sp, "sn": sn}
        return planes, rem_e, L

    def encode(self, seg, key=None):
        planes, _, _ = self._planes(seg)
        return planes

    def encode_ef(self, seg, key=None):
        # residual = seg - decode = (c - kept) - rem_out = rem_e
        planes, rem_e, L = self._planes(seg)
        return planes, rem_e.reshape(-1)[:L]

    def decode(self, planes):
        signs = K1.unpack_bits(planes["words"], LANE)
        rem = jnp.where(signs > 0, planes["sp"], -planes["sn"])
        rem = jnp.where(planes["mask"], 0.0, rem)
        return (planes["kept"] + rem).reshape(-1)

    def static_tx_bytes(self, length: int) -> int:
        # the packed remainder plane; kept values are counted per send
        return -(-length // 8) + 8 * _rows_of(length)

    def sent_elems(self, planes):
        return jnp.sum(planes["mask"].astype(jnp.int32))


# 4 B value + 4 B index per data-dependent sparse element on the wire
SPARSE_ELEM_BYTES = 8


def make_codec(method: str, backend: str = "auto", **kw) -> SegmentCodec:
    if method == "none":
        return NoneCodec(backend)
    if method == "onebit":
        return OnebitCodec(backend)
    if method == "terngrad":
        return TerngradCodec(backend=backend, **kw)
    if method == "qsgd":
        return QsgdCodec(backend=backend, **kw)
    if method == "dgc":
        return DgcCodec(backend=backend, **kw)
    raise ValueError(f"no segment codec for method {method!r}")


def codec_for(compressor: Compressor) -> SegmentCodec:
    """The segment codec matching a ``Compressor`` spec (same method,
    same quantization knobs, same kernel backend; EF/reconstruction
    knobs live in the transport)."""
    m = compressor.method
    be = compressor.backend
    if m == "terngrad":
        return TerngradCodec(clip_sigma=compressor.clip_sigma, backend=be)
    if m == "qsgd":
        return QsgdCodec(s_levels=compressor.s_levels, backend=be)
    if m == "dgc":
        return DgcCodec(density=compressor.density, backend=be)
    return make_codec(m, backend=be)
