"""The unified communication plane (survey §3.3.1–§3.3.3 co-design).

One layer owns everything a gradient exchange needs:

  ``codecs``     per-segment wire codecs — encode a flat fp32 segment into
                 fixed-shape *planes* (bit-packed sign words, quantized
                 bytes, side information) that travel through collective
                 permutes, and decode them back.
  ``transport``  topology schedule *generators* — ring / tree / butterfly /
                 fully-connected schedules whose reduce-scatter and
                 all-gather steps carry encoded planes (encode → ppermute
                 the planes → decode-accumulate), with per-worker error
                 feedback for the lossy hops.
  ``plan``       ``CommPlan`` — the bucket fusion + TicTac issue order +
                 codec + topology + wire-accounting plan every
                 gradient-exchange call site executes (``DeviceEngine``,
                 the hybrid mesh data axis, and the ZeRO z1–z3 paths).

See docs/comm.md for the lifecycle and the modeled-vs-measured wire
accounting semantics.
"""
from repro.comm.codecs import SegmentCodec, codec_for, make_codec
from repro.comm.plan import CommPlan, plan_buckets
from repro.comm.transport import (SCHEDULES, fp32_schedule_bytes,
                                  model_error_factor, schedule_tx_bytes)

__all__ = [
    "SegmentCodec", "codec_for", "make_codec",
    "CommPlan", "plan_buckets",
    "SCHEDULES", "fp32_schedule_bytes", "model_error_factor",
    "schedule_tx_bytes",
]
