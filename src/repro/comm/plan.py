"""``CommPlan``: the one communication plan every gradient exchange in
the repo executes.

A plan is built once per (parameter structure × worker axis) and owns:

  * the fused-bucket layout (backward-order fusion into ~``bucket_mb``
    buckets) and the TicTac/random/layer transfer **issue order** — the
    ``core/comm_scheduler`` logic, now behind one object shared by the
    executed schedule and the analytic timeline so they cannot drift;
  * the **topology** schedule (ring/tree/butterfly/…) each bucket is
    reduced with, via ``repro.comm.transport``;
  * the **codec** (``repro.comm.codecs``) and the ``wire`` mode:

      wire="modeled"   compression happens per worker *before* the
                       exchange (``Compressor.roundtrip``) and the
                       schedule moves full-precision payloads; wire bytes
                       are the compressor's analytic accounting (what the
                       simulator reports — the two backends stay
                       cross-validatable).
      wire="measured"  the schedule itself carries encoded planes
                       (encode → ppermute → decode-accumulate, per-worker
                       EF for the lossy hops) and wire bytes are counted
                       from those planes: shape-static parts at plan time
                       (``measured_step_tx_bytes``), dgc's data-dependent
                       sparse elements per step from the traced
                       ``sent_elems`` the exchange returns.

  ``bsp/*/none`` is identical under both modes: the exact codec routes
  through the legacy full-precision schedules, bit-for-bit.

``DeviceEngine`` (train/data_parallel.py) and the hybrid mesh's data axis
(parallel/engine.py, z0–z3) both consume this object — one planner, one
issue order, one accounting surface.  See docs/comm.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codecs import SPARSE_ELEM_BYTES, SegmentCodec, codec_for
from repro.comm.transport import (SCHEDULES, compressed_allreduce,
                                  compressed_allreduce_ef,
                                  compressed_reduce_scatter,
                                  compressed_reduce_scatter_ef,
                                  fp32_schedule_bytes, pad_for_schedule,
                                  schedule_tx_bytes)
from repro.core.collectives import axis_size
from repro.core.comm_scheduler import (LayerCost, LinkModel, bucketize,
                                       random_order, schedule_no_overlap,
                                       schedule_overlap, tictac_order)
from repro.core.compression import Compressor
from repro.core.parameter_server import all_gather_flat, shard_of_flat

WIRE_MODES = ("modeled", "measured")


def bucket_order(n: int, order: str, layers: Sequence[LayerCost],
                 seed: int) -> List[int]:
    if order == "tictac":
        return tictac_order(layers)
    if order == "random":
        return random_order(layers, seed)
    if order == "layer":
        return list(range(n))
    raise ValueError(order)


def plan_buckets(params_example, bucket_mb: float, order: str,
                 back_s_per_byte: float, seed: int
                 ) -> Tuple[List[List[int]], List[int], List[LayerCost]]:
    """Fuse gradient leaves (backward = reverse-pytree order) into buckets
    of ~bucket_mb and choose the transfer issue order.  This single plan
    is shared by the executed schedule (every architecture and mesh) and
    the analytic timeline model."""
    leaves = jax.tree.leaves(params_example)
    layers = [LayerCost(f"g{i}", back_s_per_byte * x.size * 4, x.size * 4)
              for i, x in enumerate(leaves)]
    fused = bucketize(layers, bucket_mb * 1e6)
    buckets = [[int(nm[1:]) for nm in b.name.split("+")] for b in fused]
    order_idx = bucket_order(len(fused), order, fused, seed)
    return buckets, order_idx, fused


def modeled_event_bytes(compressor: Compressor, params_example) -> int:
    """The compressor's analytic per-push accounting over
    ``params_example`` (what the simulator reports) — the single
    implementation every engine's modeled wire increment uses."""
    zeros = jax.tree.map(lambda x: jnp.zeros(np.shape(x), jnp.float32),
                         params_example)
    state = compressor.init_state(zeros)
    _, _, wb = compressor.roundtrip(zeros, state, jax.random.PRNGKey(0))
    return int(wb)


def scatter_flat(flat, idxs, leaf_shapes, out, dtype=None):
    """Split a fused bucket vector back into its leaves (into ``out``)."""
    off = 0
    for i in idxs:
        shape, leaf_dtype = leaf_shapes[i]
        size = int(np.prod(shape)) if shape else 1
        out[i] = flat[off:off + size].reshape(shape).astype(
            dtype or leaf_dtype)
        off += size
    return out


@dataclasses.dataclass
class CommPlan:
    """One executable exchange plan (see the module docstring)."""
    axis: str
    n: int                           # workers on the axis
    topology: str
    compressor: Compressor
    wire: str                        # modeled | measured
    buckets: List[List[int]]
    order: List[int]                 # issue order over bucket indices
    fused: List[LayerCost]
    treedef: Any
    leaf_shapes: List[Tuple[Tuple[int, ...], Any]]
    link: LinkModel = LinkModel()
    # dtype gradients travel in on the UNCOMPRESSED exchange: "bfloat16"
    # halves the wire words of the exact schedules (codec payloads are
    # already quantized planes and are unaffected; parameter all-gathers
    # always travel exact fp32)
    reduce_dtype: str = "float32"

    @classmethod
    def plan(cls, params_example, *, axis: str, n: int,
             topology: str = "ring",
             compressor: Compressor = Compressor("none"),
             wire: str = "modeled", bucket_mb: float = 4.0,
             order: str = "tictac", back_s_per_byte: float = 2e-12,
             seed: int = 0, link: LinkModel = LinkModel(),
             reduce_dtype: str = "float32") -> "CommPlan":
        if wire not in WIRE_MODES:
            raise ValueError(f"wire={wire!r} (want {WIRE_MODES})")
        if topology not in SCHEDULES:
            raise ValueError(f"unknown topology {topology!r}")
        buckets, order_idx, fused = plan_buckets(
            params_example, bucket_mb, order, back_s_per_byte, seed)
        treedef = jax.tree.structure(params_example)
        shapes = [(tuple(x.shape), x.dtype)
                  for x in jax.tree.leaves(params_example)]
        return cls(axis=axis, n=n, topology=topology, compressor=compressor,
                   wire=wire, buckets=buckets, order=order_idx, fused=fused,
                   treedef=treedef, leaf_shapes=shapes, link=link,
                   reduce_dtype=reduce_dtype)

    # ------------------------------------------------------------ derived
    @property
    def codec(self) -> SegmentCodec:
        return codec_for(self.compressor)

    @property
    def in_schedule(self) -> bool:
        """True when payloads are encoded inside the schedule (measured
        wire mode with a lossy method)."""
        return self.wire == "measured" and self.compressor.method != "none"

    @property
    def word_bytes(self) -> int:
        """Bytes per word of the uncompressed gradient exchange (4 fp32,
        2 when ``reduce_dtype="bfloat16"``)."""
        return int(jnp.dtype(self.reduce_dtype).itemsize)

    def _exact_tx(self, codec, length: int) -> float:
        """``static_tx_bytes`` with the reduce-dtype word width applied to
        the exact codec (NoneCodec counts 4 B/word; a bf16 exchange moves
        2 B/word).  Lossy codec planes are unaffected."""
        base = codec.static_tx_bytes(length)
        if codec.exact and self.word_bytes != 4:
            return base * self.word_bytes / 4
        return base

    def bucket_len(self, b: int) -> int:
        return sum(int(np.prod(s) or 1) for s, _ in
                   ((self.leaf_shapes[i]) for i in self.buckets[b]))

    def _cat(self, leaves, b: int):
        return jnp.concatenate(
            [leaves[i].astype(jnp.float32).reshape(-1)
             for i in self.buckets[b]])

    # ------------------------------------------------- exact (fp32) ops
    def reduce_grads(self, grads):
        """Full-precision bucketed mean-allreduce in plan issue order —
        the legacy exact path, bit-identical to the pre-refactor
        ``make_bucketed_allreduce``.  Call inside ``shard_map``."""
        reduce_leaf = SCHEDULES[self.topology]
        leaves = jax.tree.leaves(grads)
        n = axis_size(self.axis)
        rdt = jnp.dtype(self.reduce_dtype)
        out: List[Any] = [None] * len(leaves)
        for b in self.order:                   # the executed schedule
            flat = self._cat(leaves, b)
            if rdt != jnp.float32:
                flat = flat.astype(rdt)        # the bf16 wire words
            red = reduce_leaf(flat, self.axis).astype(jnp.float32) / n
            scatter_flat(red, self.buckets[b], self.leaf_shapes, out)
        return jax.tree.unflatten(self.treedef, out)

    # ---------------------------------------- codec-in-schedule exchange
    def exchange(self, grads, ef, key):
        """Mean-allreduce with encoded payloads inside the topology
        schedule.  ``ef`` is the worker's error-feedback pytree (None for
        the stateless quantizers), ``key`` drives the stochastic codecs.
        Returns ``(mean_grads, new_ef, sent_elems)`` — fold ``sent_elems``
        (a traced int32) into the step outputs for dgc's measured bytes.
        Call inside ``shard_map``."""
        comp, codec = self.compressor, self.codec
        gain = comp.ef_gain if comp.method == "onebit" else 1.0
        leaves = jax.tree.leaves(grads)
        ef_leaves = jax.tree.leaves(ef) if ef is not None else None
        out: List[Any] = [None] * len(leaves)
        new_ef: List[Any] = [None] * len(leaves)
        sent = jnp.zeros((), jnp.int32)
        for b in self.order:
            L = self.bucket_len(b)
            P = pad_for_schedule(L, self.n)
            g_flat = jnp.pad(self._cat(leaves, b), (0, P - L))
            key, sub = jax.random.split(key)
            if ef_leaves is not None:
                # hand the residual bucket down: the transport applies the
                # (over-relaxed) compensation, runs fused encode+EF hops,
                # and returns the telescoped next-step residual
                e_flat = jnp.pad(self._cat(ef_leaves, b), (0, P - L))
                red, new_e, nz = compressed_allreduce_ef(
                    g_flat, e_flat, self.axis, self.topology, codec, sub,
                    gain=gain)
                scatter_flat(new_e[:L], self.buckets[b],
                             self.leaf_shapes, new_ef, dtype=jnp.float32)
            else:
                red, _, nz = compressed_allreduce(
                    g_flat, self.axis, self.topology, codec, sub)
            sent = sent + nz
            scatter_flat(red[:L] / self.n, self.buckets[b],
                         self.leaf_shapes, out)
        out_tree = jax.tree.unflatten(self.treedef, out)
        ef_tree = (jax.tree.unflatten(self.treedef, new_ef)
                   if ef_leaves is not None else None)
        return out_tree, ef_tree, sent

    def ps_exchange(self, params, grads, ef, key, lr: float):
        """The centralized counterpart: compressed ring reduce-scatter of
        each gradient bucket (the PS push), SGD on my 1/n shard (the
        server work), full-precision all-gather of the updated shard (the
        pull — parameters travel exact).  Returns ``(new_params, new_ef,
        sent_elems)``.  Call inside ``shard_map``."""
        comp, codec = self.compressor, self.codec
        gain = comp.ef_gain if comp.method == "onebit" else 1.0
        p_leaves = jax.tree.leaves(params)
        g_leaves = jax.tree.leaves(grads)
        ef_leaves = jax.tree.leaves(ef) if ef is not None else None
        out: List[Any] = [None] * len(p_leaves)
        new_ef: List[Any] = [None] * len(p_leaves)
        sent = jnp.zeros((), jnp.int32)
        for b in self.order:
            L = self.bucket_len(b)
            P = pad_for_schedule(L, self.n)
            g_flat = jnp.pad(self._cat(g_leaves, b), (0, P - L))
            key, sub = jax.random.split(key)
            if ef_leaves is not None:
                e_flat = jnp.pad(self._cat(ef_leaves, b), (0, P - L))
                g_shard, new_e, nz = compressed_reduce_scatter_ef(
                    g_flat, e_flat, self.axis, codec, sub, gain=gain)
                scatter_flat(new_e[:L], self.buckets[b],
                             self.leaf_shapes, new_ef, dtype=jnp.float32)
            else:
                g_shard, _, nz = compressed_reduce_scatter(
                    g_flat, self.axis, codec, sub)
            sent = sent + nz
            p_flat = jnp.pad(self._cat(p_leaves, b), (0, P - L))
            p_shard = shard_of_flat(p_flat, self.axis)
            new_shard = p_shard - lr * (g_shard / self.n)
            full = all_gather_flat(new_shard, self.axis, L)
            scatter_flat(full, self.buckets[b], self.leaf_shapes, out)
        out_tree = jax.tree.unflatten(self.treedef, out)
        ef_tree = (jax.tree.unflatten(self.treedef, new_ef)
                   if ef_leaves is not None else None)
        return out_tree, ef_tree, sent

    # -------------------------------------------------------------- trace
    def hop_model(self, b: int, arch: str = "allreduce"
                  ) -> List[Tuple[str, float]]:
        """The per-hop wire model for one exchange of bucket ``b``: a list
        of (hop kind, mean per-worker tx bytes) mirroring exactly the
        aggregate ``schedule_tx_bytes`` / ``measured_step_tx_bytes``
        accounting, so the sum over hops equals the per-bucket measured
        bytes (shape-static part; dgc adds its traced sparse payload at
        the step level)."""
        import math
        codec = self.codec if self.in_schedule else codec_for(
            Compressor("none"))
        n = self.n
        if n == 1:
            return []
        L = self.bucket_len(b)
        P = pad_for_schedule(L, n)
        m = P // n
        e = lambda length: self._exact_tx(codec, length)
        if arch == "ps":
            # gradient RS encoded, parameter AG exact fp32 (docs/comm.md)
            return ([("rs", float(e(m)))] * (n - 1)
                    + [("ag", float(4 * m))] * (n - 1))
        topo = self.topology
        if topo in ("ring", "psum"):
            return ([("rs", float(e(m)))] * (n - 1)
                    + [("ag", float(e(m)))] * (n - 1))
        if topo == "butterfly":
            if codec.exact:
                return [("exchange", float(e(P)))] * int(math.log2(n))
            rs = [("rs", float(e((n >> (k + 1)) * m)))
                  for k in range(int(math.log2(n)))]
            return rs + [("ag", float(e(m)))] * (n - 1)
        if topo == "tree":
            half = (n - 1) / n * e(P)
            return [("reduce", float(half)), ("broadcast", float(half))]
        if topo == "fully_connected":
            return [("send", float(e(P)))] * (n - 1)
        raise ValueError(topo)

    def emit_trace(self, rec, *, arch: str = "allreduce",
                   pid: str = "train", tid: str = "loop",
                   clock=None) -> None:
        """Emit the exchange this plan just executed onto the trace
        timeline (docs/observability.md): an ``exchange`` span holding
        one span per fused bucket *in issue order*, each carrying its
        per-hop wire events.  The schedule runs inside jit, so these are
        the plan's own deterministic model of what executed — virtual
        clock only, byte-reproducible under fixed seeds."""
        if not rec.enabled:
            return
        comp = self.compressor
        # the modeled bounds the analyzer compares issue order against:
        # serial buckets (worst) vs TicTac-ordered overlap (best for
        # this plan) vs the order actually executed.  Rounded so traces
        # stay byte-stable (obs/analyze.overlap_efficiency).
        no_overlap_s = schedule_no_overlap(self.fused, self.link)
        tictac_s = schedule_overlap(self.fused, self.link,
                                    tictac_order(self.fused))
        issue_s = schedule_overlap(self.fused, self.link, self.order)
        rec.begin("exchange", pid=pid, tid=tid, cat="comm", clock=clock,
                  topology=self.topology, codec=comp.method,
                  backend=getattr(comp, "backend", "auto"),
                  wire_mode=self.wire, arch=arch,
                  n_buckets=len(self.buckets),
                  step_tx_bytes=self.measured_step_tx_bytes(arch),
                  modeled_no_overlap_us=round(no_overlap_s * 1e6, 3),
                  modeled_tictac_overlap_us=round(tictac_s * 1e6, 3),
                  modeled_issue_overlap_us=round(issue_s * 1e6, 3))
        for b in self.order:
            hops = self.hop_model(b, arch)
            rec.begin(f"bucket{b}", pid=pid, tid=tid, cat="comm",
                      elems=self.bucket_len(b),
                      padded=pad_for_schedule(self.bucket_len(b), self.n),
                      leaves=len(self.buckets[b]),
                      tx_bytes=int(sum(x for _, x in hops)))
            for h, (kind, nbytes) in enumerate(hops):
                # mean per-worker bytes can be fractional (tree halves);
                # keep the fraction so hop sums match the accounting
                rec.instant("hop", pid=pid, tid=tid, cat="comm",
                            hop=h, kind=kind, tx_bytes=round(nbytes, 3))
            rec.end(pid=pid, tid=tid)
        rec.end(pid=pid, tid=tid)

    # --------------------------------------------------------- accounting
    def modeled_timeline(self) -> Dict[str, float]:
        """Iteration-time projections for the exact bucket plan this
        engine executes — the no-overlap vs overlap comparison."""
        return {
            "no_overlap_s": schedule_no_overlap(self.fused, self.link),
            "overlap_s": schedule_overlap(self.fused, self.link,
                                          self.order),
            "n_buckets": len(self.fused),
        }

    def measured_step_tx_bytes(self, arch: str = "allreduce") -> int:
        """Shape-static measured bytes ONE worker puts on the wire per
        BSP step — recomputed per bucket from the plan (never cached from
        a step-0 trace).  For the exact codec this is the fp32 schedule;
        for ``ps`` the gradient RS is encoded and the parameter AG is
        fp32.  Add ``SPARSE_ELEM_BYTES * sent_elems`` for dgc."""
        codec = self.codec if self.in_schedule else codec_for(
            Compressor("none"))
        # bf16 reduce halves the exact codec's wire words (its accounting
        # is linear in length, so scaling the schedule total is exact);
        # lossy planes and the fp32 parameter all-gather are unaffected
        scale = (self.word_bytes / 4
                 if codec.exact and self.word_bytes != 4 else 1.0)
        total = 0.0
        for b in range(len(self.buckets)):
            L = self.bucket_len(b)
            P = pad_for_schedule(L, self.n)
            if arch == "ps":
                m = P // self.n
                rs = (self.n - 1) * codec.static_tx_bytes(m) * scale
                ag = (self.n - 1) * 4 * m          # params travel exact
                total += rs + ag
            else:
                total += schedule_tx_bytes(self.topology, self.n, P,
                                           codec) * scale
        return int(total)

    def measured_bytes(self, sent_elems: int) -> int:
        """Data-dependent measured bytes for ``sent_elems`` sparse
        elements (dgc's per-step payload)."""
        return int(sent_elems) * SPARSE_ELEM_BYTES

    def fp32_step_tx_bytes(self) -> int:
        """The full-precision schedule's per-worker tx bytes per step —
        the baseline compressed-payload ratios are quoted against."""
        total = 0.0
        for b in range(len(self.buckets)):
            P = pad_for_schedule(self.bucket_len(b), self.n)
            total += fp32_schedule_bytes(self.topology, self.n, P)
        return int(total)

    def modeled_event_bytes(self, params_example) -> int:
        """The compressor's analytic per-push accounting (what the
        simulator reports) — the ``wire="modeled"`` step increment."""
        return modeled_event_bytes(self.compressor, params_example)
