"""Topology schedule generators: collective schedules whose steps carry
*encoded* segment payloads (survey §3.3.1(2) × §3.3.3 co-design).

Two families live here:

1. The **exact** schedules (the pre-refactor ``core/allreduce.py``
   topologies, moved verbatim): full-precision ppermute schedules,
   numerically equal to ``psum``.  ``core/allreduce.py`` re-exports them,
   and every ``none``-codec exchange runs them unchanged — that is the
   bitwise-compatibility contract of the refactor.

2. The **codec** schedules (``compressed_allreduce`` /
   ``compressed_reduce_scatter``): the same topologies, but every
   transmission is ``encode → ppermute the planes → decode``:

   * ring reduce-scatter: each hop encodes the *partial sum* it forwards;
     the hop's quantization error is accumulated into the sender's
     error-feedback residual at that chunk position (per-link EF — the
     residual re-enters the sender's own gradient next step).
   * ring all-gather: the chunk's owner encodes its reduced chunk *once*
     (owner EF) and the planes are relayed unchanged around the ring, so
     every worker decodes identical bytes — replicated parameters cannot
     drift.
   * tree: re-encode up the reduce tree (sender EF per hop); the root
     encodes the total once and the planes broadcast down unchanged.
   * butterfly: lossy butterfly runs *halving-doubling* (recursive-halving
     reduce-scatter with hop EF + an all-gather of the owner-encoded
     planes).  A lossy recursive-doubling exchange would hand every
     worker a differently-quantized sum — inconsistent replicas — so the
     exact and lossy butterfly schedules intentionally differ; the byte
     models below account for both.
   * fully-connected: every worker encodes its own contribution once and
     all-gathers the planes; everyone decodes the same n payloads.

   All generators return ``(result, residual, sent_elems)`` where
   ``residual`` is the flat per-worker EF contribution of every encode
   this worker performed and ``sent_elems`` is the traced count of
   data-dependent sparse elements shipped (dgc; 0 otherwise).

Byte accounting: ``schedule_tx_bytes`` is the *mean per-worker* bytes a
schedule puts on the wire (total transmissions / n) for the shape-static
part of the payloads; ``model_error_factor`` documents the exact ratio
between the legacy critical-path model ``per_device_bytes`` and this
mean-tx measure per topology (see docs/comm.md).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm.codecs import NoneCodec, SegmentCodec
from repro.core.collectives import axis_size


# ===================================================== exact schedules
# (the pre-refactor core/allreduce.py implementations, moved verbatim;
# core/allreduce.py re-exports them so existing call sites — and bitwise
# behaviour — are unchanged)
def ring_allreduce(x, axis_name: str):
    """Bandwidth-optimal ring: reduce-scatter then all-gather, 2(n-1) steps."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    me = lax.axis_index(axis_name)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    chunks = jnp.pad(flat, (0, pad)).reshape(n, -1)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def rs_step(i, c):
        send = c[(me - i) % n]
        recv = lax.ppermute(send, axis_name, fwd)
        return c.at[(me - i - 1) % n].add(recv)

    chunks = lax.fori_loop(0, n - 1, rs_step, chunks)
    # rank r now owns reduced chunk (r + 1) % n

    def ag_step(i, c):
        send = c[(me + 1 - i) % n]
        recv = lax.ppermute(send, axis_name, fwd)
        return c.at[(me - i) % n].set(recv)

    chunks = lax.fori_loop(0, n - 1, ag_step, chunks)
    return chunks.reshape(-1)[:flat.shape[0]].reshape(shape).astype(dtype)


def butterfly_allreduce(x, axis_name: str):
    """Recursive doubling: log2(n) exchange-and-add rounds (n power of 2)."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    assert n & (n - 1) == 0, "butterfly requires power-of-two workers"
    acc = x
    for k in range(int(math.log2(n))):
        d = 1 << k
        perm = [(i, i ^ d) for i in range(n)]
        acc = acc + lax.ppermute(acc, axis_name, perm)
    return acc


def tree_allreduce(x, axis_name: str):
    """Binomial tree: reduce to rank 0, then broadcast back down."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    me = lax.axis_index(axis_name)
    levels = int(math.log2(n))
    assert 1 << levels == n, "tree requires power-of-two workers"
    acc = x
    # reduce phase: at level k, ranks with me % 2^(k+1) == 2^k send down
    for k in range(levels):
        d = 1 << k
        perm = [(i, i - d) for i in range(n) if i % (2 * d) == d]
        recv = lax.ppermute(acc, axis_name, perm)
        is_receiver = (me % (2 * d)) == 0
        acc = jnp.where(is_receiver, acc + recv, acc)
    # broadcast phase
    for k in reversed(range(levels)):
        d = 1 << k
        perm = [(i, i + d) for i in range(n) if i % (2 * d) == 0]
        recv = lax.ppermute(acc, axis_name, perm)
        is_receiver = (me % (2 * d)) == d
        acc = jnp.where(is_receiver, recv, acc)
    return acc


def fully_connected_allreduce(x, axis_name: str):
    """Every worker sends its full tensor to every other (the O(n^2) traffic
    case the survey warns about); numerically an all_gather + sum."""
    g = lax.all_gather(x, axis_name)
    return jnp.sum(g, axis=0).astype(x.dtype)


def psum_allreduce(x, axis_name: str):
    return lax.psum(x, axis_name)


SCHEDULES = {
    "ring": ring_allreduce,
    "butterfly": butterfly_allreduce,
    "tree": tree_allreduce,
    "fully_connected": fully_connected_allreduce,
    "psum": psum_allreduce,
}


# ===================================================== codec schedules
def _permute(planes: Dict[str, Any], axis_name: str, perm):
    return jax.tree.map(
        lambda p: lax.ppermute(p, axis_name, perm), planes)


def _where_planes(cond, new: Dict[str, Any], old: Dict[str, Any]):
    return jax.tree.map(lambda a, b: jnp.where(cond, a, b), new, old)


def _ring_rs(flat, axis_name: str, codec: SegmentCodec, key, n: int):
    """Compressed ring reduce-scatter: rank r ends owning reduced chunk r.
    Returns (chunks [n, m] with c[me] reduced, residual [n, m],
    sent_elems, key)."""
    me = lax.axis_index(axis_name)
    m = flat.shape[0] // n
    c = flat.reshape(n, m)
    res = jnp.zeros_like(c)
    sent = jnp.zeros((), jnp.int32)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def step(i, carry):
        c, res, sent, key = carry
        key, sub = jax.random.split(key)
        pos = (me - i - 1) % n
        send = c[pos]
        # fused encode + hop EF: one read of the chunk yields the planes
        # and the quantization residual (send - decode) together
        planes, r = codec.encode_ef(send, sub)
        res = res.at[pos].add(r)
        sent = sent + codec.sent_elems(planes)
        planes = _permute(planes, axis_name, fwd)
        recv = codec.decode(planes)[:m]
        return c.at[(me - i - 2) % n].add(recv), res, sent, key

    return lax.fori_loop(0, n - 1, step, (c, res, sent, key))


def _owner_encode(c, res, pos, codec: SegmentCodec, key):
    """Encode chunk ``pos`` once at its owner (EF the encode error) and
    replace it with its own decode so every worker — owner included —
    consumes identical bytes.  Encoding is not itself a transmission:
    the caller's distribution loop counts every send of these planes."""
    m = c.shape[1]
    planes = codec.encode(c[pos], key)
    dec = codec.decode(planes)[:m]
    res = res.at[pos].add(c[pos] - dec)
    return c.at[pos].set(dec), res, planes


def _ring_exchange(flat, axis_name: str, codec: SegmentCodec, key):
    n = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    m = flat.shape[0] // n
    fwd = [(i, (i + 1) % n) for i in range(n)]
    c, res, sent, key = _ring_rs(flat, axis_name, codec, key, n)
    key, sub = jax.random.split(key)
    c, res, planes = _owner_encode(c, res, me, codec, sub)

    def ag_step(i, carry):
        c, planes, sent = carry
        # one transmission per hop: i=0 is the owner's own send, later
        # iterations are relays — n-1 sends total per plane
        sent = sent + codec.sent_elems(planes)
        planes = _permute(planes, axis_name, fwd)
        c = c.at[(me - 1 - i) % n].set(codec.decode(planes)[:m])
        return c, planes, sent

    c, _, sent = lax.fori_loop(0, n - 1, ag_step, (c, planes, sent))
    return c.reshape(-1), res.reshape(-1), sent


def _butterfly_exchange(flat, axis_name: str, codec: SegmentCodec, key):
    """Halving-doubling: recursive-halving RS (hop EF) + an all-gather of
    the owner-encoded chunk planes (consistent decode everywhere)."""
    n = axis_size(axis_name)
    assert n & (n - 1) == 0, "butterfly requires power-of-two workers"
    me = lax.axis_index(axis_name)
    m = flat.shape[0] // n
    acc = flat.reshape(n, m)
    res = jnp.zeros_like(acc)
    sent = jnp.zeros((), jnp.int32)
    levels = int(math.log2(n))
    for k in range(levels):
        d = n >> (k + 1)                      # rank and chunk distance
        base = me & ~((n >> k) - 1)
        has_upper = (me & d) != 0
        my_start = base + jnp.where(has_upper, d, 0)
        send_start = base + jnp.where(has_upper, 0, d)
        send = lax.dynamic_slice(acc, (send_start, 0), (d, m))
        key, sub = jax.random.split(key)
        planes, r = codec.encode_ef(send.reshape(-1), sub)
        res_slice = lax.dynamic_slice(res, (send_start, 0), (d, m))
        res = lax.dynamic_update_slice(res, res_slice + r.reshape(d, m),
                                       (send_start, 0))
        sent = sent + codec.sent_elems(planes)
        planes = _permute(planes, axis_name, [(i, i ^ d) for i in range(n)])
        recv = codec.decode(planes)[:d * m].reshape(d, m)
        mine = lax.dynamic_slice(acc, (my_start, 0), (d, m))
        acc = lax.dynamic_update_slice(acc, mine + recv, (my_start, 0))
    key, sub = jax.random.split(key)
    acc, res, planes = _owner_encode(acc, res, me, codec, sub)
    sent = sent + codec.sent_elems(planes) * (n - 1)   # AG transmissions
    gathered = jax.tree.map(lambda p: lax.all_gather(p, axis_name), planes)
    chunks = jax.vmap(codec.decode)(gathered)[:, :m]   # [n, m], identical
    return chunks.reshape(-1), res.reshape(-1), sent


def _tree_exchange(flat, axis_name: str, codec: SegmentCodec, key):
    n = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    L = flat.shape[0]
    levels = int(math.log2(n))
    assert 1 << levels == n, "tree requires power-of-two workers"
    acc = flat
    res = jnp.zeros_like(flat)
    sent = jnp.zeros((), jnp.int32)
    # reduce: senders re-encode their partial and EF the encode error
    for k in range(levels):
        d = 1 << k
        is_sender = (me % (2 * d)) == d
        is_receiver = (me % (2 * d)) == 0
        key, sub = jax.random.split(key)
        planes, r = codec.encode_ef(acc, sub)
        res = res + jnp.where(is_sender, r, 0.0)
        sent = sent + jnp.where(is_sender, codec.sent_elems(planes), 0)
        perm = [(i, i - d) for i in range(n) if i % (2 * d) == d]
        recv = codec.decode(_permute(planes, axis_name, perm))[:L]
        acc = jnp.where(is_receiver, acc + recv, acc)
    # root encodes the total once; the planes broadcast down *unchanged*
    # (the broadcast loop counts each of the n-1 forwards — encoding
    # itself is not a transmission)
    key, sub = jax.random.split(key)
    planes, r = codec.encode_ef(acc, sub)
    res = res + jnp.where(me == 0, r, 0.0)
    for k in reversed(range(levels)):
        d = 1 << k
        is_sender = (me % (2 * d)) == 0
        is_receiver = (me % (2 * d)) == d
        sent = sent + jnp.where(is_sender, codec.sent_elems(planes), 0)
        perm = [(i, i + d) for i in range(n) if i % (2 * d) == 0]
        recv = _permute(planes, axis_name, perm)
        planes = _where_planes(is_receiver, recv, planes)
    return codec.decode(planes)[:L], res, sent


def _fully_connected_exchange(flat, axis_name: str, codec: SegmentCodec,
                              key):
    n = axis_size(axis_name)
    L = flat.shape[0]
    key, sub = jax.random.split(key)
    planes, res = codec.encode_ef(flat, sub)
    sent = codec.sent_elems(planes) * (n - 1)
    gathered = jax.tree.map(lambda p: lax.all_gather(p, axis_name), planes)
    out = jnp.sum(jax.vmap(codec.decode)(gathered)[:, :L], axis=0)
    return out, res, sent


_CODEC_EXCHANGES = {
    "ring": _ring_exchange,
    "psum": _ring_exchange,        # psum ring-schedules on the torus
    "butterfly": _butterfly_exchange,
    "tree": _tree_exchange,
    "fully_connected": _fully_connected_exchange,
}


def compressed_allreduce(flat, axis_name: str, topology: str,
                         codec: SegmentCodec, key
                         ) -> Tuple[Any, Any, Any]:
    """Sum-allreduce a flat fp32 vector with encoded payloads inside the
    ``topology`` schedule.  ``flat`` must be padded so every chunk is a
    whole number of LANE-wide rows (``pad_for_schedule``).  Returns
    ``(reduced_sum, ef_residual, sent_elems)``; callers divide by the
    axis size for mean semantics and fold the residual into per-worker
    error-feedback state."""
    return _CODEC_EXCHANGES[topology](flat, axis_name, codec, key)


def compressed_reduce_scatter(flat, axis_name: str, codec: SegmentCodec,
                              key) -> Tuple[Any, Any, Any]:
    """Compressed ring reduce-scatter: rank r receives the reduced chunk
    r of ``flat`` (shape [len/n]).  Returns (my_shard_sum, residual,
    sent_elems) — the gradient-push half of the PS / ZeRO exchange."""
    n = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    c, res, sent, _ = _ring_rs(flat, axis_name, codec, key, n)
    return c[me], res.reshape(-1), sent


def compressed_allreduce_ef(flat, ef, axis_name: str, topology: str,
                            codec: SegmentCodec, key, *, gain: float = 1.0
                            ) -> Tuple[Any, Any, Any]:
    """EF-compensated exchange: the transport owns the whole residual
    lifecycle — compensate ``c_in = flat + gain*ef``, run the codec
    schedule (every hop's encode is the fused ``encode_ef``), and fold
    the hop residuals into the returned next-step EF vector, measured
    against the true compensated gradient ``flat + ef`` so the
    telescoping invariant holds for any over-relaxation gain.  Callers
    (``CommPlan``) hand the residual down instead of applying EF as
    separate jnp passes around the schedule.  Returns
    ``(reduced_sum, new_ef, sent_elems)``."""
    cin = flat + gain * ef
    red, res, sent = _CODEC_EXCHANGES[topology](cin, axis_name, codec, key)
    return red, (flat + ef) - cin + res, sent


def compressed_reduce_scatter_ef(flat, ef, axis_name: str,
                                 codec: SegmentCodec, key, *,
                                 gain: float = 1.0) -> Tuple[Any, Any, Any]:
    """EF-compensated ring reduce-scatter (see ``compressed_allreduce_ef``
    — the PS/ZeRO gradient-push counterpart)."""
    cin = flat + gain * ef
    shard, res, sent = compressed_reduce_scatter(cin, axis_name, codec, key)
    return shard, (flat + ef) - cin + res, sent


def pad_for_schedule(length: int, n: int) -> int:
    """Padded flat length for a chunked schedule: a whole number of 1/n
    chunks (codecs row-pad each payload internally)."""
    return n * (-(-length // n))


# ======================================================== byte models
def per_device_bytes(topology: str, n: int, size_bytes: float) -> float:
    """Analytic critical-path traffic for one exchange (the pre-refactor
    benchmark model, unchanged): the bytes crossing the busiest device's
    links.  See ``model_error_factor`` for how it relates to the measured
    mean per-worker tx bytes."""
    if n == 1:
        return 0.0
    if topology in ("ring", "psum"):
        return 2 * (n - 1) / n * size_bytes
    if topology == "butterfly":
        return math.log2(n) * size_bytes
    if topology == "tree":
        return 2 * math.log2(n) * size_bytes
    if topology == "fully_connected":
        return (n - 1) * size_bytes
    raise ValueError(topology)


def schedule_tx_bytes(topology: str, n: int, length: int,
                      codec: SegmentCodec) -> float:
    """Mean per-worker bytes one exchange of a padded length-``length``
    segment puts on the wire (total schedule transmissions / n), for the
    shape-static part of the codec's payloads.  This is what the measured
    wire accounting reports for static codecs; dgc adds 8 B per traced
    ``sent_elems``."""
    if n == 1:
        return 0.0
    m = -(-length // n)
    e = codec.static_tx_bytes
    if topology in ("ring", "psum"):
        # RS: n-1 hop encodes; AG: owner encode relayed n-1 hops
        return (n - 1) * e(m) + (n - 1) * e(m)
    if topology == "butterfly":
        if codec.exact:
            return math.log2(n) * e(length)       # recursive doubling
        rs = sum(e((n >> (k + 1)) * m) for k in range(int(math.log2(n))))
        return rs + (n - 1) * e(m)                # halving + plane AG
    if topology == "tree":
        # n-1 reduce sends + n-1 broadcast forwards of the full payload
        return 2 * (n - 1) / n * e(length)
    if topology == "fully_connected":
        return (n - 1) * e(length)
    raise ValueError(topology)


def fp32_schedule_bytes(topology: str, n: int, length: int) -> float:
    """Mean per-worker tx bytes of the full-precision schedule — the
    baseline the compressed-payload ratios are quoted against."""
    return schedule_tx_bytes(topology, n, length, NoneCodec())


def model_error_factor(topology: str, n: int, exact: bool = True) -> float:
    """The documented ratio ``per_device_bytes / schedule_tx_bytes`` per
    topology (docs/comm.md): the critical-path model counts the busiest
    device (tree: the root's rx+tx), the measured accounting counts the
    mean per-worker tx.  Divide ``per_device_bytes`` by this factor to
    predict the measured value."""
    if n == 1:
        return 1.0
    if topology in ("ring", "psum", "fully_connected"):
        return 1.0
    if topology == "tree":
        return math.log2(n) * n / (n - 1)
    if topology == "butterfly":
        if exact:
            return 1.0
        return math.log2(n) * n / (2 * (n - 1))
    raise ValueError(topology)
