"""Measured straggler *detection* (survey §3.2.3): per-worker step-time
EMAs feeding the ``bsp+backup:k`` drop set.

The backup-worker policy (elastic/backup.py) originally ranked workers by
the *plan-scheduled* speed schedule — ``slow:wIxF@t`` events the run was
told about.  Real stragglers are not announced; this module measures
them.  Each BSP round, both engines time every worker's host-side work
(batch fetch, plus the gradient computation in the simulator, where it is
per-worker) and fold it into an exponential moving average; once every
worker has ``warmup`` observations, the EMA ranking *replaces* the
scheduled ranking in the drop set (``Strategy(detect=True)`` /
``"bsp+backup:1+detect"``).

Determinism note: the drop set becomes a function of wall-clock
measurements, so detect-mode runs are reproducible only insofar as the
straggler is.  The cross-validation tests drive a real (sleeping) data
source and assert the measured drop set converges to the one the
equivalent ``slow:wIxF`` plan schedules.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.elastic.backup import drop_set


class StepTimeEMA:
    """Per-worker step-time EMA with the same drop-ranking rule as the
    scheduled policy (ties toward the higher worker id)."""

    def __init__(self, num_workers: int, alpha: float = 0.5,
                 warmup: int = 2):
        if not 0 < alpha <= 1:
            raise ValueError("alpha must be in (0, 1]")
        if warmup < 1:
            raise ValueError("warmup must be >= 1")
        self.alpha = alpha
        self.warmup = warmup
        self.ema: List[Optional[float]] = [None] * num_workers
        self.count: List[int] = [0] * num_workers

    def observe(self, worker: int, seconds: float) -> None:
        self.count[worker] += 1
        if self.count[worker] == 1:
            # a worker's first measurement absorbs one-time costs (JIT
            # compilation of the shared step, cold caches) and would
            # mis-rank whoever pays them as the straggler — discard it
            return
        prev = self.ema[worker]
        self.ema[worker] = (seconds if prev is None
                            else self.alpha * seconds
                            + (1 - self.alpha) * prev)

    @property
    def ready(self) -> bool:
        """True once every worker has ``warmup`` measurements — before
        that the engines fall back to the scheduled ranking."""
        return all(c >= self.warmup for c in self.count)

    def factors(self) -> List[float]:
        """Measured slowdown estimates, normalized to the fastest worker
        (1.0 = fastest; unmeasured workers report 1.0)."""
        known = [e for e in self.ema if e is not None]
        base = min(known) if known else 1.0
        base = base or 1.0
        return [1.0 if e is None else e / base for e in self.ema]

    def drop_set(self, k: int):
        """The k measured-slowest workers, same tie rule as the scheduled
        policy."""
        return drop_set([1.0 if e is None else e for e in self.ema], k)

    # ------------------------------------------------------ elastic plumbing
    def reshard(self, slots: Sequence[int], new_workers: int) -> None:
        """Survivor slots keep their measurements; grown slots start
        unmeasured (and hold the drop set back until re-warmed)."""
        grown = new_workers - len(slots)
        self.ema = [self.ema[s] for s in slots] + [None] * grown
        self.count = [self.count[s] for s in slots] + [0] * grown

    def state(self) -> Dict:
        return {"ema": list(self.ema), "count": list(self.count)}

    def load_state(self, state: Optional[Dict]) -> None:
        if not state:
            return
        self.ema = [None if e is None else float(e) for e in state["ema"]]
        self.count = [int(c) for c in state["count"]]
