"""Declarative elastic event plans (survey §3.2.3 / §3.4.2).

A plan is a schedule of events against a training run's *global step*
clock — worker w crashes before step t, the job is resized N→M before
step t, worker w slows down ×f before step t, the job is suspended and
resumed (checkpoint-restart) before step t.  Plans are frozen data; the
elastic trainer (elastic/recovery.py) consumes them through a one-shot
cursor so a post-crash rollback cannot re-fire the crash.

Grammar (``EventPlan.parse`` / ``.spec()`` are inverses)::

    plan    := item ("," item)*
    item    := "crash:w" W "@" T        worker W crashes before step T
             | "resize:" M "@" T        resize the job to M workers
             | "slow:w" W "x" F "@" T   worker W slows down ×F (F=1 clears)
             | "restart@" T             suspend + resume from checkpoint

e.g. ``"crash:w1@5,resize:4@10"`` — lose worker 1 before step 5, grow
back to 4 workers before step 10.

``FailurePlan`` / ``ResizePlan`` / ``StragglerPlan`` are typed
conveniences over the same event stream; ``plan_from_sched_trace``
converts a ``sched/`` simulator allocation trace (Gandiva suspend/resume
+ elastic resize decisions) into a plan, closing the scheduler↔trainer
loop: the multi-tenant simulator decides *when* a job loses or regains
capacity, and the Strategy engines live through it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

KINDS = ("crash", "resize", "slow", "restart")


@dataclasses.dataclass(frozen=True)
class ElasticEvent:
    """One scheduled event; fires immediately *before* global step
    ``step`` executes."""
    step: int
    kind: str                  # crash | resize | slow | restart
    worker: int = -1           # crash/slow target
    workers: int = 0           # resize target size
    factor: float = 1.0        # slow multiplier (1.0 clears)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind={self.kind!r} not in {KINDS}")
        if self.step < 0:
            raise ValueError("event step must be >= 0")
        if self.kind in ("crash", "slow") and self.worker < 0:
            raise ValueError(f"{self.kind} event needs a worker index")
        if self.kind == "resize" and self.workers < 1:
            raise ValueError("resize event needs workers >= 1")
        if self.kind == "slow" and self.factor <= 0:
            raise ValueError("slow factor must be > 0")

    def spec(self) -> str:
        if self.kind == "crash":
            return f"crash:w{self.worker}@{self.step}"
        if self.kind == "resize":
            return f"resize:{self.workers}@{self.step}"
        if self.kind == "slow":
            return f"slow:w{self.worker}x{self.factor:g}@{self.step}"
        return f"restart@{self.step}"


def _parse_item(item: str) -> ElasticEvent:
    item = item.strip()
    if "@" not in item:
        raise ValueError(f"bad plan item {item!r}: missing '@step'")
    head, step_s = item.rsplit("@", 1)
    step = int(step_s)
    if head == "restart":
        return ElasticEvent(step=step, kind="restart")
    if ":" not in head:
        raise ValueError(f"bad plan item {item!r}: want kind:args@step")
    kind, arg = head.split(":", 1)
    if kind == "crash":
        if not arg.startswith("w"):
            raise ValueError(f"bad plan item {item!r}: want crash:wN@T")
        return ElasticEvent(step=step, kind="crash", worker=int(arg[1:]))
    if kind == "resize":
        return ElasticEvent(step=step, kind="resize", workers=int(arg))
    if kind == "slow":
        if not arg.startswith("w") or "x" not in arg:
            raise ValueError(f"bad plan item {item!r}: want slow:wNxF@T")
        w_s, f_s = arg[1:].split("x", 1)
        return ElasticEvent(step=step, kind="slow", worker=int(w_s),
                            factor=float(f_s))
    raise ValueError(f"bad plan item {item!r}: unknown kind {kind!r}")


class EventPlan:
    """An ordered, immutable schedule of elastic events."""

    def __init__(self, events: Iterable[ElasticEvent] = ()):
        self.events: Tuple[ElasticEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.step, KINDS.index(e.kind))))

    @classmethod
    def parse(cls, text: str) -> "EventPlan":
        text = text.strip()
        if not text:
            return cls()
        return cls(_parse_item(i) for i in text.split(","))

    def spec(self) -> str:
        return ",".join(e.spec() for e in self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    @property
    def needs_checkpoints(self) -> bool:
        return any(e.kind in ("crash", "restart") for e in self.events)

    def start(self) -> "PlanRun":
        return PlanRun(self)


class PlanRun:
    """Consume-once cursor over a plan: ``take(t)`` returns the not-yet
    consumed events scheduled at or before step t.  After a crash rolls
    the run back, already-consumed events (including the crash itself)
    stay consumed — a plan fires each event exactly once.

    The cursor remembers what it consumed (``consumed_specs``) so the
    elastic trainer can persist it in checkpoints: a preempted-and-
    resumed run must not re-fire events its previous incarnation already
    lived through, and "already fired" is NOT derivable from the resume
    step alone (a crash rollback restores a checkpoint *earlier* than the
    crash event it consumed)."""

    def __init__(self, plan: EventPlan):
        self._pending: List[ElasticEvent] = list(plan.events)
        self._consumed: List[ElasticEvent] = []

    def take(self, step: int) -> List[ElasticEvent]:
        due = [e for e in self._pending if e.step <= step]
        self._pending = [e for e in self._pending if e.step > step]
        self._consumed.extend(due)
        return due

    def take_one(self, step: int) -> "ElasticEvent | None":
        """Pop and return the next due event only — a crash rollback can
        then leave the rest of the batch pending so nothing is lost."""
        for i, e in enumerate(self._pending):
            if e.step <= step:
                self._consumed.append(e)
                return self._pending.pop(i)
        return None

    def consumed_specs(self) -> List[str]:
        """Specs of every event fired so far, in firing order."""
        return [e.spec() for e in self._consumed]

    def mark_consumed(self, specs: Sequence[str]) -> None:
        """Replay a previous incarnation's consumption record (from a
        checkpoint): each spec removes one matching pending event."""
        for spec in specs:
            for i, e in enumerate(self._pending):
                if e.spec() == spec:
                    self._consumed.append(self._pending.pop(i))
                    break

    @property
    def pending(self) -> Tuple[ElasticEvent, ...]:
        return tuple(self._pending)


# ----------------------------------------------------------- typed plans
@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Worker crashes: ``crashes = ((step, worker), ...)``."""
    crashes: Tuple[Tuple[int, int], ...] = ()

    def events(self) -> List[ElasticEvent]:
        return [ElasticEvent(step=s, kind="crash", worker=w)
                for s, w in self.crashes]


@dataclasses.dataclass(frozen=True)
class ResizePlan:
    """Scheduler-driven resizes: ``resizes = ((step, new_workers), ...)``."""
    resizes: Tuple[Tuple[int, int], ...] = ()

    def events(self) -> List[ElasticEvent]:
        return [ElasticEvent(step=s, kind="resize", workers=m)
                for s, m in self.resizes]


@dataclasses.dataclass(frozen=True)
class StragglerPlan:
    """Worker slowdowns: ``slows = ((step, worker, factor), ...)``."""
    slows: Tuple[Tuple[int, int, float], ...] = ()

    def events(self) -> List[ElasticEvent]:
        return [ElasticEvent(step=s, kind="slow", worker=w, factor=f)
                for s, w, f in self.slows]


def merge_plans(*plans) -> EventPlan:
    """Combine EventPlans and/or typed plans into one schedule."""
    events: List[ElasticEvent] = []
    for p in plans:
        if isinstance(p, EventPlan):
            events.extend(p.events)
        else:
            events.extend(p.events())
    return EventPlan(events)


# -------------------------------------------------- scheduler → trainer
def plan_from_sched_trace(trace: Sequence, jid: int,
                          steps_per_sec: float = 1.0,
                          nominal_gpus: int = 0) -> EventPlan:
    """Convert one job's ``sched/`` simulator allocation trace into an
    event plan against the job's own training-step clock.

    ``trace`` rows are the simulator's ``TraceEvent``s (time, jid, kind
    in start/suspend/resume/finish, gpus).  The job's step clock advances
    at ``steps_per_sec`` only while it holds an allocation.  A resume at
    the same GPU count becomes a ``restart`` (Gandiva suspend/resume =
    checkpoint + restore); a resume at a different count becomes a
    ``resize`` (elastic re-allocation).  Pass the job's requested size as
    ``nominal_gpus`` so a *shrunk start* (``simulate(elastic=True)``
    granting fewer GPUs than requested) also emits its initial
    ``resize`` — the trainer is assumed to be configured at the nominal
    size."""
    rows = sorted((e for e in trace if e.jid == jid), key=lambda e: e.t)
    events: List[ElasticEvent] = []
    steps = 0.0
    cur_gpus = None
    run_from = None
    for e in rows:
        if e.kind == "start":
            if nominal_gpus and e.gpus != nominal_gpus:
                events.append(ElasticEvent(step=int(round(steps)),
                                           kind="resize", workers=e.gpus))
            cur_gpus, run_from = e.gpus, e.t
        elif e.kind == "suspend" and run_from is not None:
            steps += (e.t - run_from) * steps_per_sec
            run_from = None
        elif e.kind == "resume":
            at = max(1, int(round(steps)))
            if cur_gpus is not None and e.gpus != cur_gpus:
                events.append(ElasticEvent(step=at, kind="resize",
                                           workers=e.gpus))
            else:
                events.append(ElasticEvent(step=at, kind="restart"))
            cur_gpus, run_from = e.gpus, e.t
        elif e.kind == "finish" and run_from is not None:
            steps += (e.t - run_from) * steps_per_sec
            run_from = None
    return EventPlan(events)
