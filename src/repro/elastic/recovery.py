"""Checkpoint-recovery and scheduler-driven resize for Strategy engines.

``fit_elastic`` is the elastic counterpart of ``repro.train.strategy.fit``:
it drives any Strategy engine step by step while consuming an elastic
event plan (elastic/events.py).  Semantics, in the order events fire
(always *before* the step they are scheduled at):

  slow:wNxF   straggler: the engine's speed schedule scales worker N's
              period by F — changes the async firing schedule and the
              ``bsp+backup:k`` drop set (elastic/backup.py).
  resize:M@t  scheduler grant/revoke: the engine reshards N→M live, in
              process — no rollback.  Survivor workers keep their EF
              residuals and batch clocks; data streams are re-assigned
              through ``data/partition.stream_assignment``.  A
              post-reshard checkpoint is written immediately so a later
              crash never restores across a resize boundary.
  crash:wN@t  failure: the run rolls back to the latest committed
              checkpoint, reshards to the surviving K-1 workers (slot N
              dropped), and continues — work since the checkpoint is
              lost (counted in ``metrics["recoveries"]``), the process
              survives.
  restart@t   Gandiva-style suspend/resume: snapshot now, then restore —
              exercises the full save→load→import path with zero lost
              steps.

Engine state travels through ``repro.checkpoint``: arrays (params, EF
residuals, per-worker pulled copies, rng) in the sharded npz store,
bookkeeping (worker count, tick/update counters, staleness clocks) in the
manifest's ``extra`` blob.  Checkpoints are atomic (store.py), so a crash
mid-save leaves the previous checkpoint intact.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint.store import (is_valid_checkpoint, load_checkpoint,
                                    read_manifest, save_checkpoint)
from repro.data.partition import stream_assignment
from repro.elastic.events import EventPlan, merge_plans
from repro.obs.trace import get_recorder

_CKPT_FMT = "step_{:06d}"


# ------------------------------------------------------- engine snapshots
def save_engine_state(path: str, engine, state, step: int,
                      history_len: int = 0,
                      extra: Optional[Dict[str, Any]] = None,
                      incremental_from: Optional[str] = None,
                      shard_bytes: int = 512 * 1024 * 1024,
                      background: bool = False
                      ) -> Optional[threading.Thread]:
    """Atomically snapshot an engine's full run-state at ``step``.
    ``extra`` adds trainer-level bookkeeping (e.g. the consumed event
    record) to the manifest next to the engine's own meta.
    ``incremental_from`` enables hash-skip shard linking against a
    previous committed snapshot (checkpoint/store.py) — restores stay
    bitwise-identical.  Engine snapshots always carry content hashes so
    the *next* cadence save can link against this one even when this
    save is full (crash/preemption commits).

    ``background=True`` dispatches only the *file write* to a daemon
    thread and returns it for the caller to join; the device→host export
    still happens here, synchronously, so the captured arrays are the
    state at call time no matter how far the training loop has advanced
    by the time the write lands.  The snapshot does not count as
    committed until the returned thread is joined — atomicity
    (store.py's rename commit) guarantees a reader meanwhile sees either
    the previous checkpoint or nothing, never a torn one."""
    arrays, meta = engine.export_state(state)
    meta = dict(meta, step=int(step), history_len=int(history_len),
                **(extra or {}))

    def write():
        save_checkpoint(path, arrays, step=int(step), extra=meta,
                        incremental_from=incremental_from,
                        shard_bytes=shard_bytes, hash_leaves=True)

    if background:
        th = threading.Thread(target=write, name=f"ckpt-write-{step}",
                              daemon=True)
        th.start()
        return th
    write()
    return None


def restore_engine_state(path: str, engine, params_like
                         ) -> Tuple[Any, Dict[str, Any]]:
    """Load a snapshot back into ``engine`` (resharding it first if the
    snapshot was taken at a different worker count).  ``params_like``
    only provides the parameter pytree *structure* for decoding.
    Returns (state, meta)."""
    meta = read_manifest(path)["extra"]
    # one throwaway init provides the pytree structure; reshard it (not a
    # second init) when the snapshot was taken at a different size
    probe = engine.init(params_like)
    if meta["num_workers"] != _engine_workers(engine):
        probe = engine.reshard(probe, meta["num_workers"],
                               step=meta["step"])
    template, _ = engine.export_state(probe)
    arrays, _step = load_checkpoint(path, template)
    state = engine.import_state(arrays, meta)
    return state, meta


def _engine_workers(engine) -> int:
    inner = getattr(engine, "inner", engine)
    return inner.cfg.num_workers


def _engine_streams(engine) -> int:
    """Batch streams the engine consumes: the data-parallel slot count.
    For the flat engines that equals the worker count; a hybrid engine
    spreads its workers over tensor/stage axes too and exposes the data
    axis as ``data_streams``."""
    inner = getattr(engine, "inner", engine)
    return getattr(inner, "data_streams", inner.cfg.num_workers)


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    """Newest committed (manifest-bearing) step_* checkpoint, or None."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        if name.startswith("step_") and is_valid_checkpoint(full):
            try:
                step = int(name.split("_", 1)[1])
            except ValueError:
                continue
            if best is None or step > best[0]:
                best = (step, full)
    return best[1] if best else None


# --------------------------------------------------------- elastic batches
class ElasticBatches:
    """Worker→stream indirection for resizable jobs.

    The user's ``batches(t, s)`` is keyed by a *logical stream* s in
    [0, n_streams); each worker slot covers an ordered list of streams
    through ``data/partition.stream_assignment`` (identity at nominal
    size, so an unresized run sees exactly the original batches) and
    rotates through its list by step — after a shrink the M workers keep
    covering all N streams instead of starving N−M of them.  The map is
    recomputed deterministically at every resize."""

    def __init__(self, batches: Callable[[int, int], Any], n_streams: int,
                 seed: int = 0):
        self.batches = batches
        self.n_streams = n_streams
        self.seed = seed
        self.assignment = stream_assignment(n_streams, n_streams, seed)

    def assign(self, num_workers: int) -> List[List[int]]:
        self.assignment = stream_assignment(self.n_streams, num_workers,
                                            self.seed)
        return self.assignment

    def __call__(self, t: int, worker: int):
        streams = self.assignment[worker]
        return self.batches(t, streams[t % len(streams)])


# ------------------------------------------------------------ the trainer
def fit_elastic(strategy, grad_fn: Callable, params,
                batches: Callable[[int, int], Any], steps: int, plan,
                checkpoint_dir: Optional[str] = None,
                checkpoint_every: int = 5,
                devices=None, resume: bool = False,
                preempt_signals: Optional[Tuple[int, ...]] = None):
    """Drive ``strategy``'s engine for ``steps`` global steps under an
    elastic event plan.  Returns (params, history, metrics) like
    ``Trainer.fit``; metrics additionally carry ``recoveries`` (one
    record per crash/restart), ``resizes``, ``executed_steps`` (includes
    work redone after rollbacks), ``final_workers`` and
    ``dropped_updates``.

    Real preemption: when a ``checkpoint_dir`` is given, a handler for
    ``preempt_signals`` (default: SIGTERM, main thread only) is installed
    for the duration of the run.  On delivery the loop finishes its
    in-flight step, commits a snapshot, and returns cleanly with
    ``metrics["preempted"] = True`` — the process exits 0 instead of
    dying with work lost.  A follow-up invocation with ``resume=True``
    restores the newest committed checkpoint in ``checkpoint_dir``
    (reporting ``metrics["resumed_from"]``) and finishes the remaining
    steps; plan events scheduled before the resume point are treated as
    already fired."""
    if isinstance(plan, str):
        plan = EventPlan.parse(plan)
    elif not isinstance(plan, EventPlan):
        plan = merge_plans(plan)
    if plan.needs_checkpoints and checkpoint_dir is None:
        raise ValueError("plan contains crash/restart events; "
                         "fit_elastic needs a checkpoint_dir to recover "
                         "from")
    if checkpoint_every < 1:
        raise ValueError("checkpoint_every must be >= 1")
    engine = strategy.build(grad_fn, devices)
    eb = ElasticBatches(batches, n_streams=_engine_streams(engine),
                        seed=strategy.seed)
    run = plan.start()
    st = engine.init(params)
    ckpt = (lambda step: os.path.join(checkpoint_dir,
                                      _CKPT_FMT.format(step))) \
        if checkpoint_dir else None

    history: List[dict] = []
    recoveries: List[dict] = []
    resizes = 0
    executed = 0
    # recovery only ever restores checkpoints THIS run committed —
    # a reused checkpoint_dir with stale step_* dirs from an earlier
    # run must not leak foreign state into this one (resume=True is the
    # explicit opt-in for picking up a previous incarnation's snapshot)
    written: set = set()

    rec = get_recorder()

    # at most one snapshot write in flight: cadence saves dispatch the
    # file write to a background thread so the next train step overlaps
    # the disk I/O, and every consumer of "the newest committed
    # checkpoint" — a later commit (incremental links need the previous
    # snapshot durable), crash/restart recovery, and run exit — joins it
    # first
    pending_writes: List[threading.Thread] = []

    def join_writes():
        while pending_writes:
            pending_writes.pop().join()

    def commit(step: int, state, hist_len: int, full: bool = False,
               background: bool = False):
        # every snapshot records which plan events have already fired:
        # "fired" is not derivable from the step alone (a crash rollback
        # commits *earlier* than the crash it consumed), and a resumed
        # incarnation must not re-fire any of them.
        # Periodic cadence saves are incremental (unchanged shards are
        # hash-skipped against the newest committed snapshot); crash
        # rollback and preemption commits stay full saves.
        join_writes()
        prev = ckpt(max(written)) if (written and not full) else None
        # the span measures what the training loop actually pays: for a
        # background commit that is the device→host export + dispatch,
        # not the write itself (dispatch="async" marks those records)
        with rec.span("snapshot", pid="elastic", tid="events", cat="elastic",
                      clock=("train_step", step), step=step,
                      mode="full" if prev is None else "incremental",
                      dispatch="async" if background else "sync"):
            th = save_engine_state(ckpt(step), engine, state, step, hist_len,
                                   extra={"consumed": run.consumed_specs()},
                                   incremental_from=prev,
                                   background=background)
        if th is not None:
            pending_writes.append(th)
        written.add(step)

    t = 0
    resumed_from = None
    if resume:
        if not ckpt:
            raise ValueError("resume=True needs a checkpoint_dir")
        path = latest_checkpoint(checkpoint_dir)
        if path is not None:
            st, meta = restore_engine_state(path, engine, params)
            t = resumed_from = int(meta["step"])
            eb.assign(_engine_streams(engine))
            # replay the previous incarnation's consumption record so
            # nothing it lived through fires twice
            run.mark_consumed(meta.get("consumed", ()))
            # re-commit under THIS incarnation's frame: the restored
            # checkpoint's history_len counts the previous incarnation's
            # (unavailable) history, and a later rollback truncating our
            # history with it would duplicate steps in the returned
            # record
            commit(t, st, 0)
    if ckpt and not written:
        commit(t, st, 0)

    # SIGTERM-driven preemption snapshot: flag only in the handler, act
    # at the loop boundary so the in-flight step completes first
    preempted: List[int] = []
    installed: List[Tuple[int, Any]] = []
    if ckpt and threading.current_thread() is threading.main_thread():
        sigs = ((signal.SIGTERM,) if preempt_signals is None
                else preempt_signals)
        for sig in sigs:
            installed.append((sig, signal.signal(
                sig, lambda signum, frame: preempted.append(signum))))

    try:
        while t < steps:
            if preempted:
                commit(t, st, len(history), full=True)
                break
            rolled_back = False
            # one event at a time: a crash rollback leaves the rest of the
            # due batch pending, to fire when the run reaches them again
            while (ev := run.take_one(t)) is not None:
                if ev.kind == "slow":
                    rec.instant("straggler", pid="elastic", tid="events",
                                cat="elastic", clock=("train_step", t),
                                worker=ev.worker, factor=ev.factor)
                    engine.set_slowdown(ev.worker, ev.factor)
                    if ckpt:
                        # commit so a later crash rollback (which restores
                        # pre-event slowdowns and never re-fires consumed
                        # events) cannot erase the straggler
                        commit(t, st, len(history))
                elif ev.kind == "resize":
                    with rec.span("resize", pid="elastic", tid="events",
                                  cat="elastic", clock=("train_step", t),
                                  from_workers=_engine_workers(engine),
                                  to_workers=ev.workers):
                        st = engine.reshard(st, ev.workers, step=t)
                        eb.assign(_engine_streams(engine))
                    resizes += 1
                    if ckpt:
                        # commit the post-reshard state so a later crash
                        # never restores across the resize boundary
                        commit(t, st, len(history))
                elif ev.kind in ("crash", "restart"):
                    # an in-flight cadence write may BE the newest
                    # committed snapshot — recovery must not race it
                    join_writes()
                    t0 = time.time()
                    # explicit begin/end (not a ``with``): the error paths
                    # below abort the run anyway, and a truncated trace is
                    # the honest record of a failed recovery
                    rec.begin("recovery", pid="elastic", tid="events",
                              cat="elastic", clock=("train_step", t),
                              kind=ev.kind,
                              worker=(ev.worker if ev.kind == "crash"
                                      else None))
                    if ev.kind == "restart":
                        # scheduler suspend: snapshot the live state first
                        # (full save — recovery must not depend on links)
                        commit(t, st, len(history), full=True)
                    if not written:
                        raise RuntimeError(
                            f"no checkpoint committed by this run in "
                            f"{checkpoint_dir!r} to recover from at step "
                            f"{t}")
                    path = ckpt(max(written))
                    if not is_valid_checkpoint(path):
                        raise RuntimeError(
                            f"checkpoint {path!r} is gone or torn; cannot "
                            f"recover at step {t}")
                    st, meta = restore_engine_state(path, engine, params)
                    rstep = int(meta["step"])
                    history = history[:int(meta["history_len"])]
                    # checkpoints from the abandoned timeline (steps
                    # beyond the restore point) must not satisfy a later
                    # recovery
                    written = {s for s in written if s <= rstep}
                    if ev.kind == "crash":
                        # a flat engine loses one worker; a hybrid mesh
                        # loses the dead device's whole tensor*stage
                        # block (one data replica) — the engine knows
                        inner = getattr(engine, "inner", engine)
                        if hasattr(inner, "crash_plan"):
                            survivors, lost = inner.crash_plan(ev.worker)
                        else:
                            survivors = _engine_workers(engine) - 1
                            lost = (ev.worker,)
                        st = engine.reshard(st, survivors, step=rstep,
                                            lost=lost)
                        eb.assign(_engine_streams(engine))
                        commit(rstep, st, len(history), full=True)
                    rec.end(pid="elastic", tid="events",
                            restored_step=rstep, lost_steps=t - rstep,
                            workers=_engine_workers(engine))
                    recoveries.append(dict(
                        kind=ev.kind, at=t, restored_step=rstep,
                        lost_steps=t - rstep,
                        lost_worker=ev.worker if ev.kind == "crash"
                        else None,
                        workers=_engine_workers(engine),
                        wall_s=time.time() - t0))
                    t = rstep
                    rolled_back = True
                    break
            if rolled_back:
                continue
            if ckpt and t > 0 and t % checkpoint_every == 0:
                commit(t, st, len(history), background=True)
            if rec.enabled:
                # same step track as train_loop (fit_elastic drives the
                # engine directly), so engine sub-spans nest identically
                with rec.span("step", pid="train", tid="loop", cat="train",
                              clock=("train_step", t), step=t,
                              workers=_engine_workers(engine)):
                    st, evs = engine.step(st, eb, t)
            else:
                st, evs = engine.step(st, eb, t)
            history.extend(evs)
            executed += 1
            t += 1
            if executed > steps * 10 + 100:
                raise RuntimeError("elastic run not converging on its "
                                   "step target (runaway rollback loop?)")
    finally:
        # the run is not over until its last snapshot is durable
        join_writes()
        for sig, old in installed:
            signal.signal(sig, old)

    mets = engine.metrics()
    mets.update(recoveries=recoveries, resizes=resizes,
                executed_steps=executed, wasted_steps=executed - steps,
                final_workers=_engine_workers(engine),
                preempted=bool(preempted), preempt_step=(t if preempted
                                                         else None),
                resumed_from=resumed_from)
    return engine.finalize(st), history, mets
