"""Checkpoint-recovery and scheduler-driven resize for Strategy engines.

``fit_elastic`` is the elastic counterpart of ``repro.train.strategy.fit``:
it drives any Strategy engine step by step while consuming an elastic
event plan (elastic/events.py).  Semantics, in the order events fire
(always *before* the step they are scheduled at):

  slow:wNxF   straggler: the engine's speed schedule scales worker N's
              period by F — changes the async firing schedule and the
              ``bsp+backup:k`` drop set (elastic/backup.py).
  resize:M@t  scheduler grant/revoke: the engine reshards N→M live, in
              process — no rollback.  Survivor workers keep their EF
              residuals and batch clocks; data streams are re-assigned
              through ``data/partition.stream_assignment``.  A
              post-reshard checkpoint is written immediately so a later
              crash never restores across a resize boundary.
  crash:wN@t  failure: the run rolls back to the latest committed
              checkpoint, reshards to the surviving K-1 workers (slot N
              dropped), and continues — work since the checkpoint is
              lost (counted in ``metrics["recoveries"]``), the process
              survives.
  restart@t   Gandiva-style suspend/resume: snapshot now, then restore —
              exercises the full save→load→import path with zero lost
              steps.

Engine state travels through ``repro.checkpoint``: arrays (params, EF
residuals, per-worker pulled copies, rng) in the sharded npz store,
bookkeeping (worker count, tick/update counters, staleness clocks) in the
manifest's ``extra`` blob.  Checkpoints are atomic (store.py), so a crash
mid-save leaves the previous checkpoint intact.
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint.store import (is_valid_checkpoint, load_checkpoint,
                                    read_manifest, save_checkpoint)
from repro.data.partition import stream_assignment
from repro.elastic.events import EventPlan, merge_plans

_CKPT_FMT = "step_{:06d}"


# ------------------------------------------------------- engine snapshots
def save_engine_state(path: str, engine, state, step: int,
                      history_len: int = 0) -> None:
    """Atomically snapshot an engine's full run-state at ``step``."""
    arrays, meta = engine.export_state(state)
    meta = dict(meta, step=int(step), history_len=int(history_len))
    save_checkpoint(path, arrays, step=int(step), extra=meta)


def restore_engine_state(path: str, engine, params_like
                         ) -> Tuple[Any, Dict[str, Any]]:
    """Load a snapshot back into ``engine`` (resharding it first if the
    snapshot was taken at a different worker count).  ``params_like``
    only provides the parameter pytree *structure* for decoding.
    Returns (state, meta)."""
    meta = read_manifest(path)["extra"]
    # one throwaway init provides the pytree structure; reshard it (not a
    # second init) when the snapshot was taken at a different size
    probe = engine.init(params_like)
    if meta["num_workers"] != _engine_workers(engine):
        probe = engine.reshard(probe, meta["num_workers"],
                               step=meta["step"])
    template, _ = engine.export_state(probe)
    arrays, _step = load_checkpoint(path, template)
    state = engine.import_state(arrays, meta)
    return state, meta


def _engine_workers(engine) -> int:
    inner = getattr(engine, "inner", engine)
    return inner.cfg.num_workers


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    """Newest committed (manifest-bearing) step_* checkpoint, or None."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        if name.startswith("step_") and is_valid_checkpoint(full):
            try:
                step = int(name.split("_", 1)[1])
            except ValueError:
                continue
            if best is None or step > best[0]:
                best = (step, full)
    return best[1] if best else None


# --------------------------------------------------------- elastic batches
class ElasticBatches:
    """Worker→stream indirection for resizable jobs.

    The user's ``batches(t, s)`` is keyed by a *logical stream* s in
    [0, n_streams); each worker slot covers an ordered list of streams
    through ``data/partition.stream_assignment`` (identity at nominal
    size, so an unresized run sees exactly the original batches) and
    rotates through its list by step — after a shrink the M workers keep
    covering all N streams instead of starving N−M of them.  The map is
    recomputed deterministically at every resize."""

    def __init__(self, batches: Callable[[int, int], Any], n_streams: int,
                 seed: int = 0):
        self.batches = batches
        self.n_streams = n_streams
        self.seed = seed
        self.assignment = stream_assignment(n_streams, n_streams, seed)

    def assign(self, num_workers: int) -> List[List[int]]:
        self.assignment = stream_assignment(self.n_streams, num_workers,
                                            self.seed)
        return self.assignment

    def __call__(self, t: int, worker: int):
        streams = self.assignment[worker]
        return self.batches(t, streams[t % len(streams)])


# ------------------------------------------------------------ the trainer
def fit_elastic(strategy, grad_fn: Callable, params,
                batches: Callable[[int, int], Any], steps: int, plan,
                checkpoint_dir: Optional[str] = None,
                checkpoint_every: int = 5,
                devices=None):
    """Drive ``strategy``'s engine for ``steps`` global steps under an
    elastic event plan.  Returns (params, history, metrics) like
    ``Trainer.fit``; metrics additionally carry ``recoveries`` (one
    record per crash/restart), ``resizes``, ``executed_steps`` (includes
    work redone after rollbacks), ``final_workers`` and
    ``dropped_updates``."""
    if isinstance(plan, str):
        plan = EventPlan.parse(plan)
    elif not isinstance(plan, EventPlan):
        plan = merge_plans(plan)
    if plan.needs_checkpoints and checkpoint_dir is None:
        raise ValueError("plan contains crash/restart events; "
                         "fit_elastic needs a checkpoint_dir to recover "
                         "from")
    if checkpoint_every < 1:
        raise ValueError("checkpoint_every must be >= 1")
    engine = strategy.build(grad_fn, devices)
    eb = ElasticBatches(batches, n_streams=strategy.workers,
                        seed=strategy.seed)
    run = plan.start()
    st = engine.init(params)
    ckpt = (lambda step: os.path.join(checkpoint_dir,
                                      _CKPT_FMT.format(step))) \
        if checkpoint_dir else None

    history: List[dict] = []
    recoveries: List[dict] = []
    resizes = 0
    executed = 0
    # recovery only ever restores checkpoints THIS run committed —
    # a reused checkpoint_dir with stale step_* dirs from an earlier
    # run must not leak foreign state into this one
    written: set = set()

    def commit(step: int, state, hist_len: int):
        save_engine_state(ckpt(step), engine, state, step, hist_len)
        written.add(step)

    if ckpt:
        commit(0, st, 0)

    t = 0
    while t < steps:
        rolled_back = False
        # one event at a time: a crash rollback leaves the rest of the
        # due batch pending, to fire when the run reaches them again
        while (ev := run.take_one(t)) is not None:
            if ev.kind == "slow":
                engine.set_slowdown(ev.worker, ev.factor)
                if ckpt:
                    # commit so a later crash rollback (which restores
                    # pre-event slowdowns and never re-fires consumed
                    # events) cannot erase the straggler
                    commit(t, st, len(history))
            elif ev.kind == "resize":
                st = engine.reshard(st, ev.workers, step=t)
                eb.assign(ev.workers)
                resizes += 1
                if ckpt:
                    # commit the post-reshard state so a later crash never
                    # restores across the resize boundary
                    commit(t, st, len(history))
            elif ev.kind in ("crash", "restart"):
                t0 = time.time()
                if ev.kind == "restart":
                    # scheduler suspend: snapshot the live state first
                    commit(t, st, len(history))
                if not written:
                    raise RuntimeError(
                        f"no checkpoint committed by this run in "
                        f"{checkpoint_dir!r} to recover from at step {t}")
                path = ckpt(max(written))
                if not is_valid_checkpoint(path):
                    raise RuntimeError(
                        f"checkpoint {path!r} is gone or torn; cannot "
                        f"recover at step {t}")
                st, meta = restore_engine_state(path, engine, params)
                rstep = int(meta["step"])
                history = history[:int(meta["history_len"])]
                # checkpoints from the abandoned timeline (steps beyond
                # the restore point) must not satisfy a later recovery
                written = {s for s in written if s <= rstep}
                if ev.kind == "crash":
                    survivors = _engine_workers(engine) - 1
                    st = engine.reshard(st, survivors, step=rstep,
                                        lost=(ev.worker,))
                    eb.assign(survivors)
                    commit(rstep, st, len(history))
                recoveries.append(dict(
                    kind=ev.kind, at=t, restored_step=rstep,
                    lost_steps=t - rstep,
                    lost_worker=ev.worker if ev.kind == "crash" else None,
                    workers=_engine_workers(engine),
                    wall_s=time.time() - t0))
                t = rstep
                rolled_back = True
                break
        if rolled_back:
            continue
        if ckpt and t > 0 and t % checkpoint_every == 0:
            commit(t, st, len(history))
        st, evs = engine.step(st, eb, t)
        history.extend(evs)
        executed += 1
        t += 1
        if executed > steps * 10 + 100:
            raise RuntimeError("elastic run not converging on its step "
                               "target (runaway rollback loop?)")

    mets = engine.metrics()
    mets.update(recoveries=recoveries, resizes=resizes,
                executed_steps=executed, wasted_steps=executed - steps,
                final_workers=_engine_workers(engine))
    return engine.finalize(st), history, mets
