"""Elastic, fault-tolerant training (survey §3.2.3 / §3.4.2).

Makes every registered Strategy cell survivable and resizable:

  events.py    declarative FailurePlan / ResizePlan / StragglerPlan event
               schedules + the sched/-trace adapter (scheduler↔trainer)
  recovery.py  fit_elastic: periodic engine snapshots through
               repro.checkpoint, crash rollback + reshard, live resize
  backup.py    bounded drop-slowest-k gradient aggregation (the survey's
               backup-worker straggler mitigation; ``bsp+backup:k``)
  detector.py  measured straggler detection: per-worker step-time EMAs
               feeding the backup drop set (``bsp+backup:k+detect``)

See docs/elasticity.md for the grammar, recovery semantics, and the
backup-worker accounting.
"""
from repro.elastic.backup import drop_set, participation_weights
from repro.elastic.detector import StepTimeEMA
from repro.elastic.events import (ElasticEvent, EventPlan, FailurePlan,
                                  ResizePlan, StragglerPlan, merge_plans,
                                  plan_from_sched_trace)
from repro.elastic.recovery import (ElasticBatches, fit_elastic,
                                    latest_checkpoint, restore_engine_state,
                                    save_engine_state)

__all__ = [
    "ElasticEvent", "EventPlan", "FailurePlan", "ResizePlan",
    "StragglerPlan", "merge_plans", "plan_from_sched_trace",
    "fit_elastic", "ElasticBatches", "save_engine_state",
    "restore_engine_state", "latest_checkpoint",
    "drop_set", "participation_weights", "StepTimeEMA",
]
