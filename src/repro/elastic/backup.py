"""Straggler mitigation via backup workers (survey §3.2.3 / §3.3.2).

The survey's backup-worker technique (Chen et al.: run N workers, apply
the first N-k gradients, discard the k stragglers') becomes a Strategy
knob: ``bsp+backup:k`` runs synchronous data parallelism but aggregates
only the fastest N-k workers each step.  The straggler's mini-batch is
discarded — its gradient never reaches the server, so its error-feedback
state must not be consumed either (both engines mask EF updates with the
participation weights below).

Which workers are "slowest" is deterministic, like everything else in the
repo: the engine's worker speed schedule (``periods``, optionally scaled
by elastic ``slow`` events — see elastic/events.py) ranks the workers,
and the k with the largest effective period are dropped, ties broken
toward the higher worker id.  The simulator and the device backend rank
with the same function, so their drop sets — and therefore losses and
wire accounting — agree by construction.
"""
from __future__ import annotations

from typing import FrozenSet, Optional, Sequence

import numpy as np


def drop_set(periods: Sequence[float], k: int,
             slowdowns: Optional[Sequence[float]] = None) -> FrozenSet[int]:
    """The k slowest workers under the effective speed schedule.

    ``periods[w]`` is worker w's base period (larger = slower); an active
    ``slowdowns[w]`` factor multiplies it.  Ties break toward the higher
    worker id so the drop set is a pure function of (periods, slowdowns,
    k) on every backend."""
    n = len(periods)
    if k <= 0:
        return frozenset()
    if k >= n:
        raise ValueError(f"backup k={k} must leave at least one of "
                         f"{n} workers")
    eff = [p * (slowdowns[w] if slowdowns is not None else 1.0)
           for w, p in enumerate(periods)]
    order = sorted(range(n), key=lambda w: (eff[w], w))
    return frozenset(order[n - k:])


def participation_weights(num_workers: int, drop: FrozenSet[int]
                          ) -> np.ndarray:
    """Per-worker aggregation weights for a drop-slowest-k step: a mean
    over ``num_workers`` of the weighted gradients equals the plain mean
    over the participants (dropped workers contribute exact zeros)."""
    n_part = num_workers - len(drop)
    w = np.full((num_workers,), num_workers / max(1, n_part), np.float32)
    if drop:
        w[sorted(drop)] = 0.0
    return w
