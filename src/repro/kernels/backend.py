"""Kernel backend seam: one resolution rule for every Pallas-vs-jnp choice.

Every hot spot with a Pallas kernel (the ``repro.kernels`` codec family,
flash attention) accepts a ``backend`` knob with three values:

  kernel   the Pallas implementation.  On TPU hardware it compiles to a
           fused Mosaic kernel; on CPU hosts it executes in interpret
           mode (``pl.pallas_call(interpret=True)``) — numerically the
           same program, used by the parity tests and smoke gates.
  ref      the pure-jnp oracle (the pre-seam production math).  XLA
           fuses the elementwise work, but nothing is hand-tiled.
  auto     ``kernel`` when the process has TPU devices, else ``ref``.
           Interpret-mode Pallas trades away the fusion win it exists
           for, so CPU hosts auto-fall back to the oracle and TPU hosts
           get the fused path — "as fast as the hardware allows" on both.

``REPRO_KERNEL_BACKEND=kernel|ref`` overrides ``auto`` for a whole
process (CI smoke gates and benchmarks use it to force the kernel path
on CPU).  Explicit ``backend="kernel"``/``"ref"`` always wins over the
environment.

The knob is threaded once per layer: ``Compressor.backend`` (modeled
per-worker roundtrip), ``SegmentCodec`` via ``codec_for`` (measured
payloads inside the collective schedules), ``Strategy.kernel_backend``
(spec-level selection for both), and ``ModelConfig.attn_backend`` /
the ``backend=`` kwarg of ``models.attention`` (flash attention).
See docs/kernels.md for the full matrix.
"""
from __future__ import annotations

import functools
import os

KERNEL_BACKENDS = ("auto", "kernel", "ref")


@functools.lru_cache(maxsize=None)
def _has_tpu() -> bool:
    import jax
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - uninitialized backend
        return False


def resolve_backend(backend: str = "auto") -> str:
    """Resolve a backend knob to ``"kernel"`` or ``"ref"`` (module
    docstring).  Raises on unknown values so typos fail loudly at plan /
    construction time rather than silently running the wrong math."""
    if backend not in KERNEL_BACKENDS:
        raise ValueError(
            f"backend={backend!r} (want one of {KERNEL_BACKENDS})")
    if backend != "auto":
        return backend
    env = os.environ.get("REPRO_KERNEL_BACKEND", "")
    if env:
        if env not in ("kernel", "ref"):
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r} (want kernel|ref)")
        return env
    return "kernel" if _has_tpu() else "ref"


def kernel_interpret() -> bool:
    """True when Pallas kernels must run in interpret mode (no TPU in the
    process).  Every ``interpret=`` default in ``repro.kernels`` call
    sites routes through this single rule."""
    return not _has_tpu()
