"""Pallas TPU kernel: 1-bit quantize + error feedback (Seide et al. [159]).

This runs on every gradient byte every step, which makes it the bandwidth
hot-spot the survey's §3.3.3 is about.  Gradients are reshaped to [R, C]
rows; each grid step processes a (block_r, C) VMEM tile and emits the sign
plane, the per-row scale, and the updated error-feedback residual in one
fused pass (one HBM read of g/e, one write of each output — arithmetic
intensity is too low for anything but a fused elementwise kernel, so the
win over unfused jnp is purely avoided HBM traffic).

TPU has no 1-bit dtype; signs leave the kernel as int8 and are bit-packed
into int32 words (32x) by ``ops.pack_bits`` for the wire-format byte count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, e_ref, s_ref, scale_ref, ne_ref):
    c = g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    signs = jnp.where(c >= 0, jnp.int8(1), jnp.int8(-1))
    scale = jnp.mean(jnp.abs(c), axis=-1, keepdims=True)
    s_ref[...] = signs
    scale_ref[...] = scale
    ne_ref[...] = c - signs.astype(jnp.float32) * scale


def onebit_compress(g, e, *, block_r: int = 256, interpret: bool = True):
    """g, e [R, C] -> (signs int8 [R, C], scale f32 [R, 1], new_e f32 [R, C])."""
    R, C = g.shape
    br = min(block_r, R)
    r_pad = (R + br - 1) // br * br
    gp = jnp.pad(g, ((0, r_pad - R), (0, 0)))
    ep = jnp.pad(e, ((0, r_pad - R), (0, 0)))
    grid = (r_pad // br,)
    signs, scale, new_e = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0)),
                   pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r_pad, C), jnp.int8),
                   jax.ShapeDtypeStruct((r_pad, 1), jnp.float32),
                   jax.ShapeDtypeStruct((r_pad, C), jnp.float32)],
        interpret=interpret,
    )(gp, ep)
    return signs[:R], scale[:R], new_e[:R]
