"""Jit'd wrappers + wire-format bit packing for the 1-bit kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import kernel_interpret, resolve_backend
from repro.kernels.onebit.fused import onebit_encode_ef
from repro.kernels.onebit.onebit import onebit_compress
from repro.kernels.onebit.ref import (onebit_decompress_ref,
                                      onebit_encode_ef_ref, onebit_ref)


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def compress(g, e, *, block_r: int = 256, interpret: bool = True):
    return onebit_compress(g, e, block_r=block_r, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("gain", "symmetric", "block_r",
                                             "backend"))
def encode_ef(g, e=None, valid=None, *, gain: float = 1.0,
              symmetric: bool = False, block_r: int = 256,
              backend: str = "auto"):
    """Fused 1-bit encode + EF residual (``fused.onebit_encode_ef``),
    dispatched through the kernel backend seam: ``kernel`` runs the
    single-pass Pallas kernel (interpret mode off-TPU), ``ref`` the
    expression-identical jnp oracle."""
    if resolve_backend(backend) == "kernel":
        return onebit_encode_ef(g, e, valid, gain=gain, symmetric=symmetric,
                                block_r=block_r,
                                interpret=kernel_interpret())
    return onebit_encode_ef_ref(g, e, valid, gain=gain, symmetric=symmetric)


@jax.jit
def decompress(signs, scale):
    return onebit_decompress_ref(signs, scale)


@jax.jit
def pack_bits(signs):
    """int8 signs {-1,+1} [R, C] (C % 32 == 0) -> int32 words [R, C//32].

    This is the on-the-wire format: 1 bit per gradient element."""
    R, C = signs.shape
    bits = (signs > 0).astype(jnp.uint32).reshape(R, C // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("C",))
def unpack_bits(words, C: int | None = None):
    R, W = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    signs = jnp.where(bits == 1, jnp.int8(1), jnp.int8(-1)).reshape(R, W * 32)
    return signs if C is None else signs[:, :C]


def wire_bytes(numel: int) -> int:
    """Bytes on the wire per tensor: 1 bit per element + 4B scale per row
    (accounted at 256-wide rows)."""
    return numel // 8 + 4 * max(1, numel // 256)


__all__ = ["compress", "decompress", "encode_ef", "pack_bits", "unpack_bits",
           "onebit_ref", "onebit_encode_ef_ref", "wire_bytes"]
