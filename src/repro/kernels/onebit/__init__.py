from repro.kernels.onebit.ops import (compress, decompress, onebit_ref,
                                      pack_bits, unpack_bits, wire_bytes)

__all__ = ["compress", "decompress", "onebit_ref", "pack_bits",
           "unpack_bits", "wire_bytes"]
