from repro.kernels.onebit.ops import (compress, decompress, encode_ef,
                                      onebit_encode_ef_ref, onebit_ref,
                                      pack_bits, unpack_bits, wire_bytes)

__all__ = ["compress", "decompress", "encode_ef", "onebit_ref",
           "onebit_encode_ef_ref", "pack_bits", "unpack_bits", "wire_bytes"]
