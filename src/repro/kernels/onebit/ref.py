"""Oracle for 1-bit SGD quantization with error feedback (Seide et al. [159]).

compensated c = g + e;  transmit sign(c) with a per-row |c| mean as scale;
residual e' = c - decompressed keeps the full information (error feedback).
"""
from __future__ import annotations

import jax.numpy as jnp


def onebit_ref(g, e):
    """g, e [R, C] float -> (signs int8 in {-1,+1}, scale [R,1] f32, e')."""
    c = g.astype(jnp.float32) + e.astype(jnp.float32)
    signs = jnp.where(c >= 0, jnp.int8(1), jnp.int8(-1))
    scale = jnp.mean(jnp.abs(c), axis=-1, keepdims=True)
    decompressed = signs.astype(jnp.float32) * scale
    new_e = c - decompressed
    return signs, scale, new_e


def onebit_decompress_ref(signs, scale):
    return signs.astype(jnp.float32) * scale


def onebit_encode_ef_ref(g, e=None, valid=None, *, gain: float = 1.0,
                         symmetric: bool = False):
    """Oracle for the fused encode+EF kernel (``fused.onebit_encode_ef``):
    same signature, same five outputs, expression-identical math."""
    g = g.astype(jnp.float32)
    if e is not None:
        e = e.astype(jnp.float32)
        cin = g + gain * e
        ctrue = g + e
    else:
        cin = ctrue = g
    signs = jnp.where(cin >= 0, jnp.int8(1), jnp.int8(-1))
    if valid is not None:
        valid = valid != 0
    if symmetric:
        sp = sn = jnp.mean(jnp.abs(cin), axis=-1, keepdims=True)
    else:
        pos = signs > 0
        neg = ~pos
        if valid is not None:
            pos = pos & valid
            neg = neg & valid
        npos = jnp.maximum(jnp.sum(pos, axis=-1, keepdims=True), 1)
        nneg = jnp.maximum(jnp.sum(neg, axis=-1, keepdims=True), 1)
        sp = jnp.sum(jnp.where(pos, cin, 0.0), axis=-1, keepdims=True) / npos
        sn = jnp.sum(jnp.where(neg, -cin, 0.0), axis=-1, keepdims=True) / nneg
    recon = jnp.where(signs > 0, sp, -sn)
    out = recon if valid is None else jnp.where(valid, recon, 0.0)
    return signs, sp, sn, out, ctrue - out
