"""Oracle for 1-bit SGD quantization with error feedback (Seide et al. [159]).

compensated c = g + e;  transmit sign(c) with a per-row |c| mean as scale;
residual e' = c - decompressed keeps the full information (error feedback).
"""
from __future__ import annotations

import jax.numpy as jnp


def onebit_ref(g, e):
    """g, e [R, C] float -> (signs int8 in {-1,+1}, scale [R,1] f32, e')."""
    c = g.astype(jnp.float32) + e.astype(jnp.float32)
    signs = jnp.where(c >= 0, jnp.int8(1), jnp.int8(-1))
    scale = jnp.mean(jnp.abs(c), axis=-1, keepdims=True)
    decompressed = signs.astype(jnp.float32) * scale
    new_e = c - decompressed
    return signs, scale, new_e


def onebit_decompress_ref(signs, scale):
    return signs.astype(jnp.float32) * scale
