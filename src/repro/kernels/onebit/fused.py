"""Pallas TPU kernel: fused 1-bit encode + error-feedback residual.

The seed ``onebit.py`` kernel emits the symmetric ``sign * mean|c|``
plane; production (``comm/codecs.py`` and ``core/compression.py``) since
grew the Seide two-bin reconstruction, per-row valid masks for lane
padding / dgc's already-sent slots, and the ``ef_gain`` over-relaxation —
all as separate jnp passes, so one encode touches each gradient byte
four-plus times.  This kernel is the fusion of the whole sequence: one
grid step reads a ``(block_r, C)`` tile of ``g`` (and optionally ``e``
and a valid mask) from HBM once and writes every output of the
encode+EF contract:

    c_in   = g + gain * e        (what the quantizer sees)
    c_true = g + e               (what the residual is measured against)
    signs  = sign(c_in)                       -> the 1-bit wire plane
    sp,sn  = per-row bin means of c_in        -> 8 B/row side info
             (or both = mean|c_in| when symmetric=True, the seed format)
    out    = valid ? decode(signs, sp, sn) : 0
    new_e  = c_true - out                     -> next step's EF residual

Arithmetic intensity is far below the TPU ridge, so the win is purely
the avoided HBM round-trips of the unfused jnp passes; the math is kept
expression-identical to the oracles so backend parity is bitwise, not
just allclose (asserted by tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(*refs, gain: float, has_e: bool, has_valid: bool,
            symmetric: bool):
    it = iter(refs)
    g_ref = next(it)
    e_ref = next(it) if has_e else None
    v_ref = next(it) if has_valid else None
    s_ref, sp_ref, sn_ref, o_ref, ne_ref = it

    g = g_ref[...].astype(jnp.float32)
    if has_e:
        e = e_ref[...].astype(jnp.float32)
        cin = g + gain * e
        ctrue = g + e
    else:
        cin = ctrue = g
    signs = jnp.where(cin >= 0, jnp.int8(1), jnp.int8(-1))
    valid = (v_ref[...] != 0) if has_valid else None

    if symmetric:
        sp = sn = jnp.mean(jnp.abs(cin), axis=-1, keepdims=True)
    else:
        pos = signs > 0
        neg = ~pos
        if valid is not None:
            pos = pos & valid
            neg = neg & valid
        npos = jnp.maximum(jnp.sum(pos, axis=-1, keepdims=True), 1)
        nneg = jnp.maximum(jnp.sum(neg, axis=-1, keepdims=True), 1)
        sp = jnp.sum(jnp.where(pos, cin, 0.0), axis=-1, keepdims=True) / npos
        sn = jnp.sum(jnp.where(neg, -cin, 0.0), axis=-1, keepdims=True) / nneg

    recon = jnp.where(signs > 0, sp, -sn)
    out = recon if valid is None else jnp.where(valid, recon, 0.0)
    s_ref[...] = signs
    sp_ref[...] = sp
    sn_ref[...] = sn
    o_ref[...] = out
    ne_ref[...] = ctrue - out


def onebit_encode_ef(g, e=None, valid=None, *, gain: float = 1.0,
                     symmetric: bool = False, block_r: int = 256,
                     interpret: bool = True):
    """g [R, C]; e, valid optional [R, C] (valid: nonzero = real element).

    Returns ``(signs int8 [R,C], sp f32 [R,1], sn f32 [R,1],
    out f32 [R,C], new_e f32 [R,C])`` per the module contract.  ``e=None``
    means no error feedback (``c_in = c_true = g``, the segment-codec
    case); ``valid=None`` means every element is real."""
    R, C = g.shape
    br = min(block_r, R)
    r_pad = (R + br - 1) // br * br

    def rpad(x, fill=0):
        return jnp.pad(x, ((0, r_pad - R), (0, 0)), constant_values=fill)

    operands = [rpad(g.astype(jnp.float32))]
    if e is not None:
        operands.append(rpad(e.astype(jnp.float32)))
    if valid is not None:
        operands.append(rpad(valid.astype(jnp.int8)))
    row_spec = pl.BlockSpec((br, C), lambda i: (i, 0))
    col_spec = pl.BlockSpec((br, 1), lambda i: (i, 0))

    signs, sp, sn, out, new_e = pl.pallas_call(
        functools.partial(_kernel, gain=gain, has_e=e is not None,
                          has_valid=valid is not None, symmetric=symmetric),
        grid=(r_pad // br,),
        in_specs=[row_spec] * len(operands),
        out_specs=[row_spec, col_spec, col_spec, row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((r_pad, C), jnp.int8),
                   jax.ShapeDtypeStruct((r_pad, 1), jnp.float32),
                   jax.ShapeDtypeStruct((r_pad, 1), jnp.float32),
                   jax.ShapeDtypeStruct((r_pad, C), jnp.float32),
                   jax.ShapeDtypeStruct((r_pad, C), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return signs[:R], sp[:R], sn[:R], out[:R], new_e[:R]
