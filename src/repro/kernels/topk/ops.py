"""Jit'd wrappers for DGC sparsification."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import kernel_interpret, resolve_backend
from repro.kernels.topk.ref import threshold_for_density, topk_ref
from repro.kernels.topk.topk import topk_compress


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def compress(g, e, threshold, *, block_r: int = 256, interpret: bool = True):
    return topk_compress(g, e, threshold, block_r=block_r,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_r", "backend"))
def sparsify(g, e, threshold, *, block_r: int = 256, backend: str = "auto"):
    """Fused threshold-sparsify + error accumulation, dispatched through
    the kernel backend seam.  Returns (kept f32 [R, C], new_e f32)."""
    if resolve_backend(backend) == "kernel":
        return topk_compress(g, e, threshold, block_r=block_r,
                             interpret=kernel_interpret())
    return topk_ref(g, e, threshold)


def wire_bytes(numel: int, density: float) -> int:
    """(4B index + 4B value) per surviving element."""
    return int(numel * density) * 8


__all__ = ["compress", "sparsify", "topk_ref", "threshold_for_density",
           "wire_bytes"]
