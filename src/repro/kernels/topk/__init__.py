from repro.kernels.topk.ops import (compress, sparsify, threshold_for_density,
                                    topk_ref, wire_bytes)

__all__ = ["compress", "sparsify", "threshold_for_density", "topk_ref",
           "wire_bytes"]
