from repro.kernels.topk.ops import (compress, threshold_for_density, topk_ref,
                                    wire_bytes)

__all__ = ["compress", "threshold_for_density", "topk_ref", "wire_bytes"]
