"""Pallas TPU kernel: DGC threshold-sparsification with error accumulation.

Top-k selection does not vectorize on the VPU; like DGC's GPU kernel we use
a threshold (from a cheap quantile estimate done once outside) and a fused
elementwise pass that emits the surviving values and banks the rest into
the error-feedback residual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, e_ref, t_ref, o_ref, ne_ref):
    c = g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    t = t_ref[0, 0]
    mask = jnp.abs(c) >= t
    out = jnp.where(mask, c, 0.0)
    o_ref[...] = out
    ne_ref[...] = c - out


def topk_compress(g, e, threshold, *, block_r: int = 256,
                  interpret: bool = True):
    """g, e [R, C]; threshold scalar -> (sparse f32 [R, C], new_e f32)."""
    R, C = g.shape
    br = min(block_r, R)
    r_pad = (R + br - 1) // br * br
    gp = jnp.pad(g.astype(jnp.float32), ((0, r_pad - R), (0, 0)))
    ep = jnp.pad(e.astype(jnp.float32), ((0, r_pad - R), (0, 0)))
    t = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    out, new_e = pl.pallas_call(
        _kernel,
        grid=(r_pad // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                   pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r_pad, C), jnp.float32),
                   jax.ShapeDtypeStruct((r_pad, C), jnp.float32)],
        interpret=interpret,
    )(gp, ep, t)
    return out[:R], new_e[:R]
