"""Oracle for Deep Gradient Compression-style sparsification (Lin et al.
[106]): threshold sparsify + error accumulation of the untransmitted rest."""
from __future__ import annotations

import jax.numpy as jnp


def topk_ref(g, e, threshold):
    """g, e [R, C]; threshold scalar.

    Returns (sparse values f32 [R, C] with zeros below threshold, new error).
    Wire format = (indices, values) of nonzeros; density measured separately.
    """
    c = g.astype(jnp.float32) + e.astype(jnp.float32)
    mask = jnp.abs(c) >= threshold
    out = jnp.where(mask, c, 0.0)
    new_e = c - out
    return out, new_e


def threshold_for_density(g, e, density: float):
    """Quantile threshold that keeps ~density of the compensated gradient."""
    c = jnp.abs(g.astype(jnp.float32) + e.astype(jnp.float32)).reshape(-1)
    return jnp.quantile(c, 1.0 - density)
