from repro.kernels.terngrad.ops import (compress, decompress, ternarize,
                                        terngrad_ref, wire_bytes)

__all__ = ["compress", "decompress", "ternarize", "terngrad_ref",
           "wire_bytes"]
