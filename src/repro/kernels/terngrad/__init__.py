from repro.kernels.terngrad.ops import (compress, decompress, terngrad_ref,
                                        wire_bytes)

__all__ = ["compress", "decompress", "terngrad_ref", "wire_bytes"]
