"""Pallas TPU kernel: TernGrad stochastic ternarization (Wen et al. [190]).

The per-tensor statistics (std for clipping, max|g| for the scale) are
reductions computed once outside and passed in as (1,1) SMEM-style operands;
the kernel then does the bandwidth-bound elementwise ternarize in VMEM
tiles.  Uniform random bits are an explicit input so the pure-jnp oracle is
bit-identical (and interpret mode needs no TPU PRNG).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, u_ref, stats_ref, t_ref):
    g = g_ref[...].astype(jnp.float32)
    sigma_clip = stats_ref[0, 0]
    s = stats_ref[0, 1]
    g = jnp.where(sigma_clip > 0,
                  jnp.clip(g, -sigma_clip, sigma_clip), g)
    p = jnp.abs(g) / jnp.maximum(s, 1e-30)
    b = (u_ref[...] < p).astype(jnp.int8)
    t_ref[...] = jnp.sign(g).astype(jnp.int8) * b


def terngrad_ternarize(gc, u, s, *, block_r: int = 256,
                       interpret: bool = True):
    """Ternarize pre-clipped rows against a precomputed scale ``s``.

    The segment codec computes its statistics on the *unpadded* payload
    before row-padding, so the kernel cannot re-derive them from the rows
    it sees; passing ``stats = [0, s]`` skips the in-kernel clip branch
    and reuses the same fused elementwise pass.  gc, u [R, C] -> int8."""
    stats = jnp.stack([jnp.float32(0.0), jnp.asarray(s, jnp.float32)]
                      ).reshape(1, 2)
    R, C = gc.shape
    br = min(block_r, R)
    r_pad = (R + br - 1) // br * br
    gp = jnp.pad(gc.astype(jnp.float32), ((0, r_pad - R), (0, 0)))
    up = jnp.pad(u, ((0, r_pad - R), (0, 0)), constant_values=1.0)
    tern = pl.pallas_call(
        _kernel,
        grid=(r_pad // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, C), jnp.int8),
        interpret=interpret,
    )(gp, up, stats)
    return tern[:R]


def terngrad_compress(g, u, *, clip_sigma: float = 2.5, block_r: int = 256,
                      interpret: bool = True):
    """g, u [R, C] -> (tern int8 [R, C], scale scalar f32)."""
    g32 = g.astype(jnp.float32)
    sigma = jnp.std(g32) * clip_sigma if clip_sigma else jnp.float32(0.0)
    gc = jnp.where(sigma > 0, jnp.clip(g32, -sigma, sigma), g32)
    s = jnp.max(jnp.abs(gc))
    stats = jnp.stack([sigma, s]).reshape(1, 2)

    R, C = g.shape
    br = min(block_r, R)
    r_pad = (R + br - 1) // br * br
    gp = jnp.pad(g32, ((0, r_pad - R), (0, 0)))
    up = jnp.pad(u, ((0, r_pad - R), (0, 0)), constant_values=1.0)
    tern = pl.pallas_call(
        _kernel,
        grid=(r_pad // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, C), jnp.int8),
        interpret=interpret,
    )(gp, up, stats)
    return tern[:R], s
