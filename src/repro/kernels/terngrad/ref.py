"""Oracle for TernGrad (Wen et al. [190]): stochastic ternary gradients.

g -> s * sign(g) * b,  b ~ Bernoulli(|g| / s),  s = max|g| (per tensor,
after optional clipping).  The random draw is an input so kernel and oracle
share it exactly.
"""
from __future__ import annotations

import jax.numpy as jnp


def terngrad_ref(g, u, clip_sigma: float = 2.5):
    """g [R, C]; u [R, C] uniform(0,1) -> (tern int8 {-1,0,1}, scale scalar)."""
    g32 = g.astype(jnp.float32)
    if clip_sigma:
        sigma = jnp.std(g32)
        g32 = jnp.clip(g32, -clip_sigma * sigma, clip_sigma * sigma)
    s = jnp.max(jnp.abs(g32))
    p = jnp.abs(g32) / jnp.maximum(s, 1e-30)
    b = (u < p).astype(jnp.int8)
    tern = jnp.sign(g32).astype(jnp.int8) * b
    return tern, s


def ternarize_ref(gc, u, s):
    """Oracle for ``terngrad_ternarize``: pre-clipped rows, external scale
    (the segment-codec math in ``comm/codecs.py``)."""
    gc = gc.astype(jnp.float32)
    p = jnp.abs(gc) / jnp.maximum(s, 1e-30)
    b = (u < p).astype(jnp.int8)
    return jnp.sign(gc).astype(jnp.int8) * b


def terngrad_decompress_ref(tern, s):
    return tern.astype(jnp.float32) * s
