"""Jit'd wrappers for TernGrad."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import kernel_interpret, resolve_backend
from repro.kernels.terngrad.ref import (ternarize_ref,
                                        terngrad_decompress_ref,
                                        terngrad_ref)
from repro.kernels.terngrad.terngrad import (terngrad_compress,
                                             terngrad_ternarize)


@functools.partial(jax.jit, static_argnames=("clip_sigma", "interpret",
                                             "block_r"))
def compress(g, u, *, clip_sigma: float = 2.5, block_r: int = 256,
             interpret: bool = True):
    return terngrad_compress(g, u, clip_sigma=clip_sigma, block_r=block_r,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_r", "backend"))
def ternarize(gc, u, s, *, block_r: int = 256, backend: str = "auto"):
    """Stochastic ternarize of pre-clipped rows with an external scale,
    dispatched through the kernel backend seam (the segment-codec entry:
    statistics come from the unpadded payload)."""
    if resolve_backend(backend) == "kernel":
        return terngrad_ternarize(gc, u, s, block_r=block_r,
                                  interpret=kernel_interpret())
    return ternarize_ref(gc, u, s)


@jax.jit
def decompress(tern, s):
    return terngrad_decompress_ref(tern, s)


def wire_bytes(numel: int) -> int:
    """2 bits per element (ternary packs 16/int32 word) + 4B scale."""
    return numel // 4 + 4


__all__ = ["compress", "decompress", "ternarize", "terngrad_ref",
           "wire_bytes"]
