"""Jit'd wrappers for TernGrad."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.terngrad.ref import terngrad_decompress_ref, terngrad_ref
from repro.kernels.terngrad.terngrad import terngrad_compress


@functools.partial(jax.jit, static_argnames=("clip_sigma", "interpret",
                                             "block_r"))
def compress(g, u, *, clip_sigma: float = 2.5, block_r: int = 256,
             interpret: bool = True):
    return terngrad_compress(g, u, clip_sigma=clip_sigma, block_r=block_r,
                             interpret=interpret)


@jax.jit
def decompress(tern, s):
    return terngrad_decompress_ref(tern, s)


def wire_bytes(numel: int) -> int:
    """2 bits per element (ternary packs 16/int32 word) + 4B scale."""
    return numel // 4 + 4


__all__ = ["compress", "decompress", "terngrad_ref", "wire_bytes"]
