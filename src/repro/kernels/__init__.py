"""Pallas TPU kernels for the survey's perf-critical hot spots:

- flash_attention: fused block attention (the models substrate's compute)
- onebit / terngrad / qsgd / topk: the §3.3.3 gradient-compression family

Each package ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), and ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
