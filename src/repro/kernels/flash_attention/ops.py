"""Jit'd public wrappers for the flash-attention kernels.

``attention`` / ``decode`` run the Pallas kernels directly.
``attention_grad`` is the trainable entry the model layer routes through:
its forward is the flash kernel and its VJP replays the pure-jnp oracle
(Pallas kernels do not differentiate), so gradients match the reference
math the models were validated against.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import (flash_attention,
                                                           flash_decode)
from repro.kernels.flash_attention.ref import attention_ref, decode_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128, interpret: bool = True):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode(q, ck, cv, pos, *, window: int = 0, block_k: int = 128,
           interpret: bool = True):
    return flash_decode(q, ck, cv, pos, window=window, block_k=block_k,
                        interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attention_grad(q, k, v, causal, window, interpret):
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=interpret)


def _attention_grad_fwd(q, k, v, causal, window, interpret):
    return _attention_grad(q, k, v, causal, window, interpret), (q, k, v)


def _attention_grad_bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda qq, kk, vv: attention_ref(qq, kk, vv, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


_attention_grad.defvjp(_attention_grad_fwd, _attention_grad_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def attention_grad(q, k, v, *, causal: bool = True, window: int = 0,
                   interpret: bool = True):
    """Flash forward with a reference-math VJP (safe under value_and_grad)."""
    return _attention_grad(q, k, v, causal, window, interpret)


__all__ = ["attention", "attention_grad", "attention_ref", "decode",
           "decode_ref", "flash_attention", "flash_decode"]
