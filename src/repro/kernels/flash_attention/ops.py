"""Jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128, interpret: bool = True):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


__all__ = ["attention", "attention_ref", "flash_attention"]
