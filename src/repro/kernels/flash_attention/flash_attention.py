"""Block-tiled online-softmax (flash) attention Pallas TPU kernel.

Grid (B, H, nq, nk): the innermost nk axis streams K/V blocks through VMEM
while float32 VMEM scratch accumulators (running max m, normalizer l, output
acc) persist across nk steps — the canonical TPU flash schedule.  GQA is
free: the K/V BlockSpec index_map folds the query head onto its KV head, so
no repeated K/V ever materializes in VMEM.  Block shapes default to the
MXU-aligned (128, 128); head_dim is the minor (lane) dimension.

Causal / sliding-window masking is applied per-block from global positions.
``interpret=True`` executes the kernel body on CPU (this container); on TPU
hardware pass interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < seq_len                                # key padding
    if causal:
        mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # [bq, bk]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q [B, S, H, hd]; k, v [B, S, KV, hd] (KV divides H) -> [B, S, H, hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    scale = 1.0 / (hd ** 0.5)

    # [B, H, S, hd] layout, pad S to block multiples
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, S))
    sq_pad = (S + bq - 1) // bq * bq
    sk_pad = (S + bk - 1) // bk * bk
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad - S), (0, 0)))
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, sk_pad - S), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, sk_pad - S), (0, 0)))
    nq, nk = sq_pad // bq, sk_pad // bk

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk, seq_len=S),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, _g=group: (b, h // _g, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, _g=group: (b, h // _g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, sq_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :S, :], 1, 2)


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, window: int, bk: int, nk: int,
                   kv_len: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [1, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [1, bk]

    pos = pos_ref[0, 0]                                  # traced scalar
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = kpos < kv_len                                 # cache padding
    if window:
        # ring buffer: slot j holds global position p_j with p_j % W == j
        # and p_j <= pos; valid iff that position has been written (>= 0).
        age = (pos - kpos) % window
        mask &= (pos - age) >= 0
    else:
        mask &= kpos <= pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # [1, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # [1, bk]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode(q, ck, cv, pos, *, window: int = 0, block_k: int = 128,
                 interpret: bool = True):
    """One-token grouped-query decode against the stored cache layout.

    q [B, 1, H, hd]; ck, cv [B, L, KV, hd] (KV divides H); pos scalar int32
    (traced — same decode step for the whole batch) -> [B, 1, H, hd].

    The grid streams K/V cache blocks through VMEM with the same online-
    softmax scratch as the training kernel, but the query block is a single
    row and the K/V BlockSpec folds query heads onto their KV head, so the
    cache is never repeated H/KV-fold (the repeat-free property of
    ``models.attention._gqa_decode_sdpa``).  ``window > 0`` masks the ring
    buffer by slot age exactly like the jnp decode path.
    """
    B, _, H, hd = q.shape
    L, KV = ck.shape[1], ck.shape[2]
    group = H // KV
    scale = 1.0 / (hd ** 0.5)

    qt = jnp.moveaxis(q, 2, 1)                           # [B, H, 1, hd]
    kt = jnp.moveaxis(ck, 2, 1)                          # [B, KV, L, hd]
    vt = jnp.moveaxis(cv, 2, 1)
    bk = min(block_k, max(8, L))
    lp = (L + bk - 1) // bk * bk
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, lp - L), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, lp - L), (0, 0)))
    nk = lp // bk
    posb = jnp.asarray(pos, jnp.int32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          bk=bk, nk=nk, kv_len=L),
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, ik, _g=group: (b, h // _g, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, ik, _g=group: (b, h // _g, ik, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ik: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, posb)
    return jnp.moveaxis(out, 1, 2)
