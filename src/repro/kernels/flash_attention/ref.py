"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B, S, H, hd]; k, v [B, S, KV, hd] (KV divides H).  fp32 math."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    if causal:
        mask = kj <= qi
        if window:
            mask &= kj > qi - window
    else:
        mask = jnp.ones((S, S), dtype=bool)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_ref(q, ck, cv, pos, *, window: int = 0):
    """One-token decode oracle, repeat-free grouped einsum over the cache.

    q [B, 1, H, hd]; ck, cv [B, L, KV, hd]; pos scalar int32 (traced).
    Mirrors ``models.attention._gqa_decode_sdpa`` masking: ``window > 0``
    treats the cache as a ring buffer and masks slots by age."""
    B, _, H, hd = q.shape
    L, KV = ck.shape[1], ck.shape[2]
    G = H // KV
    idx = jnp.arange(L)
    if window:
        age = (pos - idx) % window
        mask1d = (pos - age) >= 0
    else:
        mask1d = idx <= pos
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgd,blkd->bkgql", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    s = jnp.where(mask1d[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgql,blkd->bqkgd", p, cv.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)
