from repro.kernels.flash_attention.ops import (attention, attention_ref,
                                               flash_attention)

__all__ = ["attention", "attention_ref", "flash_attention"]
