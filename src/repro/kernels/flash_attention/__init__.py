from repro.kernels.flash_attention.ops import (attention, attention_grad,
                                               attention_ref, decode,
                                               decode_ref, flash_attention,
                                               flash_decode)

__all__ = ["attention", "attention_grad", "attention_ref", "decode",
           "decode_ref", "flash_attention", "flash_decode"]
