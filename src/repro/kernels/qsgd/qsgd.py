"""Pallas TPU kernel: QSGD s-level stochastic quantization (Alistarh [8])."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, u_ref, norm_ref, q_ref, *, s_levels: int):
    g = g_ref[...].astype(jnp.float32)
    norm = norm_ref[0, 0]
    p = jnp.abs(g) / jnp.maximum(norm, 1e-30) * s_levels
    lo = jnp.floor(p)
    lvl = lo + (u_ref[...] < (p - lo)).astype(jnp.float32)
    lvl = jnp.clip(lvl, 0, s_levels)
    q_ref[...] = (jnp.sign(g) * lvl).astype(jnp.int8)


def qsgd_compress(g, u, *, s_levels: int = 127, block_r: int = 256,
                  interpret: bool = True):
    """g, u [R, C] -> (levels int8 [R, C], norm scalar f32)."""
    g32 = g.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(jnp.square(g32)))
    R, C = g.shape
    br = min(block_r, R)
    r_pad = (R + br - 1) // br * br
    gp = jnp.pad(g32, ((0, r_pad - R), (0, 0)))
    up = jnp.pad(u, ((0, r_pad - R), (0, 0)), constant_values=1.0)
    q = pl.pallas_call(
        functools.partial(_kernel, s_levels=s_levels),
        grid=(r_pad // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((br, C), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, C), jnp.int8),
        interpret=interpret,
    )(gp, up, norm.reshape(1, 1))
    return q[:R], norm
