"""Jit'd wrappers for QSGD."""
from __future__ import annotations

import functools

import jax

from repro.kernels.qsgd.qsgd import qsgd_compress
from repro.kernels.qsgd.ref import qsgd_decompress_ref, qsgd_ref


@functools.partial(jax.jit, static_argnames=("s_levels", "block_r",
                                             "interpret"))
def compress(g, u, *, s_levels: int = 127, block_r: int = 256,
             interpret: bool = True):
    return qsgd_compress(g, u, s_levels=s_levels, block_r=block_r,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("s_levels",))
def decompress(q, norm, *, s_levels: int = 127):
    return qsgd_decompress_ref(q, norm, s_levels)


def wire_bytes(numel: int, s_levels: int = 127) -> int:
    """8-bit levels (s=127) + 4B norm; Elias coding would shrink further."""
    return numel + 4


__all__ = ["compress", "decompress", "qsgd_ref", "wire_bytes"]
