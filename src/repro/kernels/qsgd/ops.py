"""Jit'd wrappers for QSGD."""
from __future__ import annotations

import functools

import jax

from repro.kernels.backend import kernel_interpret, resolve_backend
from repro.kernels.qsgd.qsgd import qsgd_compress
from repro.kernels.qsgd.ref import qsgd_decompress_ref, qsgd_ref


@functools.partial(jax.jit, static_argnames=("s_levels", "block_r",
                                             "interpret"))
def compress(g, u, *, s_levels: int = 127, block_r: int = 256,
             interpret: bool = True):
    return qsgd_compress(g, u, s_levels=s_levels, block_r=block_r,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("s_levels", "block_r",
                                             "backend"))
def quantize(g, u, *, s_levels: int = 127, block_r: int = 256,
             backend: str = "auto"):
    """s-level stochastic quantize, dispatched through the kernel backend
    seam.  Returns (levels int8 [R, C], norm scalar f32)."""
    if resolve_backend(backend) == "kernel":
        return qsgd_compress(g, u, s_levels=s_levels, block_r=block_r,
                             interpret=kernel_interpret())
    return qsgd_ref(g, u, s_levels)


@functools.partial(jax.jit, static_argnames=("s_levels",))
def decompress(q, norm, *, s_levels: int = 127):
    return qsgd_decompress_ref(q, norm, s_levels)


def wire_bytes(numel: int, s_levels: int = 127) -> int:
    """8-bit levels (s=127) + 4B norm; Elias coding would shrink further."""
    return numel + 4


__all__ = ["compress", "decompress", "quantize", "qsgd_ref", "wire_bytes"]
