from repro.kernels.qsgd.ops import (compress, decompress, qsgd_ref, quantize,
                                    wire_bytes)

__all__ = ["compress", "decompress", "quantize", "qsgd_ref", "wire_bytes"]
