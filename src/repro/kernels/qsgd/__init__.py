from repro.kernels.qsgd.ops import compress, decompress, qsgd_ref, wire_bytes

__all__ = ["compress", "decompress", "qsgd_ref", "wire_bytes"]
