"""Oracle for QSGD (Alistarh et al. [8]): s-level stochastic quantization.

Q(g_i) = ||g||_2 * sign(g_i) * xi_i,  xi_i in {0, 1/s, ..., s/s} with
stochastic rounding:  let p = |g_i| / ||g||_2 * s;  xi = (floor(p) +
Bernoulli(frac(p))) / s.  The uniform draw is an explicit input.
"""
from __future__ import annotations

import jax.numpy as jnp


def qsgd_ref(g, u, s_levels: int = 127):
    """g, u [R, C] -> (levels int8 signed, norm scalar f32)."""
    g32 = g.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(jnp.square(g32)))
    p = jnp.abs(g32) / jnp.maximum(norm, 1e-30) * s_levels
    lo = jnp.floor(p)
    lvl = lo + (u < (p - lo)).astype(jnp.float32)
    lvl = jnp.clip(lvl, 0, s_levels)
    q = (jnp.sign(g32) * lvl).astype(jnp.int8)
    return q, norm


def qsgd_decompress_ref(q, norm, s_levels: int = 127):
    return q.astype(jnp.float32) * (norm / s_levels)
