"""Topology-explicit allreduce schedules (survey §3.3.1(2)) built from
``jax.lax.ppermute`` inside ``shard_map``.

On the GPU clusters the survey describes, the topology (ring / tree /
butterfly / fully-connected) is a software overlay on a switched network.
On a TPU torus the ICI *is* the topology, so these become collective
*schedules*; the benchmark compares their per-device traffic against XLA's
native ``psum`` (which ring-schedules on the torus already) — quantifying
when a hand-rolled schedule loses to the compiler's.

All variants are numerically equal to ``psum`` (tested on 8 host devices).

Per-device bytes moved for an n-worker reduce of a size-S tensor:
  ring            2 (n-1)/n S        (bandwidth-optimal)
  butterfly       log2(n) S          (recursive doubling / halving-doubling)
  tree            2 log2(n) S        (reduce to root + broadcast)
  fully-connected (n-1) S            (every worker sends its full tensor)
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import axis_size


# ------------------------------------------------------------------ schedules
def ring_allreduce(x, axis_name: str):
    """Bandwidth-optimal ring: reduce-scatter then all-gather, 2(n-1) steps."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    me = lax.axis_index(axis_name)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    chunks = jnp.pad(flat, (0, pad)).reshape(n, -1)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def rs_step(i, c):
        send = c[(me - i) % n]
        recv = lax.ppermute(send, axis_name, fwd)
        return c.at[(me - i - 1) % n].add(recv)

    chunks = lax.fori_loop(0, n - 1, rs_step, chunks)
    # rank r now owns reduced chunk (r + 1) % n

    def ag_step(i, c):
        send = c[(me + 1 - i) % n]
        recv = lax.ppermute(send, axis_name, fwd)
        return c.at[(me - i) % n].set(recv)

    chunks = lax.fori_loop(0, n - 1, ag_step, chunks)
    return chunks.reshape(-1)[:flat.shape[0]].reshape(shape).astype(dtype)


def butterfly_allreduce(x, axis_name: str):
    """Recursive doubling: log2(n) exchange-and-add rounds (n power of 2)."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    assert n & (n - 1) == 0, "butterfly requires power-of-two workers"
    acc = x
    for k in range(int(math.log2(n))):
        d = 1 << k
        perm = [(i, i ^ d) for i in range(n)]
        acc = acc + lax.ppermute(acc, axis_name, perm)
    return acc


def tree_allreduce(x, axis_name: str):
    """Binomial tree: reduce to rank 0, then broadcast back down."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    me = lax.axis_index(axis_name)
    levels = int(math.log2(n))
    assert 1 << levels == n, "tree requires power-of-two workers"
    acc = x
    # reduce phase: at level k, ranks with me % 2^(k+1) == 2^k send down
    for k in range(levels):
        d = 1 << k
        perm = [(i, i - d) for i in range(n) if i % (2 * d) == d]
        recv = lax.ppermute(acc, axis_name, perm)
        is_receiver = (me % (2 * d)) == 0
        acc = jnp.where(is_receiver, acc + recv, acc)
    # broadcast phase
    for k in reversed(range(levels)):
        d = 1 << k
        perm = [(i, i + d) for i in range(n) if i % (2 * d) == 0]
        recv = lax.ppermute(acc, axis_name, perm)
        is_receiver = (me % (2 * d)) == d
        acc = jnp.where(is_receiver, recv, acc)
    return acc


def fully_connected_allreduce(x, axis_name: str):
    """Every worker sends its full tensor to every other (the O(n^2) traffic
    case the survey warns about); numerically an all_gather + sum."""
    g = lax.all_gather(x, axis_name)
    return jnp.sum(g, axis=0).astype(x.dtype)


def psum_allreduce(x, axis_name: str):
    return lax.psum(x, axis_name)


TOPOLOGIES = {
    "ring": ring_allreduce,
    "butterfly": butterfly_allreduce,
    "tree": tree_allreduce,
    "fully_connected": fully_connected_allreduce,
    "psum": psum_allreduce,
}


def per_device_bytes(topology: str, n: int, size_bytes: int) -> float:
    """Analytic per-device traffic for one allreduce (benchmark model)."""
    if n == 1:
        return 0.0
    if topology in ("ring", "psum"):
        return 2 * (n - 1) / n * size_bytes
    if topology == "butterfly":
        return math.log2(n) * size_bytes
    if topology == "tree":
        return 2 * math.log2(n) * size_bytes
    if topology == "fully_connected":
        return (n - 1) * size_bytes
    raise ValueError(topology)


# ------------------------------------------------------------------- frontend
def make_allreduce(topology: str, axis_name: str, mean: bool = True):
    """Returns f(pytree) -> pytree applying the chosen schedule per leaf.
    Must be called inside shard_map over ``axis_name``."""
    fn = TOPOLOGIES[topology]

    def reduce_tree(tree):
        def one(x):
            y = fn(x, axis_name)
            if mean:
                y = y / axis_size(axis_name)
            return y.astype(x.dtype)
        return jax.tree.map(one, tree)

    return reduce_tree
