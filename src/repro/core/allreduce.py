"""Topology-explicit allreduce schedules (survey §3.3.1(2)) built from
``jax.lax.ppermute`` inside ``shard_map``.

On the GPU clusters the survey describes, the topology (ring / tree /
butterfly / fully-connected) is a software overlay on a switched network.
On a TPU torus the ICI *is* the topology, so these become collective
*schedules*; the benchmark compares their per-device traffic against XLA's
native ``psum`` (which ring-schedules on the torus already) — quantifying
when a hand-rolled schedule loses to the compiler's.

As of the communication-plane refactor the schedules live in
``repro.comm.transport`` as *schedule generators*: the same topologies
can carry **encoded segment payloads** (encode → ppermute the planes →
decode-accumulate, per-worker error feedback) when a ``CommPlan`` runs
with ``wire="measured"``.  This module re-exports the exact
full-precision forms — unchanged, still numerically equal to ``psum``
(tested on 8 host devices) — and the legacy analytic traffic model.

Per-device bytes moved for an n-worker reduce of a size-S tensor:
  ring            2 (n-1)/n S        (bandwidth-optimal)
  butterfly       log2(n) S          (recursive doubling / halving-doubling)
  tree            2 log2(n) S        (reduce to root + broadcast)
  fully-connected (n-1) S            (every worker sends its full tensor)
"""
from __future__ import annotations

import jax

from repro.comm.transport import (SCHEDULES, butterfly_allreduce,
                                  fully_connected_allreduce, per_device_bytes,
                                  psum_allreduce, ring_allreduce,
                                  tree_allreduce)
from repro.core.collectives import axis_size

TOPOLOGIES = SCHEDULES

__all__ = ["TOPOLOGIES", "ring_allreduce", "butterfly_allreduce",
           "tree_allreduce", "fully_connected_allreduce", "psum_allreduce",
           "per_device_bytes", "make_allreduce"]


# ------------------------------------------------------------------- frontend
def make_allreduce(topology: str, axis_name: str, mean: bool = True):
    """Returns f(pytree) -> pytree applying the chosen schedule per leaf.
    Must be called inside shard_map over ``axis_name``."""
    fn = TOPOLOGIES[topology]

    def reduce_tree(tree):
        def one(x):
            y = fn(x, axis_name)
            if mean:
                y = y / axis_size(axis_name)
            return y.astype(x.dtype)
        return jax.tree.map(one, tree)

    return reduce_tree
