"""Parameter-synchronization models from survey §3.3.2 / Table 1.

BSP (synchronous), SSP (bounded-asynchronous, Cipar et al. [28]),
ASP (asynchronous, Hogwild/Downpour [149, 38]) and SMA (CROSSBOW's
synchronous model averaging [89]).

TPU adaptation (DESIGN.md §2.3): SPMD programs are bulk-synchronous by
construction — there is no shared memory for lock-free updates.  Asynchrony
is therefore a *deterministic discrete-event simulation*: K logical workers
with heterogeneous speeds push gradients computed against the parameter
version they last pulled; the trainer replays the resulting staleness
schedule exactly.  This reproduces the survey's convergence semantics
(what staleness does to the loss curve, the straggler problem, the SSP
bound) with bit-reproducible results.  Compute per event is a jitted step.

``SimSyncEngine`` is the implementation, structured as
``init / step / finalize`` so the declarative front-end
(``repro.train.strategy``) can drive it one global step at a time through
the shared trainer loop; ``run`` composes them and is bitwise-identical to
the pre-refactor monolithic loops.  ``SyncEngine`` is a deprecated alias
kept for existing call sites — construct engines via
``repro.train.Strategy(...).build(grad_fn)`` instead.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.compression import Compressor
from repro.elastic.backup import drop_set
from repro.elastic.detector import StepTimeEMA


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    mode: str = "bsp"            # bsp | ssp | asp | sma
    num_workers: int = 4
    staleness: int = 3           # SSP bound s
    lr: float = 0.1
    sma_mu: float = 0.1          # SMA correction strength
    # deterministic worker speeds: worker i finishes every periods[i] ticks
    periods: Optional[Tuple[int, ...]] = None
    compressor: Compressor = Compressor("none")
    backup: int = 0              # BSP backup workers: drop the k slowest
    # measured straggler detection: per-worker step-time EMA replaces the
    # scheduled ranking in the backup drop set (elastic/detector.py)
    detect: bool = False
    seed: int = 0


def default_periods(num_workers: int) -> Tuple[int, ...]:
    """Heterogeneous-by-default deterministic worker speeds (worker i
    finishes every i+1 ticks) — the one schedule both the simulator and the
    device backend replay."""
    return tuple(1 + i for i in range(num_workers))


def firing_schedule(tick: int, periods: Tuple[int, ...],
                    batch_idx: List[int],
                    bound: Optional[int]) -> List[int]:
    """Workers firing at this tick, in event order: worker w fires every
    ``periods[w]`` ticks unless (SSP) its batch clock is more than
    ``bound`` ahead of the slowest worker's (``bound=None`` = ASP).
    Intra-tick clock increments are visible to later workers' bound
    checks, exactly as the events apply.  This is the *single*
    deterministic schedule: the simulator executes it and the device
    backend replays it — divergence is impossible by construction."""
    firing = []
    scratch = list(batch_idx)
    for w, p in enumerate(periods):
        if tick % p:
            continue
        if bound is not None and scratch[w] - min(scratch) > bound:
            continue  # SSP: fast worker blocks on clock bound
        firing.append(w)
        scratch[w] += 1
    return firing


class ElasticWorkerSet:
    """The shared elastic worker-schedule surface of every engine
    (simulated and device): straggler slowdowns over the base ``periods``,
    the backup-drop accounting, and measured straggler detection.  One
    implementation, inherited by both backends, so the effective schedule
    — and therefore the async firing order and the backup drop set —
    cannot desynchronize between them.  Subclass ``__init__`` must set
    ``self.periods``, ``self.slowdowns``, ``self._dropped``, and call
    ``_init_detector``."""

    periods: Tuple[int, ...]
    slowdowns: List[float]
    _dropped: int
    detector: Optional[StepTimeEMA]

    def _init_detector(self, detect: bool, num_workers: int):
        self.detector = StepTimeEMA(num_workers) if detect else None

    def set_slowdown(self, worker: int, factor: float):
        """Apply a straggler event: worker's period scales by ``factor``
        (1.0 clears).  Affects the async firing schedule and the backup
        drop set."""
        self.slowdowns[worker] = factor

    def effective_periods(self) -> Tuple[int, ...]:
        """Base periods with active slowdowns folded in (min 1 tick) —
        the schedule both the firing loop and the backup drop set use."""
        return tuple(max(1, int(round(p * s)))
                     for p, s in zip(self.periods, self.slowdowns))

    def backup_drop(self, k: int):
        """The round's backup drop set: the *measured* step-time ranking
        once detection has warmed up, else the scheduled ranking
        (elastic/backup.py) — the same rule on both backends."""
        if self.detector is not None and self.detector.ready:
            return self.detector.drop_set(k)
        return drop_set(self.periods, k, self.slowdowns)

    def dropped_updates(self) -> int:
        """Gradient pushes discarded by the backup-worker policy."""
        return self._dropped

    def extra_metrics(self) -> dict:
        """Backend-specific additions to ``Engine.metrics()`` — part of
        the engine protocol (every backend implements it; the Strategy
        wrapper calls it unconditionally).  The simulator has none."""
        return {}


class SimSyncEngine(ElasticWorkerSet):
    """Drives ``grad_fn(params, batch) -> (loss, grads)`` under a
    synchronization model over a stream of per-worker batches.

    One *global step* is K updates' worth of progress: a full round for
    BSP/SMA, and for SSP/ASP as many whole ticks as it takes for the
    update counter to cross the next multiple of K (ticks are atomic, so a
    run of T steps replays exactly the event sequence of the monolithic
    event loop with threshold ``updates < T*K``)."""

    def __init__(self, cfg: SyncConfig, grad_fn: Callable):
        if cfg.backup and cfg.mode != "bsp":
            raise ValueError("backup workers compose with bsp only "
                             "(async modes have no round to drop from)")
        if cfg.backup >= cfg.num_workers:
            raise ValueError("backup k must leave at least one worker")
        self.cfg = cfg
        self.grad_fn = jax.jit(grad_fn)
        periods = cfg.periods or default_periods(cfg.num_workers)
        assert len(periods) == cfg.num_workers
        self.periods = periods
        # elastic straggler state: slow:wNxF events scale worker N's period
        self.slowdowns: List[float] = [1.0] * cfg.num_workers
        self._dropped = 0
        self._init_detector(cfg.detect, cfg.num_workers)
        self._apply = jax.jit(
            lambda p, g, lr: jax.tree.map(lambda a, b: a - lr * b, p, g))
        self._avg = jax.jit(
            lambda gs: jax.tree.map(lambda *x: sum(x) / len(x), *gs))
        mu = cfg.sma_mu
        self._sma_correct = jax.jit(
            lambda rep, center, g, lr: jax.tree.map(
                lambda r, z, gg: r - lr * gg - mu * (r - z), rep, center, g))
        self._wire = 0

    # ----------------------------------------------------------- init state
    def init(self, params) -> Dict[str, Any]:
        cfg = self.cfg
        K = cfg.num_workers
        st: Dict[str, Any] = dict(
            rng=jax.random.PRNGKey(cfg.seed),
            comp_states=[cfg.compressor.init_state(params)
                         for _ in range(K)],
            wire=0,
        )
        if cfg.mode in ("bsp",):
            st.update(params=params)
        elif cfg.mode in ("ssp", "asp"):
            st.update(
                params=params,
                pulled=[jax.tree.map(lambda x: x, params) for _ in range(K)],
                pulled_ver=[0] * K,
                server_ver=0,
                tick=0,
                updates=0,
                batch_idx=[0] * K,
                # reshard rebases the step↔update accounting here so a
                # resized run keeps "one global step = K updates" at the
                # *current* K (see reshard)
                updates_base=0,
                step_base=0,
            )
        elif cfg.mode == "sma":
            st.update(replicas=[jax.tree.map(lambda x: x, params)
                                for _ in range(K)])
        else:
            raise ValueError(cfg.mode)
        return st

    # ------------------------------------------------------------------ BSP
    def _step_bsp(self, st, batches, t):
        cfg = self.cfg
        K = cfg.num_workers
        params = st["params"]
        # backup workers: the k slowest — under the effective schedule, or
        # the *measured* step-time ranking when detection is warmed up —
        # never reach the server this round: their batch is discarded and
        # their EF state is untouched (elastic/backup.py + detector.py;
        # same rule on devices)
        drop = self.backup_drop(cfg.backup)
        losses, grads = [], []
        for w in range(K):
            if w in drop:
                if self.detector is not None:
                    # a real straggler still runs — its push just never
                    # reaches the server — so keep measuring it, or a
                    # recovered worker could stay dropped forever
                    t0 = time.perf_counter()
                    self.grad_fn(params, batches(t, w))
                    self.detector.observe(w, time.perf_counter() - t0)
                continue
            t0 = time.perf_counter()
            loss, g = self.grad_fn(params, batches(t, w))
            if self.detector is not None:
                self.detector.observe(w, time.perf_counter() - t0)
            if cfg.compressor.method != "none":
                st["rng"], sub = jax.random.split(st["rng"])
                g, st["comp_states"][w], wb = cfg.compressor.roundtrip(
                    g, st["comp_states"][w], sub)
                st["wire"] += wb
            else:
                st["wire"] += sum(int(x.size) * 4
                                  for x in jax.tree.leaves(g))
            losses.append(float(loss))
            grads.append(g)
        self._dropped += len(drop)
        st["params"] = self._apply(params, self._avg(grads), cfg.lr)
        ev = dict(step=t, loss=float(np.mean(losses)), max_staleness=0)
        if drop:
            ev["dropped"] = sorted(drop)
        return st, [ev]

    # ------------------------------------------------------- SSP / ASP core
    def _step_async(self, st, batches, t, bound: Optional[int]):
        """Event simulation: server clock = #updates applied.  Worker w
        recomputes every periods[w] ticks against its pulled version;
        SSP blocks a worker whose pulled version lags > bound behind the
        slowest worker's version (the SSP condition of [28]).  Advances
        whole ticks until ``updates >= (t+1) * K``."""
        cfg = self.cfg
        K = cfg.num_workers
        events = []
        eff_periods = self.effective_periods()   # invariant within a step
        while st["updates"] - st["updates_base"] < \
                (t + 1 - st["step_base"]) * K:
            st["tick"] += 1
            for w in firing_schedule(st["tick"], eff_periods,
                                     st["batch_idx"], bound):
                loss, g = self.grad_fn(st["pulled"][w],
                                       batches(st["batch_idx"][w], w))
                st["batch_idx"][w] += 1
                if cfg.compressor.method != "none":
                    st["rng"], sub = jax.random.split(st["rng"])
                    g, st["comp_states"][w], wb = cfg.compressor.roundtrip(
                        g, st["comp_states"][w], sub)
                    st["wire"] += wb
                else:
                    st["wire"] += sum(int(x.size) * 4
                                      for x in jax.tree.leaves(g))
                staleness = st["server_ver"] - st["pulled_ver"][w]
                st["params"] = self._apply(st["params"], g, cfg.lr)
                st["server_ver"] += 1
                st["updates"] += 1
                st["pulled"][w] = st["params"]   # pull fresh copy after push
                st["pulled_ver"][w] = st["server_ver"]
                events.append(dict(step=st["updates"], loss=float(loss),
                                   max_staleness=staleness, worker=w))
        return st, events

    # ------------------------------------------------------------------ SMA
    def _step_sma(self, st, batches, t):
        """CROSSBOW synchronous model averaging: independent replicas pulled
        toward the central average each step."""
        cfg = self.cfg
        K = cfg.num_workers
        center = self._avg(st["replicas"])
        losses = []
        for w in range(K):
            loss, g = self.grad_fn(st["replicas"][w], batches(t, w))
            st["replicas"][w] = self._sma_correct(st["replicas"][w], center,
                                                  g, cfg.lr)
            losses.append(float(loss))
            st["wire"] += sum(int(x.size) * 4 for x in jax.tree.leaves(g))
        return st, [dict(step=t, loss=float(np.mean(losses)),
                         max_staleness=0)]

    # ----------------------------------------------------------------- step
    def step(self, st, batches: Callable[[int, int], Any], t: int):
        """Advance one global step.  Returns (state, events) where events is
        the list of per-update history records produced in this step."""
        mode = self.cfg.mode
        if mode == "bsp":
            st, ev = self._step_bsp(st, batches, t)
        elif mode == "ssp":
            st, ev = self._step_async(st, batches, t, self.cfg.staleness)
        elif mode == "asp":
            st, ev = self._step_async(st, batches, t, None)
        elif mode == "sma":
            st, ev = self._step_sma(st, batches, t)
        else:
            raise ValueError(mode)
        self._wire = st["wire"]
        return st, ev

    def finalize(self, st):
        """Final parameters for the run-state (SMA: replica average)."""
        if self.cfg.mode == "sma":
            return self._avg(st["replicas"])
        return st["params"]

    def wire_bytes(self) -> int:
        return self._wire

    # ------------------------------------------- elastic reshard / snapshot
    def reshard(self, st, new_workers: int, step: int = 0,
                lost: Tuple[int, ...] = ()):
        """Re-size the simulated worker set N→M in place and return the
        resharded run-state.  Survivors (old slots minus ``lost``, in
        order) keep their compressor/EF state and batch clocks; grown
        slots start fresh at the batch frontier.  A reshard is a
        synchronization barrier: every async worker re-pulls the current
        params at the current server version, and the step↔update
        accounting rebases at global step ``step`` so one global step
        stays M updates."""
        cfg = self.cfg
        if new_workers < 1:
            raise ValueError("new_workers must be >= 1")
        if cfg.backup >= new_workers:
            raise ValueError(f"backup k={cfg.backup} needs > k workers")
        bad = [w for w in lost if w < 0 or w >= cfg.num_workers]
        if bad:
            raise ValueError(f"lost workers {bad} out of range for "
                             f"{cfg.num_workers} workers")
        survivors = [w for w in range(cfg.num_workers) if w not in set(lost)]
        slots = survivors[:new_workers]
        grown = new_workers - len(slots)
        # survivors keep their speed identity (like their slowdowns and
        # EF state); grown slots take the default-schedule tail
        periods = tuple([self.periods[s] for s in slots]
                        + list(default_periods(new_workers))[len(slots):])
        self.cfg = cfg = dataclasses.replace(
            cfg, num_workers=new_workers, periods=periods)
        self.periods = periods
        self.slowdowns = [self.slowdowns[s] for s in slots] + [1.0] * grown
        if self.detector is not None:
            self.detector.reshard(slots, new_workers)
        params_like = (st["replicas"][0] if cfg.mode == "sma"
                       else st["params"])
        st["comp_states"] = (
            [st["comp_states"][s] for s in slots]
            + [cfg.compressor.init_state(params_like) for _ in range(grown)])
        if cfg.mode in ("ssp", "asp"):
            frontier = max([st["batch_idx"][s] for s in slots] or [0])
            st["pulled"] = [st["params"]] * new_workers
            st["pulled_ver"] = [st["server_ver"]] * new_workers
            st["batch_idx"] = ([st["batch_idx"][s] for s in slots]
                               + [frontier] * grown)
            st["updates_base"] = st["updates"]
            st["step_base"] = step
        elif cfg.mode == "sma":
            center = self._avg(st["replicas"])
            st["replicas"] = ([st["replicas"][s] for s in slots]
                              + [center] * grown)
        return st

    def export_state(self, st) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Split the run-state into (array pytree, JSON-able meta) for
        ``repro.checkpoint`` — the inverse of ``import_state``."""
        cfg = self.cfg
        arrays: Dict[str, Any] = {"rng": st["rng"],
                                  "comp_states": st["comp_states"]}
        meta: Dict[str, Any] = dict(
            backend="sim", mode=cfg.mode, num_workers=cfg.num_workers,
            wire=int(st["wire"]), periods=list(self.periods),
            slowdowns=list(self.slowdowns), dropped=self._dropped,
            detector=(self.detector.state() if self.detector is not None
                      else None))
        if cfg.mode == "sma":
            arrays["replicas"] = st["replicas"]
        else:
            arrays["params"] = st["params"]
        if cfg.mode in ("ssp", "asp"):
            arrays["pulled"] = st["pulled"]
            meta.update(pulled_ver=list(st["pulled_ver"]),
                        server_ver=int(st["server_ver"]),
                        tick=int(st["tick"]), updates=int(st["updates"]),
                        batch_idx=list(st["batch_idx"]),
                        updates_base=int(st["updates_base"]),
                        step_base=int(st["step_base"]))
        return arrays, meta

    def import_state(self, arrays: Dict[str, Any], meta: Dict[str, Any]):
        """Rebuild the run-state from an ``export_state`` snapshot.  The
        engine must already be configured at ``meta['num_workers']``."""
        cfg = self.cfg
        if meta["num_workers"] != cfg.num_workers:
            raise ValueError(
                f"snapshot has {meta['num_workers']} workers, engine has "
                f"{cfg.num_workers}; reshard the engine first")
        # the worker speed schedule travels with the snapshot: a resharded
        # run's remapped periods must survive a cross-process restore
        self.periods = tuple(int(p) for p in meta["periods"])
        self.cfg = cfg = dataclasses.replace(cfg, periods=self.periods)
        self.slowdowns = [float(s) for s in meta["slowdowns"]]
        self._dropped = int(meta["dropped"])
        if self.detector is not None:
            self.detector.load_state(meta.get("detector"))
        st: Dict[str, Any] = dict(
            rng=jax.numpy.asarray(arrays["rng"]),
            comp_states=arrays["comp_states"], wire=int(meta["wire"]))
        if cfg.mode == "sma":
            st["replicas"] = arrays["replicas"]
        else:
            st["params"] = arrays["params"]
        if cfg.mode in ("ssp", "asp"):
            st.update(pulled=arrays["pulled"],
                      pulled_ver=list(meta["pulled_ver"]),
                      server_ver=int(meta["server_ver"]),
                      tick=int(meta["tick"]), updates=int(meta["updates"]),
                      batch_idx=list(meta["batch_idx"]),
                      updates_base=int(meta["updates_base"]),
                      step_base=int(meta["step_base"]))
        self._wire = st["wire"]
        return st

    # ------------------------------------------------------------------ run
    def run(self, params, batches: Callable[[int, int], Any], steps: int):
        """batches(t, worker) -> batch pytree.  Returns (params, history,
        wire_bytes)."""
        st = self.init(params)
        hist: List[dict] = []
        for t in range(steps):
            st, ev = self.step(st, batches, t)
            hist.extend(ev)
        return self.finalize(st), hist, st["wire"]


# ------------------------------------------------------- deprecation shim
_WARNED: set = set()


def warn_deprecated(name: str, replacement: str):
    """Warn once per process per deprecated entry point."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; construct engines declaratively via "
        f"{replacement}", DeprecationWarning, stacklevel=3)


class SyncEngine(SimSyncEngine):
    """Deprecated alias for ``SimSyncEngine`` — kept so existing call sites
    keep working.  Use ``repro.train.Strategy(sync=..., backend='sim')
    .build(grad_fn)`` which wraps the same engine (bitwise-identical
    results)."""

    def __init__(self, cfg: SyncConfig, grad_fn: Callable):
        warn_deprecated("SyncEngine",
                        "repro.train.Strategy(...).build(grad_fn)")
        super().__init__(cfg, grad_fn)
