"""Parameter-synchronization models from survey §3.3.2 / Table 1.

BSP (synchronous), SSP (bounded-asynchronous, Cipar et al. [28]),
ASP (asynchronous, Hogwild/Downpour [149, 38]) and SMA (CROSSBOW's
synchronous model averaging [89]).

TPU adaptation (DESIGN.md §2.3): SPMD programs are bulk-synchronous by
construction — there is no shared memory for lock-free updates.  Asynchrony
is therefore a *deterministic discrete-event simulation*: K logical workers
with heterogeneous speeds push gradients computed against the parameter
version they last pulled; the trainer replays the resulting staleness
schedule exactly.  This reproduces the survey's convergence semantics
(what staleness does to the loss curve, the straggler problem, the SSP
bound) with bit-reproducible results.  Compute per event is a jitted step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import Compressor


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    mode: str = "bsp"            # bsp | ssp | asp | sma
    num_workers: int = 4
    staleness: int = 3           # SSP bound s
    lr: float = 0.1
    sma_mu: float = 0.1          # SMA correction strength
    # deterministic worker speeds: worker i finishes every periods[i] ticks
    periods: Optional[Tuple[int, ...]] = None
    compressor: Compressor = Compressor("none")
    seed: int = 0


class SyncEngine:
    """Drives ``grad_fn(params, batch) -> (loss, grads)`` under a
    synchronization model over a stream of per-worker batches."""

    def __init__(self, cfg: SyncConfig, grad_fn: Callable):
        self.cfg = cfg
        self.grad_fn = jax.jit(grad_fn)
        periods = cfg.periods or tuple(
            1 + i for i in range(cfg.num_workers))  # heterogeneous by default
        assert len(periods) == cfg.num_workers
        self.periods = periods
        self._apply = jax.jit(
            lambda p, g, lr: jax.tree.map(lambda a, b: a - lr * b, p, g))
        self._avg = jax.jit(
            lambda gs: jax.tree.map(lambda *x: sum(x) / len(x), *gs))

    # ------------------------------------------------------------------ BSP
    def _run_bsp(self, params, batches, steps):
        K = self.cfg.num_workers
        hist = []
        # one independent EF state per worker (not K aliases of one tree):
        # each worker's residual tracks what *it* failed to transmit
        comp_states = [self.cfg.compressor.init_state(params)
                       for _ in range(K)]
        rng = jax.random.PRNGKey(self.cfg.seed)
        wire_total = 0
        for t in range(steps):
            losses, grads = [], []
            for w in range(K):
                loss, g = self.grad_fn(params, batches(t, w))
                if self.cfg.compressor.method != "none":
                    rng, sub = jax.random.split(rng)
                    g, comp_states[w], wb = self.cfg.compressor.roundtrip(
                        g, comp_states[w], sub)
                    wire_total += wb
                else:
                    wire_total += sum(int(x.size) * 4
                                      for x in jax.tree.leaves(g))
                losses.append(float(loss))
                grads.append(g)
            params = self._apply(params, self._avg(grads), self.cfg.lr)
            hist.append(dict(step=t, loss=float(np.mean(losses)),
                             max_staleness=0))
        return params, hist, wire_total

    # ------------------------------------------------------- SSP / ASP core
    def _run_async(self, params, batches, steps, bound: Optional[int]):
        """Event simulation: server clock = #updates applied.  Worker w
        recomputes every periods[w] ticks against its pulled version;
        SSP blocks a worker whose pulled version lags > bound behind the
        slowest worker's version (the SSP condition of [28])."""
        K = self.cfg.num_workers
        pulled = [jax.tree.map(lambda x: x, params) for _ in range(K)]
        pulled_ver = [0] * K
        server_ver = 0
        hist = []
        comp_states = [self.cfg.compressor.init_state(params)
                       for _ in range(K)]
        rng = jax.random.PRNGKey(self.cfg.seed)
        wire_total = 0
        tick = 0
        updates = 0
        batch_idx = [0] * K
        while updates < steps * K:
            tick += 1
            for w in range(K):
                if tick % self.periods[w]:
                    continue
                if bound is not None:
                    slowest = min(batch_idx)
                    if batch_idx[w] - slowest > bound:
                        continue  # SSP: fast worker blocks on clock bound

                loss, g = self.grad_fn(pulled[w], batches(batch_idx[w], w))
                batch_idx[w] += 1
                if self.cfg.compressor.method != "none":
                    rng, sub = jax.random.split(rng)
                    g, comp_states[w], wb = self.cfg.compressor.roundtrip(
                        g, comp_states[w], sub)
                    wire_total += wb
                else:
                    wire_total += sum(int(x.size) * 4
                                      for x in jax.tree.leaves(g))
                staleness = server_ver - pulled_ver[w]
                params = self._apply(params, g, self.cfg.lr)
                server_ver += 1
                updates += 1
                pulled[w] = params           # pull fresh copy after push
                pulled_ver[w] = server_ver
                hist.append(dict(step=updates, loss=float(loss),
                                 max_staleness=staleness, worker=w))
        return params, hist, wire_total

    # ------------------------------------------------------------------ SMA
    def _run_sma(self, params, batches, steps):
        """CROSSBOW synchronous model averaging: independent replicas pulled
        toward the central average each step."""
        K = self.cfg.num_workers
        replicas = [jax.tree.map(lambda x: x, params) for _ in range(K)]
        mu = self.cfg.sma_mu
        hist = []
        wire_total = 0

        @jax.jit
        def avg_of(reps):
            return jax.tree.map(lambda *x: sum(x) / len(x), *reps)

        @jax.jit
        def correct(rep, center, g, lr):
            return jax.tree.map(
                lambda r, z, gg: r - lr * gg - mu * (r - z), rep, center, g)

        for t in range(steps):
            center = avg_of(replicas)
            losses = []
            for w in range(K):
                loss, g = self.grad_fn(replicas[w], batches(t, w))
                replicas[w] = correct(replicas[w], center, g, self.cfg.lr)
                losses.append(float(loss))
                wire_total += sum(int(x.size) * 4 for x in jax.tree.leaves(g))
            hist.append(dict(step=t, loss=float(np.mean(losses)),
                             max_staleness=0))
        return avg_of(replicas), hist, wire_total

    # ------------------------------------------------------------------ run
    def run(self, params, batches: Callable[[int, int], Any], steps: int):
        """batches(t, worker) -> batch pytree.  Returns (params, history,
        wire_bytes)."""
        mode = self.cfg.mode
        if mode == "bsp":
            return self._run_bsp(params, batches, steps)
        if mode == "ssp":
            return self._run_async(params, batches, steps,
                                   self.cfg.staleness)
        if mode == "asp":
            return self._run_async(params, batches, steps, None)
        if mode == "sma":
            return self._run_sma(params, batches, steps)
        raise ValueError(mode)
