"""Version-portable ``shard_map`` (and friends) for the jax releases we
support.

jax has moved ``shard_map`` twice:

  * jax < 0.4.30           : ``jax.experimental.shard_map.shard_map``
    (kwarg ``check_rep``)
  * 0.4.30 <= jax < 0.5    : same entry point, still ``check_rep``
  * jax >= 0.5 / 0.6       : promoted to ``jax.shard_map``; the replication
    check was renamed ``check_vma`` (varying-manual-axes)

Call sites in this repo were written against the *new* spelling
(``jax.shard_map(..., check_vma=...)``), which does not exist on the
installed jax 0.4.37 — every multi-device test and benchmark broke.  This
shim resolves the entry point once, translates ``check_vma``/``check_rep``
into whatever the resolved function actually accepts, and is the single
``shard_map`` used everywhere in the repo (core, examples, benchmarks,
tests).
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional

import jax

__all__ = ["shard_map", "resolve_shard_map", "axis_size"]


def axis_size(axis_name: str):
    """``lax.axis_size`` appeared after jax 0.4.37.  ``psum(1, axis)`` is
    the portable spelling: jax constant-folds a literal psum to the static
    axis size on every release we support."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def resolve_shard_map() -> tuple[Callable, str]:
    """Return (shard_map_fn, dotted_origin).  Resolution order: the promoted
    ``jax.shard_map`` if this jax has it, else the experimental module."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, "jax.shard_map"
    from jax.experimental.shard_map import shard_map as exp_fn
    return exp_fn, "jax.experimental.shard_map.shard_map"


def _replication_check_kwarg(fn: Callable) -> Optional[str]:
    """Which kwarg (if any) the resolved shard_map uses for its replication
    check: 'check_vma' (new), 'check_rep' (old), or None (unknown API)."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins / C accelerated: assume new
        return "check_vma"
    if "check_vma" in params:
        return "check_vma"
    if "check_rep" in params:
        return "check_rep"
    return None


def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None, **kwargs):
    """Drop-in ``shard_map`` that accepts either ``check_vma`` (jax >= 0.5
    spelling) or ``check_rep`` (jax < 0.5 spelling) and forwards whichever
    the installed jax understands."""
    fn, _ = resolve_shard_map()
    flag = check_vma if check_vma is not None else check_rep
    if flag is not None:
        target = _replication_check_kwarg(fn)
        if target is not None:
            kwargs[target] = flag
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
