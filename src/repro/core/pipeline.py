"""Pipeline parallelism (survey §3.2.3): GPipe-style micro-batch pipeline
[Huang et al., 70] over a dedicated 'stage' mesh axis.

Each device along the stage axis holds one stage's parameters; activations
flow stage-to-stage with ``jax.lax.ppermute`` while micro-batches stream
through — at tick t, stage s processes micro-batch (t - s).  The schedule
runs inside ``lax.scan`` so it is differentiable (ppermute has a transpose
rule), giving real pipelined training, and the bubble fraction
(S-1)/(M+S-1) is observable in the tick count.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import axis_size


def gpipe_forward(stage_fn: Callable, stage_params, x_micro, axis_name: str):
    """Run inside shard_map over ``axis_name``.

    stage_fn(params, x) -> y with x/y of identical shape [mb, ...].
    stage_params: this device's stage parameters (already sharded).
    x_micro [n_micro, mb, ...]: full micro-batched input (replicated; only
    stage 0 reads it).
    Returns [n_micro, mb, ...]: outputs (nonzero only on the last stage —
    psum over the axis to broadcast if needed).
    """
    n = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    n_micro = x_micro.shape[0]
    mb_shape = x_micro.shape[1:]
    fwd = [(i, i + 1) for i in range(n - 1)]

    def tick(carry, t):
        inbox, outputs = carry
        mb_idx = t - me
        active = (mb_idx >= 0) & (mb_idx < n_micro)
        src = lax.dynamic_index_in_dim(
            x_micro, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
        x_in = jnp.where(me == 0, src, inbox)
        y = stage_fn(stage_params, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        inbox_next = lax.ppermute(y, axis_name, fwd)
        is_last = me == n - 1
        idx = jnp.clip(mb_idx, 0, n_micro - 1)
        upd = lax.dynamic_update_index_in_dim(outputs, y, idx, 0)
        outputs = jnp.where(active & is_last, upd, outputs)
        return (inbox_next, outputs), None

    inbox0 = jnp.zeros(mb_shape, dtype=x_micro.dtype)
    outputs0 = jnp.zeros_like(x_micro)
    # mark the carries as device-varying along the stage axis (scan-vma rule)
    try:
        inbox0 = lax.pcast(inbox0, (axis_name,), to="varying")
        outputs0 = lax.pcast(outputs0, (axis_name,), to="varying")
    except (AttributeError, TypeError):
        pass  # older jax: carries infer vma automatically
    (_, outputs), _ = lax.scan(tick, (inbox0, outputs0),
                               jnp.arange(n_micro + n - 1))
    return outputs


def onefb_forward(stage_fn: Callable, stage_params, x_micro, axis_name: str,
                  interleave: int = 2):
    """Interleaved 1F1B schedule (PipeDream-flush / Megatron-style virtual
    stages).  Run inside shard_map over ``axis_name``.

    Each of the S stage devices holds ``interleave`` (= v) **virtual
    stages**: its local stacked parameter block is split into v contiguous
    chunks of ``layers_local / v`` layers, and chunk c on device i is
    global virtual stage ``c*S + i`` (the engine lays params out so this
    round-robin placement holds).  Device i computes (chunk c, micro k)
    at tick ``c*m + k + i``; activations hop the ring ``i -> (i+1) % S``
    every tick, with the wrap link (S-1 -> 0) feeding a FIFO that device 0
    drains m - S ticks later for the next chunk.  The schedule runs
    ``v*m + S - 1`` ticks of ``1/v`` the per-tick work, so the bubble
    fraction drops from GPipe's (S-1)/(m+S-1) to (S-1)/(v*m+S-1).

    Requires ``n_micro >= S`` (the wrap FIFO gap m - S must be >= 0) and
    the local layer count divisible by ``interleave``.  ``interleave=1``
    is plain non-interleaved 1F1B — same bubble as GPipe at uniform tick
    cost, scheduled via the ring.  Fully differentiable: dynamic_slice /
    ppermute / scan all have transpose rules, so the backward pass runs
    the reverse schedule and gradients accumulate across micro-batches
    and chunks inside the scan, exactly as in ``gpipe_forward``.

    stage_fn(chunk_params, x) -> y applies ONE chunk (leading dim
    ``layers_local / v``) to x of shape [mb, ...].
    Returns [n_micro, mb, ...], nonzero only on the last stage device.
    """
    n = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    v = int(interleave)
    n_micro = x_micro.shape[0]
    mb_shape = x_micro.shape[1:]
    if n_micro < n:
        raise ValueError(
            f"1f1b needs micro_batches >= stages (got m={n_micro} < s={n})")
    layers_local = jax.tree.leaves(stage_params)[0].shape[0]
    if layers_local % v:
        raise ValueError(
            f"local layer count {layers_local} not divisible by "
            f"interleave={v}")
    cl = layers_local // v
    ring = [(i, (i + 1) % n) for i in range(n)]

    def tick(carry, t):
        inbox, fifo, outputs = carry
        rel = t - me
        c = jnp.clip(rel // n_micro, 0, v - 1)
        k = rel % n_micro
        active = (rel >= 0) & (rel < v * n_micro)
        # the wrap link delivered stage S-1's tick-(t-1) output for
        # (chunk c', micro k') with k' = (t - S) mod m: bank it first so
        # a gap-0 consume (m == S) still sees it this tick
        slot = (t - n) % n_micro
        fifo = jnp.where(me == 0,
                         lax.dynamic_update_index_in_dim(fifo, inbox, slot, 0),
                         fifo)
        src = lax.dynamic_index_in_dim(x_micro, k, 0, keepdims=False)
        buf = lax.dynamic_index_in_dim(fifo, k, 0, keepdims=False)
        x_in = jnp.where(me == 0, jnp.where(c == 0, src, buf), inbox)
        sp = jax.tree.map(
            lambda leaf: lax.dynamic_slice_in_dim(leaf, c * cl, cl, 0),
            stage_params)
        y = stage_fn(sp, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        inbox_next = lax.ppermute(y, axis_name, ring)
        is_out = active & (me == n - 1) & (c == v - 1)
        upd = lax.dynamic_update_index_in_dim(outputs, y, k, 0)
        outputs = jnp.where(is_out, upd, outputs)
        return (inbox_next, fifo, outputs), None

    inbox0 = jnp.zeros(mb_shape, dtype=x_micro.dtype)
    fifo0 = jnp.zeros((n_micro,) + mb_shape, dtype=x_micro.dtype)
    outputs0 = jnp.zeros_like(x_micro)
    try:
        inbox0 = lax.pcast(inbox0, (axis_name,), to="varying")
        fifo0 = lax.pcast(fifo0, (axis_name,), to="varying")
        outputs0 = lax.pcast(outputs0, (axis_name,), to="varying")
    except (AttributeError, TypeError):
        pass  # older jax: carries infer vma automatically
    (_, _, outputs), _ = lax.scan(tick, (inbox0, fifo0, outputs0),
                                  jnp.arange(v * n_micro + n - 1))
    return outputs


def gpipe_ticks(n_stages: int, n_micro: int) -> int:
    """Ticks the schedule runs for: the last micro-batch enters at tick
    ``n_micro - 1`` and drains through ``n_stages - 1`` more hops.  Every
    device executes exactly this many stage calls, so the tick count is
    also the per-stage compute (and ppermute) multiplier the hybrid
    engine's modeled accounting uses."""
    return n_micro + n_stages - 1


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe pipeline bubble: idle fraction of the schedule."""
    return (n_stages - 1) / gpipe_ticks(n_stages, n_micro)


def onefb_ticks(n_stages: int, n_micro: int, interleave: int = 2) -> int:
    """Interleaved-1F1B tick count: v*m chunk-calls per device plus the
    S-1 fill/drain.  Each tick costs 1/v of a GPipe tick (one chunk of
    ``layers_local / v`` layers), so total work is unchanged while the
    fill/drain overhead shrinks by v."""
    return interleave * n_micro + n_stages - 1


def onefb_bubble_fraction(n_stages: int, n_micro: int,
                          interleave: int = 2) -> float:
    """Interleaved-1F1B bubble: (S-1)/(v*m + S-1) — strictly below
    GPipe's (S-1)/(m + S-1) whenever v > 1."""
    return (n_stages - 1) / onefb_ticks(n_stages, n_micro, interleave)


def stacked_forward(stage_fn: Callable, stage_params, x_micro):
    """Unpipelined single-device reference for ``gpipe_forward``: apply
    the S stacked stages sequentially to every micro-batch.  The pipeline
    loss/grad tests assert the scan+ppermute schedule reproduces this to
    float tolerance — including micro-batch counts that do not divide the
    stage count (the bubble just grows)."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]
    y = x_micro
    for s in range(n_stages):
        sp = jax.tree.map(lambda leaf: leaf[s], stage_params)
        y = jax.vmap(lambda mb: stage_fn(sp, mb))(y)
    return y
