"""Pipeline parallelism (survey §3.2.3): GPipe-style micro-batch pipeline
[Huang et al., 70] over a dedicated 'stage' mesh axis.

Each device along the stage axis holds one stage's parameters; activations
flow stage-to-stage with ``jax.lax.ppermute`` while micro-batches stream
through — at tick t, stage s processes micro-batch (t - s).  The schedule
runs inside ``lax.scan`` so it is differentiable (ppermute has a transpose
rule), giving real pipelined training, and the bubble fraction
(S-1)/(M+S-1) is observable in the tick count.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import axis_size


def gpipe_forward(stage_fn: Callable, stage_params, x_micro, axis_name: str):
    """Run inside shard_map over ``axis_name``.

    stage_fn(params, x) -> y with x/y of identical shape [mb, ...].
    stage_params: this device's stage parameters (already sharded).
    x_micro [n_micro, mb, ...]: full micro-batched input (replicated; only
    stage 0 reads it).
    Returns [n_micro, mb, ...]: outputs (nonzero only on the last stage —
    psum over the axis to broadcast if needed).
    """
    n = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    n_micro = x_micro.shape[0]
    mb_shape = x_micro.shape[1:]
    fwd = [(i, i + 1) for i in range(n - 1)]

    def tick(carry, t):
        inbox, outputs = carry
        mb_idx = t - me
        active = (mb_idx >= 0) & (mb_idx < n_micro)
        src = lax.dynamic_index_in_dim(
            x_micro, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
        x_in = jnp.where(me == 0, src, inbox)
        y = stage_fn(stage_params, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        inbox_next = lax.ppermute(y, axis_name, fwd)
        is_last = me == n - 1
        idx = jnp.clip(mb_idx, 0, n_micro - 1)
        upd = lax.dynamic_update_index_in_dim(outputs, y, idx, 0)
        outputs = jnp.where(active & is_last, upd, outputs)
        return (inbox_next, outputs), None

    inbox0 = jnp.zeros(mb_shape, dtype=x_micro.dtype)
    outputs0 = jnp.zeros_like(x_micro)
    # mark the carries as device-varying along the stage axis (scan-vma rule)
    try:
        inbox0 = lax.pcast(inbox0, (axis_name,), to="varying")
        outputs0 = lax.pcast(outputs0, (axis_name,), to="varying")
    except (AttributeError, TypeError):
        pass  # older jax: carries infer vma automatically
    (_, outputs), _ = lax.scan(tick, (inbox0, outputs0),
                               jnp.arange(n_micro + n - 1))
    return outputs


def gpipe_ticks(n_stages: int, n_micro: int) -> int:
    """Ticks the schedule runs for: the last micro-batch enters at tick
    ``n_micro - 1`` and drains through ``n_stages - 1`` more hops.  Every
    device executes exactly this many stage calls, so the tick count is
    also the per-stage compute (and ppermute) multiplier the hybrid
    engine's modeled accounting uses."""
    return n_micro + n_stages - 1


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe pipeline bubble: idle fraction of the schedule."""
    return (n_stages - 1) / gpipe_ticks(n_stages, n_micro)


def stacked_forward(stage_fn: Callable, stage_params, x_micro):
    """Unpipelined single-device reference for ``gpipe_forward``: apply
    the S stacked stages sequentially to every micro-batch.  The pipeline
    loss/grad tests assert the scan+ppermute schedule reproduces this to
    float tolerance — including micro-batch counts that do not divide the
    stage count (the bubble just grows)."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]
    y = x_micro
    for s in range(n_stages):
        sp = jax.tree.map(lambda leaf: leaf[s], stage_params)
        y = jax.vmap(lambda mb: stage_fn(sp, mb))(y)
    return y
