"""Centralized architecture (survey §3.3.1(1)) adapted to TPU SPMD.

A literal parameter server (separate processes + RPC) has no TPU-pod
analogue; the faithful adaptation (DESIGN.md §2.2) keeps the PS's defining
property — *the optimizer state for each parameter shard lives in exactly
one place* — by sharding parameters/optimizer state across workers and
expressing push/pull as reduce-scatter / all-gather:

  push(grads)  : reduce_scatter over the worker axis -> my shard's grads
  update       : optimizer step on my 1/n shard only (the "server" work)
  pull(params) : all_gather my updated shard back to all workers

vs. the decentralized architecture where update work is replicated after an
all-reduce.  Traffic per device is identical (RS + AG == ring AR) but update
FLOPs/memory drop by n — exactly the ZeRO observation, and the TPU-native
form of the survey's PS-vs-allreduce dichotomy.  The benchmark quantifies
this trade-off.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import axis_size


def _pad_to(x, n):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    return jnp.pad(flat, (0, pad)), flat.shape[0]


def push_reduce_scatter(g, axis_name: str):
    """Gradient pytree -> my shard of the summed gradient (flat per leaf)."""
    n = axis_size(axis_name)

    def one(x):
        flat, _ = _pad_to(x, n)
        return lax.psum_scatter(flat.reshape(n, -1), axis_name,
                                scatter_dimension=0, tiled=False)
    return jax.tree.map(one, g)


def pull_all_gather(shard, shapes, axis_name: str):
    """My updated shards -> full parameter pytree on every worker."""
    def one(s, ref):
        full = lax.all_gather(s, axis_name).reshape(-1)[:ref.size]
        return full.reshape(ref.shape).astype(ref.dtype)
    return jax.tree.map(one, shard, shapes)


def sgd_update_fn(lr: float, mean_over=1) -> Callable:
    """The plain-SGD ``update_fn`` for ``make_ps_step``: each worker
    updates its own shard (the "server" work), optionally dividing the
    pushed gradient *sum* by ``mean_over`` workers.  This is the update
    the Strategy device backend (train/data_parallel.py) routes through
    for ``arch=ps`` — bucketed BSP pushes pass ``mean_over=axis_size``,
    single-worker SSP/ASP pushes use the raw sum."""
    def update(p_shard, g_shard, opt_shard):
        return (jax.tree.map(lambda p, g: p - lr * (g / mean_over),
                             p_shard, g_shard), opt_shard)
    return update


def make_ps_step(update_fn: Callable, axis_name: str):
    """update_fn(param_shard, grad_shard, opt_shard) ->
    (new_param_shard, new_opt_shard).

    Returns ps_step(params, grads, opt_state) to be used inside shard_map:
    each worker plays parameter-server for its 1/n shard."""
    def ps_step(params, grads, opt_state):
        n = axis_size(axis_name)
        g_shards = push_reduce_scatter(grads, axis_name)
        p_shards = jax.tree.map(
            lambda x: _shard_of(x, axis_name, n), params)
        new_p, new_opt = update_fn(p_shards, g_shards, opt_state)
        new_params = pull_all_gather(new_p, params, axis_name)
        return new_params, new_opt
    return ps_step


def _shard_of(x, axis_name: str, n: int):
    me = lax.axis_index(axis_name)
    flat, _ = _pad_to(x, n)
    m = flat.shape[0] // n
    return lax.dynamic_slice(flat, (me * m,), (m,))


# ----------------------------------------------- flat-shard public surface
# (the ZeRO optimizer-state sharding of repro.parallel routes through
# these, so the PS path and the ZeRO path cannot diverge)
def pad_to_multiple(x, n: int):
    """Flatten ``x`` and zero-pad to a multiple of ``n``.  Returns
    (padded_flat, original_flat_length)."""
    return _pad_to(x, n)


def shard_of_flat(x, axis_name: str):
    """My rank's 1/n shard of ``x`` (flattened, zero-padded) over
    ``axis_name`` — the PS "my parameters" view."""
    return _shard_of(x, axis_name, axis_size(axis_name))


def reduce_scatter_flat(flat, axis_name: str):
    """Sum-reduce a (padded) flat vector over ``axis_name``, delivering
    each rank its own contiguous shard — the PS push."""
    n = axis_size(axis_name)
    return lax.psum_scatter(flat.reshape(n, -1), axis_name,
                            scatter_dimension=0, tiled=False)


def all_gather_flat(shard, axis_name: str, length: int):
    """Concatenate per-rank shards back into the first ``length`` elements
    of the flat vector — the PS pull."""
    return lax.all_gather(shard, axis_name).reshape(-1)[:length]


def init_opt_shards(params, n: int, init_leaf: Callable):
    """Per-worker optimizer shard sizes (flat, padded length // n)."""
    def one(x):
        size = x.size
        m = (size + (-size) % n) // n
        return init_leaf(m)
    return jax.tree.map(one, params)
