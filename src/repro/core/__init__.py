"""The survey's contribution — its taxonomy of distributed-DL techniques —
as first-class composable features:

  collectives.py      —      version-portable shard_map shim
  parallelism.py      §3.2   data/tensor/hybrid sharding rules
  pipeline.py         §3.2.3 GPipe micro-batch pipeline
  parameter_server.py §3.3.1 centralized architecture (TPU adaptation)
  allreduce.py        §3.3.1 decentralized topologies (ring/tree/butterfly)
  federated.py        §3.3.1 FedAvg
  sync.py             §3.3.2 BSP / SSP / ASP / SMA
  compression.py      §3.3.3 1-bit EF / TernGrad / QSGD / DGC
  comm_scheduler.py   §3.3.3 transfer scheduling (TicTac/Bosen model)
  precision.py        §3.3.3 mixed precision + stochastic rounding
"""
from repro.core.collectives import shard_map
from repro.core.compression import Compressor, METHODS
from repro.core.sync import SimSyncEngine, SyncConfig, SyncEngine

__all__ = ["Compressor", "METHODS", "SimSyncEngine", "SyncConfig",
           "SyncEngine", "shard_map"]
