"""Parallelization methods (survey §3.2) as sharding rules.

Hybrid data+model parallelism in the Mesh-TensorFlow style the survey covers
[161]: every parameter tensor gets a PartitionSpec over mesh axes
("data", "model") [+ optional "pod"], assigned by *role*:

  column-parallel [in, out]   -> P("data", "model")   (TP on out, FSDP on in)
  row-parallel    [in, out]   -> P("model", "data")
  embedding       [V, d]      -> P("model", "data")   (vocab-parallel)
  MoE experts     [E, d, ff]  -> P("model", "data", None)  (expert-parallel,
                                  the survey's "parameter dimension")
  vectors / biases            -> replicated

Sharding the second dim over "data" is the ZeRO/FSDP choice: XLA inserts a
per-layer all-gather inside the scan, trading collective time for the n-fold
parameter-memory reduction that makes the 1T-param config representable.
The hillclimb in EXPERIMENTS.md §Perf measures exactly this trade.

Stacked (scanned) layers get leading None axes automatically.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, FlattenedIndexKey, GetAttrKey, SequenceKey

COL = ("data", "model")
ROW = ("model", "data")

# classification by the innermost meaningful key name
_COL_NAMES = {"wq", "wk", "wv", "w_q", "w_dkv", "w_krope", "w_uk", "w_uv",
              "w_gate", "w_up", "cm_k", "cm_r", "w_r", "w_k", "w_v", "w_g",
              "w_x", "w_gate_branch", "w_rg", "w_ig"}
_ROW_NAMES = {"wo", "w_o", "w_down", "w_out", "cm_v"}
_MOE_STACKED = {"w_gate", "w_up", "w_down"}


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(f"[{k.idx}]")
        elif isinstance(k, GetAttrKey):
            names.append(k.name)
        else:
            names.append(str(k))
    return names


def _trailing_spec(names: list[str], ndim: int) -> Tuple[Optional[str], ...]:
    """Spec for the trailing dims based on the leaf's role."""
    # skip dense-dict wrappers
    core = [n for n in names if n not in ("w", "b")]
    name = core[-1] if core else ""
    is_bias = names and names[-1] == "b"

    if is_bias or ndim <= 1:
        return (None,) * min(ndim, 1)
    if name == "embed":
        return ("model", "data")
    if name == "lm_head":
        return ("data", "model")
    if name in ("dec_pos", "u"):
        return (None, None)
    if name == "router":
        return ("data", None)
    if name == "conv_w":
        return (None, "model")
    if name == "wA":
        return ("data", None)
    if name == "wB":
        return (None, "data")
    in_moe = "moe" in core and "shared" not in core
    if in_moe and name in _MOE_STACKED:
        if name == "w_down":
            return ("model", None, "data")
        return ("model", "data", None)
    if name in _COL_NAMES:
        return COL
    if name in _ROW_NAMES:
        return ROW
    # unknown 2D+ leaf: replicate (safe default)
    return (None,) * min(ndim, 2)


def param_specs(params, multi_pod: bool = False, policy: str = "fsdp"):
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs).

    policy:
      fsdp    : weights sharded over BOTH data (ZeRO-3) and model (TP) —
                minimal memory, per-layer all-gathers (the default).
      tp_only : weights sharded over model only, replicated over data —
                no weight gathers; right for serving and for models whose
                params fit replicated (hillclimb lever, EXPERIMENTS §Perf).
    """
    assert policy in ("fsdp", "tp_only"), policy

    def one(path, leaf):
        names = _path_names(path)
        ndim = len(leaf.shape)
        trailing = _trailing_spec(names, ndim)
        if policy == "tp_only":
            trailing = tuple(None if ax == "data" else ax for ax in trailing)
        lead = (None,) * (ndim - len(trailing))
        return P(*(lead + tuple(trailing)))

    return jax.tree_util.tree_map_with_path(one, params)


def model_axis_dim(path, ndim: int):
    """Dimension index a leaf shards over the "model"/tensor mesh axis,
    under the same role rules as ``param_specs`` — the bridge the hybrid
    mesh planner (``repro.parallel``) uses to turn these PartitionSpecs
    into explicit per-leaf tensor-axis shards.  Returns None for leaves
    the role table replicates (biases, vectors, unknown 2D+ leaves).

    ``path`` is a ``tree_flatten_with_path`` key path; ``ndim`` the leaf's
    rank *excluding* any leading stacked-stage dimension (pass
    ``leaf.ndim - 1`` for stage-stacked leaves and add 1 to the result)."""
    names = _path_names(path)
    trailing = _trailing_spec(names, ndim)
    lead = ndim - len(trailing)
    for i, ax in enumerate(trailing):
        if ax == "model":
            return lead + i
    return None


# ------------------------------------------------------- attention hints
# Decode-attention guidance: with few KV heads (GQA), GSPMD's default is to
# all-gather each layer's hd-sharded KV cache (GBs/token).  Constraining
# the scores to be model-replicated and the attention output to stay
# hd-sharded flips the program to partial-score + all-reduce (MBs/token).
_ATTN_HINTS: dict = {"enabled": False, "data": ("data",), "mode": "hd"}


def set_attn_decode_hints(enabled: bool, multi_pod: bool = False,
                          mode: str = "hd"):
    """mode 'hd': cache sharded on head_dim; partial scores + all-reduce.
    mode 'seq': cache sharded on sequence (flash-decoding); local scores
    and softmax-combine / output partial-sums are the only collectives."""
    _ATTN_HINTS["enabled"] = enabled
    _ATTN_HINTS["data"] = data_axes(multi_pod)
    _ATTN_HINTS["mode"] = mode


def attn_decode_constraint(x, kind: str, shard_batch: bool = True):
    if not _ATTN_HINTS["enabled"]:
        return x
    from jax.lax import with_sharding_constraint as wsc
    b = _ATTN_HINTS["data"] if shard_batch else None
    seq = _ATTN_HINTS["mode"] == "seq"
    try:
        if kind == "scores":        # [B, H, q, L] — replicated over model
            return wsc(x, P(b, None, None, None))
        if kind == "out":           # [B, q, H, hd] — keep hd on model
            return wsc(x, P(b, None, None, "model"))
        if kind == "scores5d":      # [B, KV, G, q, L]
            return wsc(x, P(b, None, None, None, "model") if seq
                       else P(b, None, None, None, None))
        if kind == "out5d":         # [B, q, KV, G, hd]
            return wsc(x, P(b, None, None, None, None) if seq
                       else P(b, None, None, None, "model"))
        if kind == "q5d":           # [B, q, KV, G, hd] — reshard q (tiny!)
            # hd mode: q to hd-on-model so the score contraction is local
            # to each cache shard (partial scores + AR, never a cache AG).
            # seq mode: q replicated over model.
            return wsc(x, P(b, None, None, None, None) if seq
                       else P(b, None, None, None, "model"))
        if kind == "cache4d":       # [B, L, KV, hd] — pin storage layout
            return wsc(x, P(b, "model", None, None) if seq
                       else P(b, None, None, "model"))
    except Exception:
        return x
    return x


# ---------------------------------------------------------------- MoE hints
# When set (see set_moe_sharding_hints), repro.models.moe applies explicit
# with_sharding_constraint on the dispatch buffers so GSPMD lowers the
# token shuffle to all-to-all instead of gather-via-all-gather — the
# expert-parallel pattern the survey's hybrid-parallelism section is about.
_MOE_HINTS: dict = {"enabled": False, "data": ("data",), "model": "model",
                    "mode": "full"}


def set_moe_sharding_hints(enabled: bool, multi_pod: bool = False,
                           mode: str = "full"):
    """mode 'full': constrain tokens + expert buffers.
    mode 'expert_only': constrain only the expert-sharded buffer."""
    _MOE_HINTS["enabled"] = enabled
    _MOE_HINTS["data"] = data_axes(multi_pod)
    _MOE_HINTS["mode"] = mode


def moe_constraint(x, kind: str):
    """kind: 'tokens' [T, d] or 'experts' [E, C, d]."""
    if not _MOE_HINTS["enabled"]:
        return x
    from jax.lax import with_sharding_constraint as wsc
    try:
        if kind == "tokens" and _MOE_HINTS["mode"] == "full":
            return wsc(x, P(_MOE_HINTS["data"], None))
        if kind == "experts":
            return wsc(x, P(_MOE_HINTS["model"], None, None))
    except Exception:   # no mesh in context: constraint is a no-op request
        return x
    return x


def data_axes(multi_pod: bool = False):
    """Mesh axes that shard the batch dimension."""
    return ("pod", "data") if multi_pod else ("data",)


def batch_spec(ndim: int, multi_pod: bool = False, shard_batch: bool = True):
    """Spec for an input whose dim 0 is batch."""
    b = data_axes(multi_pod) if shard_batch else None
    return P(b, *([None] * (ndim - 1)))
