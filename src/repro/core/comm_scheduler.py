"""Communication scheduling (survey §3.3.3(3)): TicTac [60] / Bösen [187]
style transfer ordering + bucketing, as an analytic timeline model.

The survey's observation: frameworks transmit parameters in arbitrary order,
creating high iteration-time variance; ordering transfers by when the
consumer needs them (TicTac) or by significance (Bösen) removes the stalls.

On a TPU pod the "network" is the ICI and the "schedule" is where XLA
places all-reduces relative to the backward computation.  This module
models that placement: given per-layer backward compute times and gradient
sizes, it computes iteration time under (a) no overlap (all comm at the
end), (b) random bucket order, (c) reverse-layer priority order (TicTac),
and the classic bucketing trade-off (latency alpha vs bandwidth beta).
The projected timings feed benchmarks/comm_schedule_bench.py; the dominant
`collective` roofline term of the dry-run is the same quantity measured
from compiled HLO.

These are the *primitives*.  The executable surface engines consume is
``repro.comm.plan.CommPlan``, which owns the bucket fusion + issue order
built from this module and binds them to a topology schedule and wire
codec — the executed exchange and this timeline model read the same
bucket list, so they cannot drift apart (docs/comm.md).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LinkModel:
    alpha_s: float = 5e-6        # per-message latency (s)
    beta_Bps: float = 50e9       # link bandwidth (ICI ~50 GB/s)

    def time(self, nbytes: float) -> float:
        return self.alpha_s + nbytes / self.beta_Bps


@dataclasses.dataclass(frozen=True)
class LayerCost:
    name: str
    back_compute_s: float        # backward compute time producing this grad
    grad_bytes: float


def schedule_no_overlap(layers: Sequence[LayerCost], link: LinkModel) -> float:
    compute = sum(l.back_compute_s for l in layers)
    comm = sum(link.time(l.grad_bytes) for l in layers)
    return compute + comm


def schedule_overlap(layers: Sequence[LayerCost], link: LinkModel,
                     order: Sequence[int]) -> float:
    """Backward runs layer L-1 .. 0; gradient i becomes available when its
    layer's backward finishes.  Transfers run on one link in `order`
    (indices into layers), each starting when both the link is free and the
    gradient is ready.  Returns iteration time (last transfer completion)."""
    L = len(layers)
    avail = {}
    t = 0.0
    for i in reversed(range(L)):         # backward pass order
        t += layers[i].back_compute_s
        avail[i] = t
    link_free = 0.0
    done = 0.0
    for i in order:
        start = max(link_free, avail[i])
        link_free = start + link.time(layers[i].grad_bytes)
        done = max(done, link_free)
    return done


def bucketize(layers: Sequence[LayerCost], bucket_bytes: float
              ) -> List[LayerCost]:
    """Fuse consecutive (in backward order) gradients into buckets — the
    latency-vs-overlap trade-off every data-parallel framework tunes."""
    out: List[LayerCost] = []
    cur_names, cur_comp, cur_bytes = [], 0.0, 0.0
    for l in reversed(list(layers)):     # backward order
        cur_names.append(l.name)
        cur_comp += l.back_compute_s
        cur_bytes += l.grad_bytes
        if cur_bytes >= bucket_bytes:
            out.append(LayerCost("+".join(cur_names), cur_comp, cur_bytes))
            cur_names, cur_comp, cur_bytes = [], 0.0, 0.0
    if cur_names:
        out.append(LayerCost("+".join(cur_names), cur_comp, cur_bytes))
    return list(reversed(out))           # back to forward order


def tictac_order(layers: Sequence[LayerCost]) -> List[int]:
    """Transfer earliest-ready gradients first (reverse layer order) — the
    TicTac-optimal order for a chain model."""
    return list(reversed(range(len(layers))))


def random_order(layers: Sequence[LayerCost], seed: int = 0) -> List[int]:
    import random
    idx = list(range(len(layers)))
    random.Random(seed).shuffle(idx)
    return idx
