"""Mixed-precision policy (survey §3.3.3(1), Gupta et al. [55]).

params_dtype: storage; compute_dtype: matmul/activations; reduce_dtype:
gradients on the wire (the precision-reduction knob the survey discusses
for communication).  Stochastic rounding (Gupta et al.'s key finding) is
provided for low-precision parameter updates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    params_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    reduce_dtype: str = "float32"

    @property
    def pdt(self):
        return jnp.dtype(self.params_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def rdt(self):
        return jnp.dtype(self.reduce_dtype)

    def cast_for_compute(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.cdt)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def cast_for_reduce(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.rdt)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def stochastic_round(x, target_dtype, key):
    """Unbiased rounding to a lower-precision float (Gupta et al. [55]).

    Nudges the nearest-rounded value one target-dtype ulp toward x with
    probability |x - round(x)| / ulp, so E[out] == x."""
    x = x.astype(jnp.float32)
    lo32 = x.astype(target_dtype).astype(jnp.float32)
    f = jnp.finfo(target_dtype)
    # ulp of the target dtype at lo32's binade
    step = (2.0 ** jnp.floor(jnp.log2(jnp.maximum(jnp.abs(lo32),
                                                  float(f.tiny))))
            * float(f.eps))
    delta = x - lo32
    frac = jnp.clip(jnp.abs(delta) / step, 0.0, 1.0)
    u = jax.random.uniform(key, x.shape)
    out = jnp.where(u < frac, lo32 + jnp.sign(delta) * step, lo32)
    return out.astype(target_dtype)


DEFAULT = PrecisionPolicy()
FP32 = PrecisionPolicy("float32", "float32", "float32")
BF16_COMPUTE = PrecisionPolicy("float32", "bfloat16", "float32")
BF16_REDUCE = PrecisionPolicy("float32", "bfloat16", "bfloat16")
BF16_EVERYTHING = PrecisionPolicy("bfloat16", "bfloat16", "bfloat16")

# Strategy-level precision names (the mesh-suffix tokens): master weights
# stay fp32 in every named policy — "bf16" is cast-for-compute with fp32
# updates, "bf16r" additionally reduces gradients in bf16 on the wire.
POLICIES = {"fp32": FP32, "bf16": BF16_COMPUTE, "bf16r": BF16_REDUCE}


def policy_for(name: str) -> PrecisionPolicy:
    """Resolve a Strategy/mesh-suffix precision name to its policy."""
    try:
        return POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown precision {name!r} (want one of {sorted(POLICIES)})")
