"""Gradient-compression strategies from survey §3.3.3 / Table 2, unified
behind one pytree-level interface with error-feedback state.

Methods (each backed by a Pallas kernel package in ``repro.kernels``; the
``backend`` field selects the implementation through the kernel backend
seam — ``kernel`` runs the fused Pallas pass, ``ref`` the jnp oracle,
``auto`` resolves per host (see ``repro.kernels.backend``).  The two are
bit-identical — asserted by tests):

  none      : fp32 gradients as-is (the survey's baseline)
  onebit    : 1-bit SGD + error feedback        [Seide et al., 159]
  terngrad  : stochastic ternary                [Wen et al., 190]
  qsgd      : s-level stochastic quantization   [Alistarh et al., 8]
  dgc       : threshold sparsify + error accum  [Lin et al., 106]

Error-feedback fidelity notes (the convergence bugfix):

  * Seide et al. reconstruct each quantization bin by the *mean of the
    values that fell into it* — two scales per row (one for the positive
    bin, one for the negative), not one symmetric ``sign * mean|c|`` scale.
    The original implementation here used the symmetric single scale, which
    systematically underestimates asymmetric rows and injects noise into
    silent ones.  ``_two_bin_recon`` restores the paper's reconstruction.
  * Reconstruction rows follow the tensor's trailing channel axis (an
    embedding row, an attention projection column block) instead of an
    arbitrary flat 256-lane reshape, so a channel that produced no gradient
    (an unseen vocabulary row) reconstructs to exactly zero rather than
    receiving +/- scale noise from unrelated channels.  Small leaves where
    per-channel side info would not pay for itself fall back to the flat
    256-lane layout.
  * The residual is repaid with over-relaxation ``ef_gain`` (compress
    ``g + ef_gain * e`` instead of ``g + e``): the compressor prioritises
    old debt, which cuts the steady-state EF lag that stalled early-step
    convergence.  The telescoping invariant (sum sent + residual == sum of
    raw gradients) holds for any gain because the new residual is always
    measured against the true compensated gradient ``g + e``.
  * DGC's sparsity threshold is the quantile of the *unpadded* compensated
    gradient — the previous padded quantile was diluted by pad zeros — and
    the untransmitted remainder additionally travels as a 1-bit plane
    (sparse top-k + 1-bit residual hybrid), still a fraction of qsgd's
    8-bit wire cost.

All reconstruction improvements are computed from the compensated gradient
*outside* the Pallas kernels, identically on the kernel and oracle paths,
so kernel-vs-ref bit-identity is preserved.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import onebit as K1
from repro.kernels import qsgd as KQ
from repro.kernels import terngrad as KT
from repro.kernels import topk as KK
from repro.kernels.backend import kernel_interpret, resolve_backend

_LANE = 256
# Default minimum trailing-axis length for per-channel two-bin
# reconstruction (the ``Compressor.min_channel`` kwarg): with shorter
# channels the 8 B/row of bin means would rival the 1-bit plane itself and
# break the onebit < terngrad wire ordering.
_MIN_CHANNEL = 64


def _to2d(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _LANE
    return jnp.pad(flat, (0, pad)).reshape(-1, _LANE), n


def _from2d(x2d, n, shape):
    return x2d.reshape(-1)[:n].reshape(shape)


def _channel_axis(shape, min_channel: int = _MIN_CHANNEL) -> int:
    """Trailing channel length used for per-channel reconstruction, or 0
    when the leaf is too small / scalar and should use the flat layout."""
    if len(shape) == 0:
        return 0
    b = shape[-1] if len(shape) > 1 else shape[0]
    return b if b >= min_channel else 0


def _two_bin_recon(signs, c, valid=None):
    """Seide-style reconstruction: each sign bin decodes to the mean of the
    compensated values in that bin (per row).  ``signs`` is the transmitted
    int8 plane; ``c`` is the row-major compensated gradient the *sender*
    used — the bin means are the 8 B/row side information on the wire.
    ``valid`` masks elements out of the bin statistics (e.g. slots already
    sent exactly by a sparse pass, which would otherwise dilute the
    means with zeros)."""
    pos = signs > 0
    neg = ~pos
    if valid is not None:
        pos = pos & valid
        neg = neg & valid
    npos = jnp.maximum(jnp.sum(pos, axis=-1, keepdims=True), 1)
    nneg = jnp.maximum(jnp.sum(neg, axis=-1, keepdims=True), 1)
    sp = jnp.sum(jnp.where(pos, c, 0.0), axis=-1, keepdims=True) / npos
    sn = jnp.sum(jnp.where(neg, -c, 0.0), axis=-1, keepdims=True) / nneg
    return jnp.where(signs > 0, sp, -sn)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Stateless descriptor; EF state travels explicitly through the step.

    Convergence/fidelity knobs (see the module docstring for the math):

    ``ef_gain``      onebit EF over-relaxation — compress ``g + ef_gain*e``
                     so old residual debt is repaid first.  ``1.0`` is the
                     textbook Seide EF; the ``2.0`` default cuts the
                     steady-state EF lag on transformer training.  The
                     telescoping invariant holds for any gain.
    ``min_channel``  minimum trailing-axis length before onebit/dgc switch
                     from the flat 256-lane layout to per-channel two-bin
                     reconstruction.  Lower it to force channelwise recon
                     on narrow layers (more side-info bytes on the wire);
                     raise it to force the flat layout."""
    method: str = "none"
    density: float = 0.01        # dgc
    s_levels: int = 127          # qsgd
    clip_sigma: float = 2.5      # terngrad
    backend: str = "auto"        # kernel backend seam: auto | kernel | ref
    ef_gain: float = 2.0         # onebit EF over-relaxation (see above)
    min_channel: int = _MIN_CHANNEL   # channelwise-recon threshold (above)

    # ---------------------------------------------------------------- state
    def init_state(self, grads) -> Any:
        if self.method in EF_METHODS:
            return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
        return None

    @property
    def needs_rng(self) -> bool:
        return self.method in ("terngrad", "qsgd")

    # ------------------------------------------------------------- roundtrip
    def roundtrip(self, grads, state, rng=None) -> Tuple[Any, Any, int]:
        """Compress+decompress each leaf (what a worker transmits vs keeps).

        Returns (decompressed_grads, new_state, wire_bytes_total)."""
        if self.method == "none":
            bytes_total = sum(int(g.size) * 4
                              for g in jax.tree.leaves(grads))
            return grads, state, bytes_total

        leaves, treedef = jax.tree.flatten(grads)
        st_leaves = (treedef.flatten_up_to(state)
                     if state is not None else [None] * len(leaves))
        rngs = (list(jax.random.split(rng, len(leaves)))
                if rng is not None else [None] * len(leaves))

        outs, new_sts, wire = [], [], 0
        for g, e, r in zip(leaves, st_leaves, rngs):
            o, ns, wb = self._leaf(g, e, r)
            outs.append(o.astype(g.dtype))
            new_sts.append(ns)
            wire += wb
        new_state = (jax.tree.unflatten(treedef, new_sts)
                     if state is not None else None)
        return jax.tree.unflatten(treedef, outs), new_state, wire

    # ------------------------------------------------------ onebit internals
    def _onebit_plane(self, m, valid=None):
        """1-bit compress a row-major [R, C] block: transmitted signs plus
        the two-bin reconstruction (masked to ``valid``).  Returns
        (recon [R, C], wire_bytes).  One fused encode+EF kernel pass on
        the kernel backend."""
        valid_arr = None if valid is None else valid
        _, _, _, out, _ = K1.encode_ef(m, None, valid_arr,
                                       backend=self.backend)
        wb = -(-int(m.size) // 8) + 8 * int(m.shape[0])
        return out, wb

    def _leaf_onebit(self, g, e):
        """One fused pass per leaf: the encode+EF kernel reads (g, e)
        once and emits the sign plane, the bin means, the reconstruction,
        and the next residual (``c_in = g + ef_gain*e`` with the residual
        measured against ``c_true = g + e`` — the over-relaxation
        telescoping from the module docstring, now inside the kernel)."""
        shape = g.shape
        chan = _channel_axis(shape, self.min_channel)
        if chan:
            g2 = g.astype(jnp.float32).reshape(-1, chan)
            e2 = e.astype(jnp.float32).reshape(-1, chan)
            _, _, _, out, new_e = K1.encode_ef(g2, e2, gain=self.ef_gain,
                                               backend=self.backend)
            wb = -(-int(g.size) // 8) + 8 * int(g2.shape[0])
            return out.reshape(shape), new_e.reshape(shape), wb
        g2, n = _to2d(g)
        e2, _ = _to2d(e)
        # the flat fallback keeps the seed's symmetric sign*mean|c| plane
        _, _, _, out, new_e = K1.encode_ef(g2, e2, gain=self.ef_gain,
                                           symmetric=True,
                                           backend=self.backend)
        return (_from2d(out, n, shape), _from2d(new_e, n, shape),
                K1.wire_bytes(n))

    def _leaf_dgc(self, g, e):
        shape = g.shape
        ctrue = g.astype(jnp.float32) + e.astype(jnp.float32)
        g2, n = _to2d(g)
        e2, _ = _to2d(e)
        # quantile of the unpadded compensated gradient (pad zeros diluted
        # it) — kernels/topk owns the selection rule
        th = KK.threshold_for_density(g, e, self.density)
        kept2, _ = KK.sparsify(g2, e2, th, backend=self.backend)
        kept = _from2d(kept2, n, shape)
        wb = KK.wire_bytes(n, self.density)
        chan = _channel_axis(shape, self.min_channel)
        if chan:
            rem = (ctrue - kept).reshape(-1, chan)
            # kept slots were sent exactly by the sparse pass: the receiver
            # knows their indices, so they decode to 0 here and are masked
            # out of the bin means (they would dilute them with zeros)
            unsent = kept.reshape(-1, chan) == 0.0
            remq, wb1 = self._onebit_plane(rem, valid=unsent)
            remq = jnp.where(unsent, remq, 0.0)
            out = kept + remq.reshape(shape)
            wb += wb1
        else:
            out = kept
        new_e = ctrue - out
        return out, new_e, wb

    # ----------------------------------------------------------------- leaf
    def _leaf(self, g, e, r):
        if self.method == "onebit":
            return self._leaf_onebit(g, e)
        if self.method == "dgc":
            return self._leaf_dgc(g, e)
        g2, n = _to2d(g)
        shape = g.shape
        if self.method == "terngrad":
            u = jax.random.uniform(r, g2.shape)
            if resolve_backend(self.backend) == "kernel":
                t, s = KT.compress(g2, u, clip_sigma=self.clip_sigma,
                                   interpret=kernel_interpret())
            else:
                t, s = KT.terngrad_ref(g2, u, self.clip_sigma)
            out = KT.decompress(t, s)
            return _from2d(out, n, shape), None, KT.wire_bytes(n)
        if self.method == "qsgd":
            u = jax.random.uniform(r, g2.shape)
            q, nm = KQ.quantize(g2, u, s_levels=self.s_levels,
                                backend=self.backend)
            out = KQ.decompress(q, nm, s_levels=self.s_levels)
            return _from2d(out, n, shape), None, KQ.wire_bytes(n)
        raise ValueError(self.method)


METHODS = ("none", "onebit", "terngrad", "qsgd", "dgc")
# methods that carry per-worker error-feedback state through the step —
# the single definition every EF-state check in the repo keys off
EF_METHODS = ("onebit", "dgc")
