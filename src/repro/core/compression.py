"""Gradient-compression strategies from survey §3.3.3 / Table 2, unified
behind one pytree-level interface with error-feedback state.

Methods (each backed by a Pallas kernel package in ``repro.kernels`` whose
jnp oracle is the math used here; ``use_kernel=True`` routes through the
kernel, which is bit-identical — asserted by tests):

  none      : fp32 gradients as-is (the survey's baseline)
  onebit    : 1-bit SGD + error feedback        [Seide et al., 159]
  terngrad  : stochastic ternary                [Wen et al., 190]
  qsgd      : s-level stochastic quantization   [Alistarh et al., 8]
  dgc       : threshold sparsify + error accum  [Lin et al., 106]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import onebit as K1
from repro.kernels import qsgd as KQ
from repro.kernels import terngrad as KT
from repro.kernels import topk as KK

_LANE = 256


def _to2d(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _LANE
    return jnp.pad(flat, (0, pad)).reshape(-1, _LANE), n


def _from2d(x2d, n, shape):
    return x2d.reshape(-1)[:n].reshape(shape)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Stateless descriptor; EF state travels explicitly through the step."""
    method: str = "none"
    density: float = 0.01        # dgc
    s_levels: int = 127          # qsgd
    clip_sigma: float = 2.5      # terngrad
    use_kernel: bool = False     # route through the Pallas kernel (interpret)

    # ---------------------------------------------------------------- state
    def init_state(self, grads) -> Any:
        if self.method in ("onebit", "dgc"):
            return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
        return None

    @property
    def needs_rng(self) -> bool:
        return self.method in ("terngrad", "qsgd")

    # ------------------------------------------------------------- roundtrip
    def roundtrip(self, grads, state, rng=None) -> Tuple[Any, Any, int]:
        """Compress+decompress each leaf (what a worker transmits vs keeps).

        Returns (decompressed_grads, new_state, wire_bytes_total)."""
        if self.method == "none":
            bytes_total = sum(int(g.size) * 4
                              for g in jax.tree.leaves(grads))
            return grads, state, bytes_total

        leaves, treedef = jax.tree.flatten(grads)
        st_leaves = (treedef.flatten_up_to(state)
                     if state is not None else [None] * len(leaves))
        rngs = (list(jax.random.split(rng, len(leaves)))
                if rng is not None else [None] * len(leaves))

        outs, new_sts, wire = [], [], 0
        for g, e, r in zip(leaves, st_leaves, rngs):
            o, ns, wb = self._leaf(g, e, r)
            outs.append(o.astype(g.dtype))
            new_sts.append(ns)
            wire += wb
        new_state = (jax.tree.unflatten(treedef, new_sts)
                     if state is not None else None)
        return jax.tree.unflatten(treedef, outs), new_state, wire

    # ----------------------------------------------------------------- leaf
    def _leaf(self, g, e, r):
        g2, n = _to2d(g)
        shape = g.shape
        if self.method == "onebit":
            e2, _ = _to2d(e)
            if self.use_kernel:
                signs, scale, ne = K1.compress(g2, e2)
            else:
                signs, scale, ne = K1.onebit_ref(g2, e2)
            out = K1.decompress(signs, scale)
            return (_from2d(out, n, shape), _from2d(ne, n, shape),
                    K1.wire_bytes(n))
        if self.method == "terngrad":
            u = jax.random.uniform(r, g2.shape)
            if self.use_kernel:
                t, s = KT.compress(g2, u, clip_sigma=self.clip_sigma)
            else:
                t, s = KT.terngrad_ref(g2, u, self.clip_sigma)
            out = KT.decompress(t, s)
            return _from2d(out, n, shape), None, KT.wire_bytes(n)
        if self.method == "qsgd":
            u = jax.random.uniform(r, g2.shape)
            if self.use_kernel:
                q, nm = KQ.compress(g2, u, s_levels=self.s_levels)
            else:
                q, nm = KQ.qsgd_ref(g2, u, self.s_levels)
            out = KQ.decompress(q, nm, s_levels=self.s_levels)
            return _from2d(out, n, shape), None, KQ.wire_bytes(n)
        if self.method == "dgc":
            e2, _ = _to2d(e)
            th = KK.threshold_for_density(g2, e2, self.density)
            if self.use_kernel:
                out, ne = KK.compress(g2, e2, th)
            else:
                out, ne = KK.topk_ref(g2, e2, th)
            return (_from2d(out, n, shape), _from2d(ne, n, shape),
                    KK.wire_bytes(n, self.density))
        raise ValueError(self.method)


METHODS = ("none", "onebit", "terngrad", "qsgd", "dgc")
