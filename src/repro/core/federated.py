"""Federated learning (survey §3.3.1(3)): FedAvg [McMahan et al., 114] with
client sampling, local epochs, and IID vs non-IID data (Dirichlet
partitioning lives in repro.data.partition).

Per the survey's framing, federated rounds are the centralized architecture
with (a) partial participation, (b) multiple local steps between
synchronizations, and (c) weighted averaging by client example counts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FedConfig:
    num_clients: int = 10
    clients_per_round: int = 5
    local_steps: int = 4
    local_lr: float = 0.1
    seed: int = 0


def fedavg_round(params, client_batches: Sequence[Callable[[int], Any]],
                 selected: Sequence[int], grad_fn: Callable,
                 cfg: FedConfig):
    """One synchronous federated round (Bonawitz et al. [19] system model).

    client_batches[c](step) -> batch for client c.
    Returns (new_params, mean_client_loss)."""

    @jax.jit
    def local_sgd(p, batches_stacked):
        def step(pp, batch):
            loss, g = grad_fn(pp, batch)
            pp = jax.tree.map(lambda a, b: a - cfg.local_lr * b, pp, g)
            return pp, loss
        p_new, losses = jax.lax.scan(step, p, batches_stacked)
        return p_new, losses.mean()

    deltas, losses, weights = [], [], []
    for c in selected:
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[client_batches[c](s) for s in range(cfg.local_steps)])
        p_c, loss_c = local_sgd(params, batches)
        deltas.append(jax.tree.map(lambda a, b: a - b, p_c, params))
        losses.append(float(loss_c))
        weights.append(1.0)

    wsum = sum(weights)
    avg_delta = jax.tree.map(
        lambda *ds: sum(w * d for w, d in zip(weights, ds)) / wsum, *deltas)
    new_params = jax.tree.map(lambda p, d: p + d, params, avg_delta)
    return new_params, float(np.mean(losses))


def run_fedavg(params, client_batches, grad_fn, cfg: FedConfig,
               rounds: int):
    rng = np.random.RandomState(cfg.seed)
    hist = []
    for r in range(rounds):
        selected = rng.choice(cfg.num_clients, cfg.clients_per_round,
                              replace=False)
        params, loss = fedavg_round(params, client_batches, selected,
                                    grad_fn, cfg)
        hist.append(dict(round=r, loss=loss))
    return params, hist
