from repro.serve.serve_loop import generate, greedy_sample

__all__ = ["generate", "greedy_sample"]
