from repro.serve.serve_loop import generate, greedy_sample

__all__ = [
    "generate", "greedy_sample",
    # serving plane (imported lazily by callers to keep the compat path
    # light): engine.ServeEngine/ServeConfig, request.Request/SamplingParams,
    # cache.make_kv_store, batcher.Batcher, autoscale.Autoscaler
]
