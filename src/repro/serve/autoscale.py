"""Sched-driven autoscaling for the serving plane.

A serving deployment is treated as one more **tenant of the cluster
scheduler**: the autoscaler watches the open-loop arrival trace, estimates
the request rate over a sliding window, converts it into a desired replica
count, and emits the scale decisions as the *same* ``TraceEvent`` stream
the ``sched/`` simulator produces for training jobs — a suspend/resume
pair at a new GPU count.  ``repro.elastic.events.plan_from_sched_trace``
then turns that stream into an elastic ``EventPlan`` (resumes at a new
size become ``resize`` events), closing the loop

    arrival trace -> rate estimate -> replicas -> TraceEvents -> EventPlan

so serving replicas ride exactly the scheduler->trainer plumbing PR 3/5
built for elastic training.  ``serve_job`` exposes the deployment as a
``sched.jobs.Job`` so it can be co-scheduled against training tenants in
``sched.simulator.simulate``; ``simulate_queue`` replays the arrival trace
against a replica schedule to compare queueing delay (the p99-wait payoff
of scaling up under load).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.elastic.events import EventPlan, plan_from_sched_trace
from repro.obs.trace import emit_sched_trace, get_recorder
from repro.sched.jobs import Job
from repro.sched.simulator import TraceEvent


def poisson_trace(rate: float, horizon: float, seed: int = 0,
                  max_requests: Optional[int] = None) -> List[float]:
    """Open-loop Poisson arrivals: exponential inter-arrival times at
    ``rate`` req/s over ``horizon`` seconds (the serving benchmark's load
    generator — arrivals do NOT wait for completions)."""
    rng = np.random.RandomState(seed)
    out: List[float] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= horizon or (max_requests and len(out) >= max_requests):
            return out
        out.append(t)


class RateEstimator:
    """Sliding-window arrival-rate estimate (req/s over the last
    ``window`` seconds), the autoscaler's only load signal."""

    def __init__(self, window: float = 10.0):
        self.window = window
        self._arrivals: List[float] = []

    def observe(self, t: float) -> None:
        self._arrivals.append(t)

    def rate(self, now: float) -> float:
        lo = now - self.window
        n = sum(1 for t in self._arrivals if lo < t <= now)
        return n / min(self.window, now) if now > 0 else 0.0


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """``replica_rate``: req/s one replica sustains (measured, e.g. from a
    serve_bench row).  ``scale_down_patience``: consecutive intervals the
    desired count must stay below current before shrinking (hysteresis —
    scaling down evicts batch slots, so it should lag the signal)."""
    replica_rate: float = 1.0
    min_replicas: int = 1
    max_replicas: int = 8
    interval: float = 5.0          # seconds between decisions
    scale_down_patience: int = 2

    def desired(self, rate: float) -> int:
        want = math.ceil(rate / self.replica_rate) if rate > 0 else 0
        return max(self.min_replicas, min(self.max_replicas, want))


@dataclasses.dataclass
class ScaleDecision:
    t: float
    rate: float
    replicas: int


class Autoscaler:
    """Replays an arrival trace through the rate estimator and policy,
    producing the replica schedule and its sched-plane TraceEvents."""

    def __init__(self, policy: AutoscalePolicy, jid: int = 0,
                 window: float = 10.0):
        self.policy = policy
        self.jid = jid
        self.estimator = RateEstimator(window)

    def schedule(self, arrivals: Sequence[float], horizon: float,
                 burn_times: Optional[Sequence[float]] = None
                 ) -> List[ScaleDecision]:
        """``burn_times`` (optional) are SLO alert instants from a
        monitored serve engine (``ServeEngine.slo_alerts``): a decision
        interval containing a burn forces at least a one-replica
        scale-up and resets the scale-down hysteresis — a burning SLO
        outranks the arrival-rate signal (obs/slo.py)."""
        pol = self.policy
        arrivals = sorted(arrivals)
        burns = sorted(burn_times) if burn_times else []
        decisions: List[ScaleDecision] = []
        cur = pol.min_replicas
        below = 0
        i = 0
        steps = int(math.ceil(horizon / pol.interval))
        decisions.append(ScaleDecision(0.0, 0.0, cur))
        for k in range(1, steps + 1):
            now = k * pol.interval
            while i < len(arrivals) and arrivals[i] <= now:
                self.estimator.observe(arrivals[i])
                i += 1
            rate = self.estimator.rate(now)
            want = pol.desired(rate)
            burning = any(now - pol.interval < b <= now for b in burns)
            if burning:
                want = max(want, min(pol.max_replicas, cur + 1))
            if want > cur:
                cur, below = want, 0          # scale up immediately
            elif want < cur and not burning:
                below += 1                    # hysteresis on the way down
                if below >= pol.scale_down_patience:
                    cur, below = want, 0
            else:
                below = 0
            if cur != decisions[-1].replicas:
                rec = get_recorder()
                if rec.enabled:
                    extra = {"reason": "slo_burn"} if burning else {}
                    rec.instant("autoscale_decision", pid="serve",
                                tid="autoscale", cat="serve",
                                clock=("sched_time", now), jid=self.jid,
                                rate=round(rate, 6),
                                from_replicas=decisions[-1].replicas,
                                to_replicas=cur, **extra)
                decisions.append(ScaleDecision(now, rate, cur))
        return decisions

    def to_trace(self, decisions: Sequence[ScaleDecision]) -> List[TraceEvent]:
        """Scale decisions as the sched simulator's allocation stream: a
        start at the initial size, then a suspend/resume pair per change
        (resume at a new GPU count == elastic resize downstream)."""
        if not decisions:
            return []
        ev = [TraceEvent(decisions[0].t, self.jid, "start",
                         decisions[0].replicas)]
        cur = decisions[0].replicas
        for d in decisions[1:]:
            ev.append(TraceEvent(d.t, self.jid, "suspend", cur))
            ev.append(TraceEvent(d.t, self.jid, "resume", d.replicas))
            cur = d.replicas
        return ev

    def plan(self, arrivals: Sequence[float], horizon: float,
             steps_per_sec: float = 1.0,
             burn_times: Optional[Sequence[float]] = None
             ) -> Tuple[EventPlan, List[ScaleDecision]]:
        """arrival trace -> elastic EventPlan (resize events on the
        deployment's own step clock), via the shared sched plumbing."""
        decisions = self.schedule(arrivals, horizon, burn_times=burn_times)
        trace = self.to_trace(decisions)
        # the deployment's allocation stream rides the shared sched
        # timeline, next to any co-scheduled training tenants
        emit_sched_trace(get_recorder(), trace, pid="sched")
        return (plan_from_sched_trace(trace, self.jid,
                                      steps_per_sec=steps_per_sec),
                decisions)


def replicas_at(decisions: Sequence[ScaleDecision], t: float) -> int:
    cur = decisions[0].replicas if decisions else 1
    for d in decisions:
        if d.t <= t:
            cur = d.replicas
        else:
            break
    return cur


def simulate_queue(arrivals: Sequence[float],
                   decisions: Sequence[ScaleDecision],
                   service_time: float,
                   horizon: float) -> dict:
    """Replay the arrival trace against a replica schedule: each replica
    serves one request per ``service_time`` seconds (single-slot fluid
    approximation).  Returns queueing-delay stats — the metric autoscaling
    is supposed to buy down versus a fixed fleet."""
    free_at: List[float] = []        # per-replica next-free times
    waits: List[float] = []
    for t in sorted(arrivals):
        n = replicas_at(decisions, t)
        while len(free_at) < n:
            free_at.append(t)
        busy = sorted(free_at[:n])
        start = max(t, busy[0])
        # assign to the earliest-free replica of the current fleet
        idx = free_at.index(busy[0])
        free_at[idx] = start + service_time
        waits.append(start - t)
    waits.sort()
    if not waits:
        return {"completed": 0, "p50_wait": 0.0, "p99_wait": 0.0,
                "max_wait": 0.0}
    q = lambda p: waits[min(len(waits) - 1,
                            int(round(p / 100 * (len(waits) - 1))))]
    return {"completed": len(waits), "p50_wait": q(50), "p99_wait": q(99),
            "max_wait": waits[-1]}


def serve_job(jid: int, horizon: float, replicas: int,
              arrival: float = 0.0) -> Job:
    """The deployment as a cluster-scheduler tenant: a long-running job
    holding ``replicas`` GPUs for ``horizon`` seconds, co-schedulable
    against training jobs in ``sched.simulator.simulate`` (its allocation
    trace feeds ``plan_from_sched_trace`` exactly like a training job's)."""
    return Job(jid=jid, arrival=arrival, num_gpus=replicas, epochs=1,
               epoch_time_1gpu=horizon * (replicas ** 0.9),
               scaling_alpha=0.9)
