"""Token sampling for the serving plane.

``greedy_sample`` is the deterministic argmax the seed server used (and
every equivalence test still uses).  ``sample_tokens`` adds temperature /
top-k sampling with an *explicit per-request PRNG key*: the engine derives
one key per request from ``SamplingParams.seed`` and folds the token index
in per step, so a request's sample stream is reproducible regardless of
which batch slot or iteration served it (continuous batching must not
change sampled outputs).

All knobs are traced per-slot arrays so the whole batch samples in the one
jitted decode step: a slot with ``temperature <= 0`` takes the argmax
branch bit-for-bit (greedy stays the default), ``top_k > 0`` restricts to
the k highest logits via a sorted threshold (k is traced, so mixed-k
batches share one compiled program).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def greedy_sample(logits, vocab_size: int):
    """argmax over the un-padded vocab.  logits [B, 1, Vpad]."""
    return jnp.argmax(logits[..., :vocab_size], axis=-1).astype(jnp.int32)


def request_key(seed: int):
    """The per-request PRNG key ``SamplingParams.seed`` names."""
    return jax.random.PRNGKey(seed)


def _sample_one(logits, key, temperature, top_k, vocab_size: int):
    """One row: logits [V] float32, traced temperature/top_k scalars."""
    scaled = logits / jnp.maximum(temperature, 1e-6)
    # top-k via sorted threshold: keep logits >= k-th largest (traced k)
    sorted_desc = jnp.sort(logits)[::-1]
    k = jnp.clip(top_k, 1, vocab_size)
    thresh = sorted_desc[k - 1]
    allow = jnp.where(top_k > 0, logits >= thresh, True)
    return jax.random.categorical(key, jnp.where(allow, scaled, NEG_INF))


def sample_tokens(logits, vocab_size: int, keys, temperature, top_k):
    """Batched per-slot sampling inside the jitted decode step.

    logits [B, Vpad]; keys [B, 2] uint32 (one PRNG key per slot);
    temperature [B] float32; top_k [B] int32.  Slots with
    ``temperature <= 0`` return the greedy argmax (exactly
    ``greedy_sample``); the rest draw from the temperature-scaled,
    top-k-filtered categorical with their own key.
    """
    lg = logits[..., :vocab_size].astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1)
    sampled = jax.vmap(_sample_one, in_axes=(0, 0, 0, 0, None))(
        lg, keys, temperature, top_k, vocab_size)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)


def fold_token(keys, step: int):
    """Advance every per-slot key to this token index (vmapped fold_in)."""
    return jax.vmap(lambda k: jax.random.fold_in(k, step))(keys)
