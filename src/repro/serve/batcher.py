"""Continuous-batching admission control (iteration-level scheduling,
arXiv 2209.01341 / vLLM-style).

The batcher owns the request queue and the batch-slot map; the engine asks
it once per iteration which QUEUED requests to admit.  Two policies:

``continuous``
    Admit whenever a batch slot *and* the cache reservation are available
    (``KVStore.try_reserve``) — finished requests free their slot and pages
    at the end of an iteration and new work joins the very next one.
    Admission is FIFO without head-of-line bypass: if the oldest queued
    request cannot reserve pages, the iteration records a **stall** and
    admits nothing behind it (deterministic, and over-subscribed pools
    degrade to queueing delay instead of OOM).

``oneshot``
    The static-batching baseline: requests are only admitted when the
    engine is completely idle (every slot free), then as many as fit.  The
    whole batch decodes to completion before the next wave — exactly the
    serving pattern the continuous policy is benchmarked against.
"""
from __future__ import annotations

from typing import List, Optional

from repro.serve.request import Request, RequestState

POLICIES = ("continuous", "oneshot")


class Batcher:
    def __init__(self, kv_store, slots: int, policy: str = "continuous"):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.kv = kv_store
        self.slots = slots
        self.policy = policy
        self.queue: List[Request] = []          # FIFO by submission order
        self.running: List[Optional[Request]] = [None] * slots
        self.stalls = 0          # iterations a reservable-slot head couldn't
                                 # get pages (pool pressure, not slot pressure)

    # ------------------------------------------------------------ queue
    def submit(self, request: Request) -> None:
        if request.state is not RequestState.QUEUED:
            raise ValueError(f"request {request.rid} already admitted")
        self.queue.append(request)

    @property
    def num_running(self) -> int:
        return sum(1 for r in self.running if r is not None)

    @property
    def idle(self) -> bool:
        return self.num_running == 0 and not self.queue

    def next_arrival(self) -> Optional[float]:
        return min((r.arrival for r in self.queue), default=None)

    def _free_slot(self) -> int:
        for i, r in enumerate(self.running):
            if r is None:
                return i
        return -1

    # -------------------------------------------------------- admission
    def admit(self, now: float) -> List[Request]:
        """Pick the QUEUED requests (arrived by ``now``) that join the
        batch this iteration; reserves their slot and cache pages."""
        if self.policy == "oneshot" and self.num_running > 0:
            return []
        admitted: List[Request] = []
        while self.queue and self.queue[0].arrival <= now:
            slot = self._free_slot()
            if slot < 0:
                break
            head = self.queue[0]
            if not self.kv.try_reserve(head):
                # FIFO head can't get pages: stall rather than bypass
                if head.total_len > self.kv.max_len:
                    raise ValueError(
                        f"request {head.rid} needs {head.total_len} tokens "
                        f"> max_len {self.kv.max_len}: can never be served")
                self.stalls += 1
                break
            self.queue.pop(0)
            head.state = RequestState.PREFILL
            head.slot = slot
            head.admit_time = now
            self.running[slot] = head
            if hasattr(self.kv, "set_block_table"):
                self.kv.set_block_table(slot, head.pages)
            admitted.append(head)
        return admitted

    def release(self, request: Request) -> None:
        """Return a DONE request's slot and pages to the pool (continuous
        policy re-admits into them on the very next iteration)."""
        if request.state is not RequestState.DONE:
            raise ValueError(f"request {request.rid} not done")
        slot = request.slot
        if slot < 0 or self.running[slot] is not request:
            raise ValueError(f"request {request.rid} does not own slot {slot}")
        self.running[slot] = None
        self.kv.release(slot, request)
        request.slot = -1
