"""Step-synchronous serving engine: continuous batching over a paged KV
cache with prefill/decode separation.

One ``ServeEngine`` iteration is

  1. **admission** — the batcher moves QUEUED requests into free batch
     slots once their cache pages are reserved (serve/batcher.py);
  2. **prefill** — admitted prompts run as *batched forward passes*
     (grouped by prompt length so recurrent states see no padding), the
     resulting states are converted to decode layout by
     ``Model.cache_from_prefill`` and written into the request's cache
     pages; the prompt's last-token logits yield the first new token;
  3. **decode** — every DECODE-state slot advances one token through a
     single jitted step: gather pages -> per-slot-position decode
     (``decode_step`` vmapped over batch slots, so each slot carries its
     own position) -> sample -> scatter the new KV row back to its page.

The engine clock is **virtual iteration time** — each prefill group and
each decode iteration costs 1.0 — so time-to-first-token / per-token
latencies and the continuous-vs-oneshot comparison are deterministic and
machine-independent (benchmarks additionally record wall seconds).

Decode is vmapped at batch size 1 per slot, so co-batched requests can
never influence each other's tokens — the isolation continuous batching
promises.  (For capacity-based MoE models this differs from the seed's
batched decode, where expert-capacity dropping depended on whichever
requests happened to share the batch; per-request isolation is the
behavior we actually want, but it means MoE token streams are not
bit-compatible with the old loop.)

Tensor-parallel decode (``ServeConfig.tp > 1``) wraps the same jitted
step in ``shard_map`` over a ("model",) mesh: attention heads and MLP
hidden are sharded via serve/tp.py, cache pages are sharded on the KV
head axis, and ``decode_step(tp_axis=...)`` inserts the Megatron-style
``tensor_reduce`` pair after the row-parallel matmuls.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.transformer import plan_segments
from repro.obs.trace import get_recorder
from repro.serve.batcher import Batcher
from repro.serve.cache import make_kv_store
from repro.serve.request import Request, RequestState, summarize
from repro.serve.sampling import sample_tokens


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs.  ``page_size == 0`` keeps the seed's contiguous
    per-slot cache; ``> 0`` switches to paged pools (``num_pages`` caps
    the pool — None sizes it so every slot can hold ``max_len``)."""
    slots: int = 4
    max_len: int = 128
    page_size: int = 0
    num_pages: Optional[int] = None
    policy: str = "continuous"           # | "oneshot"
    cache_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    window_override: int = 0
    tp: int = 1                          # tensor-parallel decode degree


class ServeEngine:
    """``slo`` optionally attaches an ``obs.slo.SLOMonitor``: the engine
    feeds it TTFT/TPOT on every completion and a stall sample every
    iteration, emits an ``slo_burn`` instant on each transition into
    firing, and records the alert times in ``slo_alerts`` — the signal
    ``Autoscaler.schedule(..., burn_times=...)`` consumes."""

    def __init__(self, model, params, scfg: ServeConfig, slo=None):
        if model.forward is None:
            raise ValueError("ServeEngine serves decoder-only models")
        self.model, self.params, self.scfg = model, params, scfg
        self.slo = slo
        self.slo_alerts: List[dict] = []
        self._slo_firing = False
        self.cfg = model.cfg
        self.vocab = self.cfg.vocab_size

        self._tp = None
        if scfg.tp > 1:
            from repro.serve.tp import TPContext
            self._tp = TPContext(self.cfg, scfg.tp)

        self.kv = make_kv_store(
            model, scfg.slots, scfg.max_len, scfg.page_size, scfg.num_pages,
            dtype=scfg.cache_dtype, window_override=scfg.window_override)
        self.batcher = Batcher(self.kv, scfg.slots, scfg.policy)

        self.requests: List[Request] = []
        self.clock = 0.0
        self.decode_iterations = 0
        self.prefill_groups = 0
        # rids whose lifecycle spans this engine opened — a request is
        # only ever *ended* on the trace if tracing saw it get submitted
        self._traced_rids: set = set()

        B = scfg.slots
        self._last_tok = np.zeros(B, np.int32)
        self._seeds = np.zeros(B, np.int32)
        self._temp = np.zeros(B, np.float32)
        self._topk = np.zeros(B, np.int32)
        # per-segment batch axis of the cache pytree (scan groups stack a
        # leading group axis, pushing batch to axis 1)
        self._axes = [0 if seg[0] == "plain" else 1
                      for seg in plan_segments(self.cfg)]
        self._step = self._build_step()

    # ------------------------------------------------------- jitted step
    def _build_step(self):
        kv, axes, vocab = self.kv, self._axes, self.vocab
        cdt, wov = self.scfg.compute_dtype, self.scfg.window_override
        cfg_used = self._tp.cfg_local if self._tp else self.cfg
        tp_axis = "model" if self._tp else None

        def step(params, store, bt, tokens, pos, active, seeds, tok_idx,
                 temp, topk):
            contig = kv.gather(store, bt)

            def one(tok, p, caches_nb):
                # re-add the batch dim vmap stripped, per segment axis
                c1 = [jax.tree.map(lambda a, _ax=ax: jnp.expand_dims(a, _ax),
                                   sub) for sub, ax in zip(caches_nb, axes)]
                lg, nc = T.decode_step(params, cfg_used, c1,
                                       tok[None, None], p,
                                       compute_dtype=cdt,
                                       window_override=wov, tp_axis=tp_axis)
                nc = [jax.tree.map(lambda a, _ax=ax: jnp.squeeze(a, _ax),
                                   sub) for sub, ax in zip(nc, axes)]
                return lg[0, 0], nc

            # vmap over batch slots so every slot decodes AT ITS OWN
            # position — the heart of continuous batching
            logits, new = jax.vmap(one, in_axes=(0, 0, axes),
                                   out_axes=(0, axes))(tokens, pos, contig)
            keys = jax.vmap(
                lambda s, i: jax.random.fold_in(jax.random.PRNGKey(s), i))(
                seeds, tok_idx)
            nxt = sample_tokens(logits, vocab, keys, temp, topk)
            new_store = kv.scatter(store, new, bt, pos, active)
            return nxt, new_store

        if self._tp is None:
            return jax.jit(step)
        return jax.jit(self._tp.wrap_step(step, self.params, self.kv.store))

    # --------------------------------------------------------- lifecycle
    def submit(self, request: Request) -> None:
        self.requests.append(request)
        self.batcher.submit(request)
        rec = get_recorder()
        if rec.enabled:
            # lifecycle track per request: QUEUED -> PREFILL -> DECODE
            # spans back to back on tid=req<rid> (docs/observability.md)
            self._traced_rids.add(request.rid)
            rec.begin("queued", pid="serve", tid=f"req{request.rid}",
                      cat="serve", clock=("serve_iter", self.clock),
                      rid=request.rid, prompt_len=request.prompt_len,
                      max_new_tokens=request.max_new_tokens,
                      arrival=request.arrival)

    def _finish(self, r: Request) -> None:
        r.state = RequestState.DONE
        r.finish_time = self.clock
        self.batcher.release(r)
        if self.slo is not None:
            self.slo.observe("ttft", self.clock, r.first_token_latency())
            self.slo.observe("tpot", self.clock, r.per_token_latency())
        rec = get_recorder()
        if rec.enabled and r.rid in self._traced_rids:
            rec.end(pid="serve", tid=f"req{r.rid}",      # closes "decode"
                    generated=len(r.output))
            rec.instant("done", pid="serve", tid=f"req{r.rid}", cat="serve",
                        clock=("serve_iter", self.clock), rid=r.rid)
            self._traced_rids.discard(r.rid)

    def _set_slot(self, r: Request, token: int) -> None:
        i = r.slot
        self._last_tok[i] = token
        self._seeds[i] = r.sampling.seed
        self._temp[i] = r.sampling.temperature
        self._topk[i] = r.sampling.top_k

    def _prefill(self, admitted: Sequence[Request]) -> None:
        """Batched prefill, grouped by prompt length (equal lengths — no
        padding, so recurrent states and ring buffers stay exact)."""
        groups: Dict[int, List[Request]] = {}
        for r in admitted:
            groups.setdefault(r.prompt_len, []).append(r)
        rec = get_recorder()
        for plen in sorted(groups):
            rs = groups[plen]
            if rec.enabled:
                for r in rs:
                    if r.rid in self._traced_rids:
                        rec.end(pid="serve", tid=f"req{r.rid}")  # "queued"
                        rec.begin("prefill", pid="serve",
                                  tid=f"req{r.rid}", cat="serve",
                                  clock=("serve_iter", self.clock),
                                  rid=r.rid, slot=r.slot, group_len=plen)
            toks = jnp.asarray(
                np.array([list(r.prompt) for r in rs], np.int32))
            logits, states = self.model.prefill(
                self.params, toks, compute_dtype=self.scfg.compute_dtype,
                window_override=self.scfg.window_override)
            conv = self.model.cache_from_prefill(
                states, self.scfg.max_len, dtype=self.scfg.cache_dtype,
                window_override=self.scfg.window_override)
            for j, r in enumerate(rs):
                self.kv.write_prefill(r.slot, conv, j, plen)

            # first new token straight from the prefill logits
            seeds = jnp.asarray([r.sampling.seed for r in rs],
                                dtype=jnp.int32)
            keys = jax.vmap(
                lambda s: jax.random.fold_in(jax.random.PRNGKey(s), 0))(
                seeds)
            t0 = np.asarray(sample_tokens(
                logits[:, 0].astype(jnp.float32), self.vocab, keys,
                jnp.asarray([r.sampling.temperature for r in rs],
                            dtype=jnp.float32),
                jnp.asarray([r.sampling.top_k for r in rs],
                            dtype=jnp.int32)))

            self.clock += 1.0
            self.prefill_groups += 1
            for j, r in enumerate(rs):
                tok = int(t0[j])
                r.output.append(tok)
                r.first_token_time = self.clock
                r.state = RequestState.DECODE
                self._set_slot(r, tok)
                if rec.enabled and r.rid in self._traced_rids:
                    rec.end(pid="serve", tid=f"req{r.rid}")  # "prefill"
                    rec.begin("decode", pid="serve", tid=f"req{r.rid}",
                              cat="serve",
                              clock=("serve_iter", self.clock),
                              rid=r.rid, slot=r.slot)
                if len(r.output) >= r.max_new_tokens:
                    self._finish(r)

    def _decode_iteration(self) -> None:
        B = self.scfg.slots
        pos = np.zeros(B, np.int32)
        tok_idx = np.zeros(B, np.int32)
        active = np.zeros(B, bool)
        decoding: List[Request] = []
        for i, r in enumerate(self.batcher.running):
            if r is not None and r.state is RequestState.DECODE:
                active[i] = True
                pos[i] = r.prompt_len + len(r.output) - 1
                tok_idx[i] = len(r.output)
                decoding.append(r)
        nxt, new_store = self._step(
            self.params, self.kv.store, self.kv.block_tables_device(),
            jnp.asarray(self._last_tok), jnp.asarray(pos),
            jnp.asarray(active), jnp.asarray(self._seeds),
            jnp.asarray(tok_idx), jnp.asarray(self._temp),
            jnp.asarray(self._topk))
        self.kv.store = new_store
        self.clock += 1.0
        self.decode_iterations += 1
        nxt = np.asarray(nxt)
        for r in decoding:
            tok = int(nxt[r.slot])
            r.output.append(tok)
            self._last_tok[r.slot] = tok
            if len(r.output) >= r.max_new_tokens:
                self._finish(r)

    def _emit_occupancy(self, rec) -> None:
        """Counter tracks: paged-KV pool occupancy (or contiguous slot
        occupancy) sampled once per engine iteration."""
        alloc = getattr(self.kv, "allocator", None)
        clock = ("serve_iter", self.clock)
        if alloc is not None:
            rec.counter("kv_pages",
                        {"used": alloc.capacity - alloc.free_pages,
                         "free": alloc.free_pages},
                        pid="serve", cat="serve", clock=clock)
        busy = sum(r is not None for r in self.batcher.running)
        rec.counter("slots", {"used": busy, "free": self.scfg.slots - busy},
                    pid="serve", cat="serve", clock=clock)

    def step_iteration(self) -> bool:
        """One engine iteration: admit+prefill, then one decode step.
        Returns False when nothing could make progress at this clock
        (the caller should jump the clock to the next arrival)."""
        progressed = False
        rec = get_recorder()
        stalls0 = self.batcher.stalls
        admitted = self.batcher.admit(self.clock)
        if rec.enabled and self.batcher.stalls > stalls0:
            # the FIFO head could not reserve pages/a slot this iteration
            rec.instant("admission_stall", pid="serve", tid="engine",
                        cat="serve", clock=("serve_iter", self.clock),
                        stalls=self.batcher.stalls,
                        free_pages=(self.kv.allocator.free_pages
                                    if getattr(self.kv, "allocator", None)
                                    is not None else -1))
        if admitted:
            self._prefill(admitted)
            progressed = True
        if any(r is not None and r.state is RequestState.DECODE
               for r in self.batcher.running):
            self._decode_iteration()
            progressed = True
        if rec.enabled:
            self._emit_occupancy(rec)
        if self.slo is not None:
            self.slo.observe("stall", self.clock,
                             1.0 if self.batcher.stalls > stalls0 else 0.0)
            self._slo_tick(rec)
        return progressed

    def _slo_tick(self, rec) -> None:
        """Evaluate the attached monitor at the current clock; on a
        transition into firing, record the alert and emit an
        ``slo_burn`` instant on the serve timeline."""
        firing = self.slo.firing(self.clock)
        if firing and not self._slo_firing:
            self.slo_alerts.append(dict(
                t=self.clock,
                objectives=[f["objective"] for f in firing]))
            if rec.enabled:
                rec.instant(
                    "slo_burn", pid="serve", tid="slo", cat="serve",
                    clock=("serve_iter", self.clock),
                    objectives=",".join(f["objective"] for f in firing),
                    burn_long=round(max(f["burn_long"] for f in firing),
                                    4),
                    burn_short=round(max(f["burn_short"] for f in firing),
                                     4))
        self._slo_firing = bool(firing)

    def run(self, requests: Optional[Sequence[Request]] = None) -> dict:
        """Drive every submitted request to DONE; returns the metrics row
        (throughput + latency percentiles on the virtual clock, plus wall
        seconds and stall count)."""
        if requests:
            for r in requests:
                self.submit(r)
        t_wall = time.perf_counter()
        while not self.batcher.idle:
            if not self.step_iteration():
                na = self.batcher.next_arrival()
                if na is None or na <= self.clock:
                    # head arrived, batch is empty, and it still can't
                    # reserve: no future event can unblock it
                    raise RuntimeError(
                        "serving deadlock: queued requests can never be "
                        "admitted (pool too small for any single request?)")
                self.clock = na
        wall = time.perf_counter() - t_wall
        m = summarize(self.requests, makespan=self.clock)
        m.update(
            policy=self.scfg.policy,
            paged=bool(self.scfg.page_size),
            page_size=self.scfg.page_size,
            tp=self.scfg.tp,
            clock=self.clock,
            decode_iterations=self.decode_iterations,
            prefill_groups=self.prefill_groups,
            admission_stalls=self.batcher.stalls,
            wall_s=wall,
        )
        if self.slo is not None:
            m["slo_alerts"] = len(self.slo_alerts)
        return m
