"""Batch-generate compatibility shim over the serving engine.

The seed's ``generate`` warmed the cache by feeding the prompt through
the decode path *token-by-token* — S0 sequential ``decode_step`` calls
before the first new token.  It is now a thin wrapper over
``ServeEngine``: the prompt runs as ONE batched prefill forward pass
(``Model.prefill`` + ``cache_from_prefill``) and decode proceeds through
the engine's jitted step.  Greedy tokens are bitwise-identical to the old
loop (regression-tested in tests/test_serving.py); ``greedy_sample`` is
re-exported from serve/sampling.py for existing callers.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.sampling import greedy_sample  # noqa: F401  (compat)


def generate(model, params, prompt, max_new_tokens: int,
             max_len: Optional[int] = None, window_override: int = 0,
             compute_dtype=jnp.float32):
    """Greedy decode.  prompt [B, S0] int32 -> [B, S0 + max_new_tokens]."""
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.request import Request

    prompt = np.asarray(prompt)
    B, S0 = prompt.shape
    max_len = max_len or (S0 + max_new_tokens)
    eng = ServeEngine(model, params, ServeConfig(
        slots=B, max_len=max_len, policy="oneshot",
        cache_dtype=compute_dtype, compute_dtype=compute_dtype,
        window_override=window_override))
    reqs = [Request(rid=i, prompt=[int(t) for t in prompt[i]],
                    max_new_tokens=max_new_tokens) for i in range(B)]
    eng.run(reqs)
    out = np.concatenate(
        [prompt, np.array([r.output for r in reqs], np.int32)], axis=1)
    return jnp.asarray(out)
