"""Batched autoregressive serving loop (survey §5 flags DL serving as an
open direction; this is the decode path the decode_32k / long_500k shapes
exercise)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def greedy_sample(logits, vocab_size: int):
    """argmax over the un-padded vocab.  logits [B, 1, Vpad]."""
    return jnp.argmax(logits[..., :vocab_size], axis=-1).astype(jnp.int32)


def generate(model, params, prompt, max_new_tokens: int,
             max_len: Optional[int] = None, window_override: int = 0,
             compute_dtype=jnp.float32):
    """Greedy decode.  prompt [B, S0] int32 -> [B, S0 + max_new_tokens].

    The prompt is consumed through the decode path token-by-token (cache
    warm-up), then generation proceeds greedily; one jitted decode_step
    serves both phases — the production structure for a step-synchronous
    batched decoder.
    """
    B, S0 = prompt.shape
    V = model.cfg.vocab_size
    max_len = max_len or (S0 + max_new_tokens)
    caches = model.init_cache(B, max_len, dtype=compute_dtype,
                              window_override=window_override)

    step = jax.jit(
        lambda p, c, tok, pos: model.decode_step(
            p, c, tok, pos, compute_dtype=compute_dtype,
            window_override=window_override),
        static_argnames=())

    tokens = prompt
    logits = None
    for t in range(S0):
        logits, caches = step(params, caches, tokens[:, t:t + 1], t)
    for t in range(S0, S0 + max_new_tokens):
        nxt = greedy_sample(logits, V)
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        logits, caches = step(params, caches, nxt, t)
    return tokens
