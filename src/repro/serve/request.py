"""Serving request lifecycle (survey §5 model management; arXiv 2111.14247
frames continuous batching + KV management as the goodput levers).

A ``Request`` is the unit the serving plane schedules: it arrives at a
point on the engine clock, carries its prompt and decode budget, and moves
through the state machine

    QUEUED -> PREFILL -> DECODE -> DONE

``QUEUED``   submitted, waiting for a batch slot *and* for cache pages
             (admission is reservation-based — see serve/cache.py).
``PREFILL``  admitted this iteration; its prompt runs as one batched
             forward pass that fills cache pages (never token-by-token).
``DECODE``   in a batch slot, producing one token per engine iteration.
``DONE``     reached ``max_new_tokens``; its slot and pages are recycled.

Latency accounting is recorded on the engine's clock (virtual iteration
time by default, wall-seconds in the benchmarks): time-to-first-token is
``first_token_time - arrival`` and the steady-state per-token latency is
``(finish_time - first_token_time) / (generated - 1)``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence

# the latency aggregation lives in the shared observability plane now;
# re-exported here so existing ``serve.request.percentile`` callers keep
# working (docs/observability.md)
from repro.obs.metrics import percentile


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs (serve/sampling.py).  ``temperature <= 0``
    is greedy argmax — the deterministic default every equivalence test
    uses; ``top_k`` restricts sampling to the k highest logits (0 = off).
    ``seed`` derives the request's own PRNG key, folded per token."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass
class Request:
    """One generation request moving through the serving plane."""
    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival: float = 0.0
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)

    # -- lifecycle (owned by the batcher/engine) --
    state: RequestState = RequestState.QUEUED
    slot: int = -1                      # batch slot while PREFILL/DECODE
    pages: List[int] = dataclasses.field(default_factory=list)
    output: List[int] = dataclasses.field(default_factory=list)

    # -- latency accounting (engine clock) --
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    def __post_init__(self):
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_len(self) -> int:
        """Context capacity the request needs: prompt + all new tokens."""
        return self.prompt_len + self.max_new_tokens

    @property
    def done(self) -> bool:
        return self.state is RequestState.DONE

    # ------------------------------------------------------------ metrics
    def first_token_latency(self) -> float:
        """Time-to-first-token on the engine clock (inf if never served)."""
        if self.first_token_time is None:
            return float("inf")
        return self.first_token_time - self.arrival

    def per_token_latency(self) -> float:
        """Steady-state decode latency per generated token."""
        if self.finish_time is None or self.first_token_time is None:
            return float("inf")
        n = len(self.output)
        if n <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (n - 1)


def summarize(requests: Sequence[Request], makespan: float) -> dict:
    """Aggregate serving metrics over completed requests: throughput plus
    p50/p99 first-token and per-token latencies (the serve_bench row)."""
    done = [r for r in requests if r.done]
    total_tokens = sum(len(r.output) for r in done)
    ttft = [r.first_token_latency() for r in done]
    tpot = [r.per_token_latency() for r in done]
    return {
        "completed": len(done),
        "generated_tokens": total_tokens,
        "tokens_per_s": total_tokens / makespan if makespan > 0 else 0.0,
        "p50_first_token": percentile(ttft, 50),
        "p99_first_token": percentile(ttft, 99),
        "p50_per_token": percentile(tpot, 50),
        "p99_per_token": percentile(tpot, 99),
    }
