"""Tensor-parallel decode for the serving plane.

Reuses the training-side Megatron decomposition (repro.core.parallelism
role tables, repro.parallel.staged f/g collectives) for inference: over a
("model",) mesh of ``tp`` devices,

  * wq/wk/wv and w_gate/w_up are **column-sharded** (each rank owns
    ``H/tp`` query heads, ``KV/tp`` kv heads, ``ff/tp`` hidden),
  * wo and w_down are **row-sharded**, their partial products summed by
    ``tensor_reduce`` inside ``decode_step(tp_axis="model")``,
  * cache pages are sharded on the **KV-head axis** (always ``ndim-2`` of
    every attention cache leaf — contiguous rows, ring buffers, and paged
    pools alike), so each rank holds only its heads' history,
  * embeddings / norms / lm_head stay replicated — decode activations are
    replicated between the f/g pairs, exactly the training-side layout.

Inside the ``shard_map`` each rank runs the *same* engine step function
against a head-shrunk config (``num_heads/tp``, ``num_kv_heads/tp``), so
paged gather/scatter and sampling need no TP-specific code.  Serving TP
is restricted to pure-GQA decoders (no MoE / MLA / recurrent blocks and
no biases — row-parallel bias would be added ``tp`` times).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.collectives import shard_map

_COL = frozenset({"wq", "wk", "wv", "w_gate", "w_up"})
_ROW = frozenset({"wo", "w_down"})


def check_tp_supported(cfg: ModelConfig, tp: int) -> None:
    bad = [k for k in cfg.layer_kinds if k not in ("attn", "local")]
    if bad:
        raise ValueError(f"tp decode needs attention-only stacks, got {bad}")
    if cfg.attn_type == "mla":
        raise ValueError("tp decode does not shard MLA latent caches")
    if cfg.moe:
        raise ValueError("tp decode does not support MoE layers")
    if cfg.use_bias:
        raise ValueError("tp decode requires use_bias=False "
                         "(row-parallel bias would be applied tp times)")
    if cfg.num_heads % tp or cfg.num_kv_heads % tp:
        raise ValueError(
            f"tp={tp} must divide num_heads={cfg.num_heads} and "
            f"num_kv_heads={cfg.num_kv_heads}")


def _path_names(path) -> list:
    return [getattr(p, "key", None) for p in path]


def param_specs(params) -> Any:
    """PartitionSpec tree for serve-TP: column weights shard their last
    axis, row weights their second-to-last (leading scan-group axes
    shift positions, hence from-the-end indexing); the rest replicate."""
    def spec(path, leaf):
        names = _path_names(path)
        if any(n in _COL for n in names):
            return P(*([None] * (leaf.ndim - 1) + ["model"]))
        if any(n in _ROW for n in names):
            return P(*([None] * (leaf.ndim - 2) + ["model", None]))
        return P()
    return jax.tree_util.tree_map_with_path(spec, params)


def store_specs(store) -> Any:
    """Every cache leaf of a pure-GQA decoder is [..., KV, hd]-shaped
    (contiguous [B,L,KV,hd], ring [B,W,KV,hd], pools [Np,page,KV,hd],
    scan-stacked with a leading G) — shard the KV-head axis at ndim-2."""
    return jax.tree.map(
        lambda a: P(*([None] * (a.ndim - 2) + ["model", None])), store)


class TPContext:
    def __init__(self, cfg: ModelConfig, tp: int):
        check_tp_supported(cfg, tp)
        devs = jax.devices()
        if len(devs) < tp:
            raise ValueError(f"tp={tp} but only {len(devs)} devices")
        self.tp = tp
        self.mesh = Mesh(np.array(devs[:tp]), ("model",))
        self.cfg = cfg
        # each rank runs the ordinary decode math at 1/tp the heads
        self.cfg_local = dataclasses.replace(
            cfg, num_heads=cfg.num_heads // tp,
            num_kv_heads=cfg.num_kv_heads // tp)

    def wrap_step(self, step_fn, params, store):
        """shard_map the engine's step(params, store, bt, tokens, pos,
        active, seeds, tok_idx, temp, topk) -> (next_tokens, new_store).
        Everything but params/store is replicated; sampled tokens come
        back replicated (every rank computes them identically from the
        reduced logits), so the result is checked loosely."""
        ss = store_specs(store)
        in_specs = (param_specs(params), ss) + (P(),) * 8
        out_specs = (P(), ss)
        return shard_map(step_fn, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
