"""Paged KV cache over the model zoo's ``init_cache`` layouts.

vLLM-style block management (arXiv 2111.14247 §KV management) on top of
the existing cache pytrees:

  * attention caches ([.., B, L, KV, hd] k/v, [.., B, L, r] MLA latents)
    are re-laid-out as fixed-size **page pools** ``[num_pages, page, ...]``
    shared by every batch slot, addressed through per-request **block
    tables** (logical page -> physical page);
  * a **BlockAllocator** hands pages out at admission and takes them back
    on completion (free-list reuse), so batch slots are recycled
    continuously and an over-subscribed pool *stalls admission* instead
    of OOM-ing;
  * recurrent states (rglru / rwkv), sliding-window ring buffers, and
    whole caches in contiguous mode stay per-slot arrays.

Physical page 0 is reserved as the null/scratch page: freshly-reset block
tables point at it and *inactive* batch slots scatter their garbage decode
writes into it, so the one jitted decode step needs no masking branches.

Equivalence contract (tested in tests/test_serving.py): ``gather`` of a
request's pages reproduces the contiguous cache bit-for-bit at every
position <= its current one, and positions beyond are score-masked to
exactly zero probability — so paged decode is bitwise-identical to
contiguous decode.
"""
from __future__ import annotations

import math
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import plan_segments


# ------------------------------------------------------------- allocator
class BlockAllocator:
    """Free-list page allocator.  Page 0 is reserved (null/scratch)."""

    def __init__(self, num_pages: int, reserved: int = 1):
        if num_pages <= reserved:
            raise ValueError(f"num_pages={num_pages} <= reserved={reserved}")
        self.num_pages = num_pages
        self.reserved = reserved
        self._free: List[int] = list(range(reserved, num_pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        return self.num_pages - self.reserved

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if not self.can_alloc(n):
            raise MemoryError(
                f"paged KV pool exhausted: want {n}, free {len(self._free)} "
                "(admission should have stalled)")
        pages, self._free = self._free[:n], self._free[n:]
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p < self.reserved or p >= self.num_pages:
                raise ValueError(f"freeing invalid page {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
        self._free.extend(pages)


# ----------------------------------------------------- segment structure
def _seq_from_end(cfg: ModelConfig, sig, window_override: int) -> int:
    """Pages-eligible sequence axis of this layer kind, counted from the
    end of each cache leaf (0 = not paged: recurrent state / ring
    buffer).  From-the-end indexing maps through the leading group axis
    scan segments add."""
    kind, _ = sig
    if kind not in ("attn", "local"):
        return 0
    if cfg.attn_type == "mla":
        return 2                         # {c_kv, k_rope}: [.., B, L, r]
    window = cfg.window if kind == "local" else window_override
    return 0 if window else 3            # ring buffers stay per-slot


def _map_cache(cfg: ModelConfig, caches, fn, window_override: int = 0):
    """Apply ``fn(subtree, batch_axis, seq_from_end)`` per layer, walking
    the segment-plan structure of an ``init_cache`` pytree (plain layers:
    batch axis 0; scan groups: leading group axis, batch axis 1)."""
    out: List[Any] = []
    for seg, c in zip(plan_segments(cfg), caches):
        if seg[0] == "plain":
            out.append(fn(c, 0, _seq_from_end(cfg, seg[1], window_override)))
        else:
            _, pattern, _n = seg
            out.append(tuple(
                fn(c[j], 1, _seq_from_end(cfg, pattern[j], window_override))
                for j in range(len(pattern))))
    return out


def cache_bytes(caches) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))


# ------------------------------------------------------------- KV stores
class ContiguousKV:
    """The seed layout: one ``init_cache(slots, max_len)`` pytree, every
    slot owning its full-length rows.  Same interface as ``PagedKV`` so
    the engine's jitted step is layout-agnostic."""

    paged = False

    def __init__(self, model, slots: int, max_len: int, dtype=jnp.float32,
                 window_override: int = 0):
        self.cfg = model.cfg
        self.slots, self.max_len = slots, max_len
        self.dtype, self.window_override = dtype, window_override
        self.store = model.init_cache(slots, max_len, dtype=dtype,
                                      window_override=window_override)

    # the engine threads (store, block_tables) through its jitted step;
    # contiguous mode has no tables — a 0-d placeholder keeps shapes static
    def block_tables_device(self):
        return jnp.zeros((), jnp.int32)

    def gather(self, store, bt):
        return store

    def scatter(self, store, new_caches, bt, pos, active):
        # the vmapped decode already wrote each slot's token row in place
        # (inactive slots scribble at pos 0 of their own — free — rows)
        return new_caches

    # ------------------------------------------------------ admission
    def try_reserve(self, request) -> bool:
        return request.total_len <= self.max_len

    def write_prefill(self, slot: int, conv_cache, j: int, prompt_len: int):
        """Copy request ``j``'s row of a converted (decode-layout) prefill
        cache into batch slot ``slot``, walking dst/src trees in lockstep."""
        out = []
        for dst_sub, src_sub, seg in zip(
                self.store, conv_cache, plan_segments(self.cfg)):
            ax = 0 if seg[0] == "plain" else 1
            out.append(jax.tree.map(
                lambda d, s, _ax=ax: (d.at[slot].set(s[j]) if _ax == 0
                                      else d.at[:, slot].set(s[:, j])),
                dst_sub, src_sub))
        self.store = out

    def release(self, slot: int, request) -> None:
        pass                              # rows are overwritten on admit


class PagedKV:
    """Fixed-size page pools + per-slot block tables over the attention
    caches; everything else (recurrent states, ring buffers) stays a
    per-slot array exactly as in ``ContiguousKV``."""

    paged = True

    def __init__(self, model, slots: int, max_len: int, page_size: int,
                 num_pages: Optional[int] = None, dtype=jnp.float32,
                 window_override: int = 0):
        if page_size <= 0:
            raise ValueError("page_size must be > 0 for PagedKV")
        if window_override:
            raise ValueError("paged cache + window_override unsupported "
                             "(ring buffers are already constant-size)")
        self.cfg = model.cfg
        self.slots, self.max_len, self.page = slots, max_len, page_size
        self.dtype, self.window_override = dtype, 0
        self.pages_per_seq = math.ceil(max_len / page_size)
        if num_pages is None:
            # default: every slot can hold a full-length request, +1 null
            num_pages = 1 + slots * self.pages_per_seq
        self.allocator = BlockAllocator(num_pages, reserved=1)
        self.block_tables = np.zeros((slots, self.pages_per_seq), np.int32)

        template = model.init_cache(slots, max_len, dtype=dtype)

        def to_pool(sub, batch_axis, seq):
            if seq == 0:
                return sub                # per-slot leaf kept as-is
            def pool(leaf):
                s_ax = leaf.ndim - seq
                lead = leaf.shape[:s_ax]
                lead = lead[:batch_axis] + lead[batch_axis + 1:]  # drop B
                return jnp.zeros(
                    lead + (num_pages, page_size) + leaf.shape[s_ax + 1:],
                    dtype=leaf.dtype)
            return jax.tree.map(pool, sub)

        self.store = _map_cache(self.cfg, template, to_pool)

    def block_tables_device(self):
        return jnp.asarray(self.block_tables)

    # ------------------------------------------------- gather / scatter
    def gather(self, store, bt):
        """Paged pools -> the contiguous view the decode math consumes.
        Pure function of (store, bt): runs inside the jitted step."""
        P, page, L = self.pages_per_seq, self.page, self.max_len

        def one(sub, batch_axis, seq):
            if seq == 0:
                return sub
            def g(pool):
                if batch_axis == 0:      # pool [Np, page, rest]
                    v = pool[bt]         # [B, P, page, rest]
                    v = v.reshape((v.shape[0], P * page) + v.shape[3:])
                    return v[:, :L]
                # pool [G, Np, page, rest]
                v = jnp.take(pool, bt, axis=1)   # [G, B, P, page, rest]
                v = v.reshape(v.shape[:2] + (P * page,) + v.shape[4:])
                return v[:, :, :L]
            return jax.tree.map(g, sub)

        return _map_cache(self.cfg, store, one)

    def scatter(self, store, new_caches, bt, pos, active):
        """Write the token row each slot just produced back to its page
        (pure; inside the jitted step).  pos [B] int32 is the position
        just written; inactive slots are routed to null page 0."""
        page = self.page
        phys = jnp.where(active,
                         jnp.take_along_axis(
                             bt, (pos // page)[:, None], axis=1)[:, 0],
                         0)
        off = pos % page

        def one(pair, batch_axis, seq):
            pool_sub, new_sub = pair
            if seq == 0:
                return new_sub           # per-slot leaf: updated in place

            def s(pool, new):
                if batch_axis == 0:      # new [B, L, rest]
                    rows = jax.vmap(lambda a, p: a[p])(new, pos)
                    return pool.at[phys, off].set(rows.astype(pool.dtype))
                # new [G, B, L, rest] -> rows [G, B, rest]
                rows = jax.vmap(lambda a, p: a[:, p],
                                in_axes=(1, 0), out_axes=1)(new, pos)
                return pool.at[:, phys, off].set(rows.astype(pool.dtype))
            return jax.tree.map(s, pool_sub, new_sub)

        out: List[Any] = []
        for ps, ns, seg in zip(store, new_caches, plan_segments(self.cfg)):
            if seg[0] == "plain":
                out.append(one((ps, ns), 0,
                               _seq_from_end(self.cfg, seg[1], 0)))
            else:
                _, pattern, _n = seg
                out.append(tuple(
                    one((ps[j], ns[j]), 1,
                        _seq_from_end(self.cfg, pattern[j], 0))
                    for j in range(len(pattern))))
        return out

    # ------------------------------------------------------ admission
    def try_reserve(self, request) -> bool:
        """Reservation-based admission: take every page the request can
        ever touch (prompt + max_new) up front, or refuse (the batcher
        stalls the request instead of risking mid-decode OOM)."""
        if request.total_len > self.max_len:
            return False
        n = math.ceil(request.total_len / self.page)
        if not self.allocator.can_alloc(n):
            return False
        request.pages = self.allocator.alloc(n)
        return True

    def write_prefill(self, slot: int, conv_cache, j: int, prompt_len: int):
        """Scatter request ``j``'s prompt rows of a converted prefill
        cache into its reserved pages; per-slot leaves assign directly."""
        bt_row = self.block_tables[slot]
        ts = np.arange(prompt_len)
        phys = jnp.asarray(bt_row[ts // self.page])
        off = jnp.asarray(ts % self.page)

        out: List[Any] = []
        for dst_sub, src_sub, seg in zip(
                self.store, conv_cache, plan_segments(self.cfg)):
            if seg[0] == "plain":
                infos = [(0, _seq_from_end(self.cfg, seg[1], 0))]
                subs = [(dst_sub, src_sub)]
            else:
                _, pattern, _n = seg
                infos = [(1, _seq_from_end(self.cfg, pattern[k], 0))
                         for k in range(len(pattern))]
                subs = list(zip(dst_sub, src_sub))

            def wr(d, s, batch_axis, seq):
                if seq == 0:
                    return (d.at[slot].set(s[j]) if batch_axis == 0
                            else d.at[:, slot].set(s[:, j]))
                if batch_axis == 0:      # s [B, L, rest] -> rows [S0, rest]
                    rows = s[j, :prompt_len]
                    return d.at[phys, off].set(rows.astype(d.dtype))
                rows = s[:, j, :prompt_len]          # [G, S0, rest]
                return d.at[:, phys, off].set(rows.astype(d.dtype))

            done = [jax.tree.map(
                        lambda dd, ss, _i=i: wr(dd, ss, *infos[_i]),
                        subs[i][0], subs[i][1])
                    for i in range(len(subs))]
            out.append(done[0] if seg[0] == "plain" else tuple(done))
        self.store = out

    def set_block_table(self, slot: int, pages: Sequence[int]) -> None:
        row = np.zeros(self.pages_per_seq, np.int32)
        row[:len(pages)] = pages
        self.block_tables[slot] = row

    def release(self, slot: int, request) -> None:
        if request.pages:
            self.allocator.free(request.pages)
            request.pages = []
        self.block_tables[slot] = 0


def make_kv_store(model, slots: int, max_len: int, page_size: int = 0,
                  num_pages: Optional[int] = None, dtype=jnp.float32,
                  window_override: int = 0):
    """page_size == 0 -> contiguous (seed layout); > 0 -> paged pools."""
    if page_size:
        return PagedKV(model, slots, max_len, page_size, num_pages,
                       dtype=dtype, window_override=window_override)
    return ContiguousKV(model, slots, max_len, dtype=dtype,
                        window_override=window_override)
