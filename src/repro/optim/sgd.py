"""SGD with (Nesterov) momentum."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SGD:
    momentum: float = 0.9
    nesterov: bool = False

    def init(self, params):
        if self.momentum == 0:
            return {}
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params)}

    def step(self, params, grads, state, lr):
        if self.momentum == 0:
            new_p = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_p, state
        m = jax.tree.map(
            lambda mm, g: self.momentum * mm + g.astype(jnp.float32),
            state["m"], grads)
        if self.nesterov:
            upd = jax.tree.map(
                lambda mm, g: self.momentum * mm + g.astype(jnp.float32),
                m, grads)
        else:
            upd = m
        new_p = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
            params, upd)
        return new_p, {"m": m}
