"""Optimizers (pure pytree transforms; eval_shape friendly for the dry-run).

Interface: ``opt.init(params) -> state``; ``opt.step(params, grads, state,
lr) -> (params, state)``.
"""
from repro.optim.sgd import SGD
from repro.optim.adam import Adam, AdamW
from repro.optim.adafactor import Adafactor
from repro.optim.schedule import cosine_warmup, constant

OPTIMIZERS = {"sgd": SGD, "adam": Adam, "adamw": AdamW,
              "adafactor": Adafactor}

__all__ = ["SGD", "Adam", "AdamW", "Adafactor", "cosine_warmup", "constant",
           "OPTIMIZERS"]
