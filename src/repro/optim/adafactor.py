"""Adafactor (factored second moments) — the memory-frugal optimizer that
makes trillion-parameter optimizer state representable on the dry-run mesh
(state is O(rows + cols) per matrix instead of O(rows * cols))."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Adafactor:
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def init(self, params):
        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"f": jax.tree.map(one, params), "t": jnp.zeros((), jnp.int32)}

    def step(self, params, grads, state, lr):
        t = state["t"] + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** (-self.decay)

        def one(p, g, st):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if p.ndim >= 2:
                vr = beta * st["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * st["vc"] + (1 - beta) * g2.mean(-2)
                denom = jnp.maximum(vr.mean(-1, keepdims=True), self.eps)
                prec = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = g32 * jax.lax.rsqrt(prec + self.eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + self.eps)
                new_st = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_st

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["f"])
        outs = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_f = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_p, {"f": new_f, "t": t}
