"""Adam / AdamW."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Adam:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    # fp32 moment buffers per parameter — the quantity ZeRO-1/2 shard
    # away (repro.parallel.zero's memory math keys on this)
    moments_per_param = 2

    def init(self, params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def step(self, params, grads, state, lr):
        t = state["t"] + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, mm, vv):
            u = (mm / c1) / (jnp.sqrt(vv / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        return (jax.tree.map(upd, params, m, v),
                {"m": m, "v": v, "t": t})


def AdamW(weight_decay: float = 0.01, **kw):
    return Adam(weight_decay=weight_decay, **kw)
