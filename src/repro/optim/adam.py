"""Adam / AdamW, with optionally quantized (bf16) EMA moment buffers.

``moment_dtype="bfloat16"`` stores the m/v EMA buffers in bf16 (halving
the optimizer-state footprint — the survey's §3.3.3 memory lever) while
all EMA and update math stays fp32: buffers are widened on read and
rounded back on store, so the default fp32 path is bitwise-unchanged.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Adam:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # storage dtype of the m/v EMA buffers ("float32" | "bfloat16");
    # EMA/update arithmetic is always fp32
    moment_dtype: str = "float32"

    # moment buffers per parameter — the quantity ZeRO-1/2 shard
    # away (repro.parallel.zero's memory math keys on this)
    moments_per_param = 2

    @property
    def mdt(self):
        return jnp.dtype(self.moment_dtype)

    @property
    def moment_bytes(self) -> int:
        """Bytes per stored moment element (4 fp32, 2 bf16)."""
        return int(self.mdt.itemsize)

    def init(self, params):
        z = lambda p: jnp.zeros_like(p, self.mdt)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def step(self, params, grads, state, lr):
        t = state["t"] + 1
        b1, b2 = self.b1, self.b2
        f32 = lambda x: x.astype(jnp.float32)
        m = jax.tree.map(lambda mm, g: b1 * f32(mm) + (1 - b1) * f32(g),
                         state["m"], grads)
        v = jax.tree.map(
            lambda vv, g: b2 * f32(vv) + (1 - b2) * jnp.square(f32(g)),
            state["v"], grads)
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, mm, vv):
            u = (mm / c1) / (jnp.sqrt(vv / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        q = lambda x: x.astype(self.mdt)
        return (jax.tree.map(upd, params, m, v),
                {"m": jax.tree.map(q, m), "v": jax.tree.map(q, v), "t": t})


def AdamW(weight_decay: float = 0.01, **kw):
    return Adam(weight_decay=weight_decay, **kw)
