"""`make obs` tier-1 gate: the observability plane end to end.

Checks (see docs/observability.md):

  train trace    a traced ``bsp/ring/onebit@8`` run on 8 virtual devices
                 produces well-formed Chrome trace JSON with the
                 step -> compute/exchange -> bucket -> hop nesting and
                 wire-byte counter track
  determinism    two same-seed traced runs are byte-identical after
                 ``strip_wall`` (the virtual-tick clock is a pure
                 function of host event order)
  attribution    the analyzer attributes >=95% of every step window to
                 {compute, comm, snapshot, stall} with the majority
                 explained by instrumented spans, and the exchange's
                 issue-order overlap lies between the modeled
                 no-overlap and TicTac bounds
  pipeline       a traced d2.t2.s2 hybrid run reports a measured GPipe
                 bubble fraction within 10% relative of the analytic
                 (s-1)/(m+s-1)
  serve trace    a traced serve episode over an undersized page pool
                 records the queued -> prefill -> decode lifecycle span
                 chain per request, the ``kv_pages`` occupancy counter
                 track, at least one ``admission_stall`` instant, and —
                 with a tight SLO monitor attached — an ``slo_burn``
                 alert

  PYTHONPATH=src python tools/obs_smoke.py
"""
import os
import sys

# virtual devices must be configured before jax import
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.launch.env import ensure_host_devices  # noqa: E402

ensure_host_devices(8)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.obs.analyze import (overlap_efficiency,          # noqa: E402
                               pipeline_accounting, step_attribution)
from repro.obs.trace import (canonical_bytes, find_spans,   # noqa: E402
                             strip_wall, tracing, validate_trace)
from repro.train import Strategy                            # noqa: E402

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))
STEPS = 3


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


def traced_train() -> dict:
    # a second small leaf forces >1 fused bucket at this bucket_mb
    p0 = {"W": jnp.zeros((8, 1)), "b": jnp.zeros((130,))}
    strat = Strategy.parse("bsp/ring/onebit@8", lr=0.05, bucket_mb=1e-4,
                           backend="device", wire="measured")
    engine = strat.build(grad_fn)
    with tracing() as rec:
        engine.run(p0, make_batch, STEPS)
    return rec.to_chrome()


def traced_pipeline(spec: str = "bsp/ring/none@8:d2.t2.s2",
                    layers: int = 2) -> dict:
    """A staged run — the pipeline-schedule spans feed the analyzer's
    bubble accounting (schedule-aware: each ``pipe`` span stamps its own
    schedule's analytic bound)."""
    from repro.parallel import make_tiny_transformer
    params, model = make_tiny_transformer(layers, 8, 16, seed=0)
    strat = Strategy.parse(spec, lr=0.05, bucket_mb=1e-4,
                           backend="device")
    engine = strat.build(model)

    def batch(t, w):
        k = jax.random.fold_in(KEY, 7919 * t + w)
        x = jax.random.normal(k, (8, 8))
        return {"x": x, "y": x @ jax.random.normal(KEY, (8, 8))}

    with tracing() as rec:
        engine.run(params, batch, 2)
    return rec.to_chrome()


def traced_serve() -> dict:
    from repro.configs import get_config
    from repro.models import build_model
    from repro.obs.slo import SLOMonitor
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.request import Request
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = rng.randint(1, cfg.vocab_size, size=(4, 5))
    reqs = [Request(rid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=6) for i in range(4)]
    # num_pages=6 is under the 4-request working set -> admission stalls;
    # the stalled requests' TTFT blows the (deliberately tight) SLO, so
    # the attached monitor must fire at least once
    slo = SLOMonitor(["ttft_p99<2"], long_window=16.0, short_window=4.0,
                     factor=1.0)
    eng = ServeEngine(model, params, ServeConfig(
        slots=4, max_len=16, page_size=4, num_pages=6,
        cache_dtype=jnp.float32, compute_dtype=jnp.float32), slo=slo)
    with tracing() as rec:
        m = eng.run(reqs)
    assert m["admission_stalls"] > 0, "pool was not exhausted"
    assert m["slo_alerts"] > 0, "tight SLO never fired"
    return rec.to_chrome()


def main() -> int:
    failures = []

    # ------------------------------------------------------ train trace
    tr = traced_train()
    try:
        stats = validate_trace(tr)
        names = set(stats["names"])
        need = {"step", "compute", "exchange", "hop", "wire_bytes"}
        assert need <= names, f"missing events: {need - names}"
        assert any(n.startswith("bucket") for n in names), "no bucket spans"
        assert len(find_spans(tr, "step")) == STEPS, "step span per step"
        # step -> exchange -> bucket is depth 3 on the train track
        assert stats["max_depth"] >= 3, stats["max_depth"]
        ok = True
    except (AssertionError, ValueError) as e:
        ok = False
        failures.append(f"train: {e}")
    print(f"{'train trace: nested step/exchange/bucket':48s} "
          f"{'OK' if ok else 'FAIL'}")

    # ------------------------------------------------------ determinism
    a = canonical_bytes(strip_wall(tr))
    b = canonical_bytes(strip_wall(traced_train()))
    ok = a == b
    print(f"{'determinism: same-seed traces byte-identical':48s} "
          f"{'OK' if ok else 'FAIL'} ({len(a)} bytes)")
    if not ok:
        failures.append("determinism")

    # ------------------------------------------------------ attribution
    try:
        attr = step_attribution(tr)
        assert attr is not None, "no step spans to attribute"
        assert attr["basis"] == "wall", attr["basis"]
        assert attr["attributed_pct_min"] >= 95.0, attr
        assert attr["attributed_pct_max"] <= 105.0, attr
        # the instrumented spans, not the residual, explain the steps
        assert attr["known_pct_mean"] >= 50.0, attr["known_pct_mean"]
        ov = overlap_efficiency(tr)
        assert ov is not None, "exchange spans carry no modeled bounds"
        assert ov["all_in_bounds"], ov
        assert 0.0 <= ov["efficiency_mean"] <= 1.0, ov
        ok = True
    except (AssertionError, ValueError) as e:
        ok = False
        failures.append(f"attribution: {e}")
    print(f"{'analyzer: attribution sums + overlap bounds':48s} "
          f"{'OK' if ok else 'FAIL'}")

    # --------------------------------------------------------- pipeline
    try:
        pp = pipeline_accounting(traced_pipeline())
        assert pp is not None, "no pipeline spans"
        assert pp["pipes"], pp
        assert pp["rel_err_max"] <= 0.10, pp
        # schedule-aware: interleaved 1F1B on the same d2.s2 mesh (m=8)
        # measures a strictly smaller bubble than GPipe, each schedule
        # within 10% relative of its own stamped analytic bound
        gp = pipeline_accounting(traced_pipeline(
            "bsp/ring/none@4:d2.s2.m8", layers=4))
        fb = pipeline_accounting(traced_pipeline(
            "bsp/ring/none@4:d2.s2.m8.1f1b", layers=4))
        assert gp is not None and fb is not None, "missing pipe spans"
        assert gp["rel_err_max"] <= 0.10, gp
        assert fb["rel_err_max"] <= 0.10, fb
        assert fb["measured_bubble_mean"] < gp["measured_bubble_mean"], \
            (fb["measured_bubble_mean"], gp["measured_bubble_mean"])
        ok = True
    except (AssertionError, ValueError) as e:
        ok = False
        failures.append(f"pipeline: {e}")
    print(f"{'analyzer: measured bubble matches analytic':48s} "
          f"{'OK' if ok else 'FAIL'}")

    # ------------------------------------------------------ serve trace
    sv = traced_serve()
    try:
        stats = validate_trace(sv)
        names = set(stats["names"])
        need = {"queued", "prefill", "decode", "kv_pages",
                "admission_stall", "slo_burn"}
        assert need <= names, f"missing events: {need - names}"
        assert len(find_spans(sv, "queued")) == 4, "lifecycle per request"
        assert len(find_spans(sv, "decode")) == 4, "decode span per request"
        ok = True
    except (AssertionError, ValueError) as e:
        ok = False
        failures.append(f"serve: {e}")
    print(f"{'serve trace: lifecycles + kv pool + slo burn':48s} "
          f"{'OK' if ok else 'FAIL'}")

    if failures:
        print(f"\nobs gate FAILED: {failures}")
        return 1
    print("\nobs gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
