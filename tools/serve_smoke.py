"""`make serve` tier-1 gate: the serving plane end to end on the host
device (plus one 2-virtual-device tensor-parallel cell in a subprocess).

Five checks, all on reduced configs:

  equivalence   paged (page_size=4) and contiguous engines produce the
                seed loop's exact greedy tokens on tinyllama + the
                mixed rglru/ring recurrentgemma stack
  continuous    on a staggered arrival trace with mixed decode budgets,
                continuous batching beats one-shot static batching on
                p99 time-to-first-token AND tokens/s (virtual clock)
  exhaustion    a page pool sized under the working set serves the same
                tokens by stalling admission (no allocation failure)
  autoscale     the Poisson trace -> rate estimate -> replica schedule ->
                sched TraceEvents -> elastic EventPlan loop emits resize
                events and cuts simulated p99 queueing delay
  tp decode     ServeConfig(tp=2) on 2 virtual devices matches the
                single-device token stream bitwise (subprocess)

  PYTHONPATH=src python tools/serve_smoke.py
"""
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.serve.autoscale import (AutoscalePolicy,         # noqa: E402
                                   Autoscaler, ScaleDecision,
                                   poisson_trace, simulate_queue)
from repro.serve.engine import ServeConfig, ServeEngine     # noqa: E402
from repro.serve.request import Request                     # noqa: E402


def seed_loop(model, params, prompt, max_new, max_len):
    B, S0 = prompt.shape
    caches = model.init_cache(B, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, c, tok, pos: model.decode_step(
        p, c, tok, pos, compute_dtype=jnp.float32))
    tokens = jnp.asarray(prompt)
    logits = None
    for t in range(S0):
        logits, caches = step(params, caches, tokens[:, t:t + 1], t)
    V = model.cfg.vocab_size
    for t in range(S0, S0 + max_new):
        nxt = jnp.argmax(logits[..., :V], axis=-1).astype(jnp.int32)
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        logits, caches = step(params, caches, nxt, t)
    return np.asarray(tokens)[:, S0:].tolist()


def run(model, params, prompts, budgets, arrivals, **scfg):
    reqs = [Request(rid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=budgets[i], arrival=arrivals[i])
            for i in range(len(prompts))]
    eng = ServeEngine(model, params, ServeConfig(
        cache_dtype=jnp.float32, compute_dtype=jnp.float32, **scfg))
    m = eng.run(reqs)
    return [r.output for r in reqs], m


def main() -> int:
    failures = []

    # ---------------------------------------------------- equivalence
    for arch in ("tinyllama-1.1b", "recurrentgemma-9b"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        prompts = rng.randint(1, cfg.vocab_size, size=(3, 5))
        ref = seed_loop(model, params, prompts, 6, 16)
        for page in (0, 4):
            out, _ = run(model, params, prompts, [6] * 3, [0.0] * 3,
                         slots=2, max_len=16, page_size=page)
            tag = f"equivalence[{arch},page={page}]"
            ok = out == ref
            print(f"{tag:48s} {'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(tag)

    # ------------------------------------------- continuous vs oneshot
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = rng.randint(1, cfg.vocab_size, size=(6, 5))
    budgets = [3, 10, 4, 9, 5, 8]
    arrivals = [0.0, 0.0, 1.0, 2.0, 3.0, 8.0]
    out1, m1 = run(model, params, prompts, budgets, arrivals,
                   slots=2, max_len=16, page_size=4, policy="oneshot")
    outc, mc = run(model, params, prompts, budgets, arrivals,
                   slots=2, max_len=16, page_size=4, policy="continuous")
    ok = (outc == out1
          and mc["p99_first_token"] < m1["p99_first_token"]
          and mc["tokens_per_s"] >= m1["tokens_per_s"])
    print(f"{'continuous beats oneshot':48s} {'OK' if ok else 'FAIL'} "
          f"(p99 ttft {mc['p99_first_token']:.0f} vs "
          f"{m1['p99_first_token']:.0f}, tok/s {mc['tokens_per_s']:.2f} "
          f"vs {m1['tokens_per_s']:.2f})")
    if not ok:
        failures.append("continuous")

    # ------------------------------------------------------ exhaustion
    ref, _ = run(model, params, prompts[:4], [6] * 4, [0.0] * 4,
                 slots=4, max_len=16, page_size=4)
    out, m = run(model, params, prompts[:4], [6] * 4, [0.0] * 4,
                 slots=4, max_len=16, page_size=4, num_pages=6)
    ok = out == ref and m["admission_stalls"] > 0
    print(f"{'pool exhaustion stalls, same tokens':48s} "
          f"{'OK' if ok else 'FAIL'} ({m['admission_stalls']} stalls)")
    if not ok:
        failures.append("exhaustion")

    # ------------------------------------------------------- autoscale
    arrivals_t = poisson_trace(rate=2.0, horizon=60.0, seed=0)
    pol = AutoscalePolicy(replica_rate=0.5, max_replicas=8, interval=5.0)
    plan, decisions = Autoscaler(pol, jid=0).plan(arrivals_t, horizon=60.0)
    q_fixed = simulate_queue(arrivals_t, [ScaleDecision(0.0, 0.0, 1)],
                             service_time=1.0, horizon=60.0)
    q_auto = simulate_queue(arrivals_t, decisions, service_time=1.0,
                            horizon=60.0)
    ok = (any(e.kind == "resize" for e in plan)
          and q_auto["p99_wait"] < q_fixed["p99_wait"])
    print(f"{'autoscale: resize plan + p99 wait cut':48s} "
          f"{'OK' if ok else 'FAIL'} "
          f"({q_fixed['p99_wait']:.1f}s -> {q_auto['p99_wait']:.1f}s)")
    if not ok:
        failures.append("autoscale")

    # ------------------------------------------------------- tp decode
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    res = subprocess.run([sys.executable, "-c", """
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request
cfg = get_config("tinyllama-1.1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.RandomState(0)
prompts = rng.randint(1, cfg.vocab_size, size=(3, 5))
def go(tp):
    reqs = [Request(rid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=6) for i in range(3)]
    ServeEngine(model, params, ServeConfig(
        slots=2, max_len=16, page_size=4, tp=tp,
        cache_dtype=jnp.float32, compute_dtype=jnp.float32)).run(reqs)
    return [r.output for r in reqs]
assert go(2) == go(1)
print("TP-OK")
"""], env=env, capture_output=True, text=True, timeout=600)
    ok = res.returncode == 0 and "TP-OK" in res.stdout
    print(f"{'tp=2 decode == single device':48s} {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append("tp")
        print(res.stdout[-2000:])
        print(res.stderr[-2000:])

    if failures:
        print(f"\nserve gate FAILED: {failures}")
        return 1
    print("\nserve gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
