"""`make strategies` tier-1 gate: execute EVERY registered Strategy cell.

Each cell of ``repro.train.strategy.registered_cells()`` — the full
sync × arch × compression matrix on both backends — runs for 2 global
steps on a tiny deterministic regression problem with 2 workers; device
cells run on 2 virtual host devices.  The target fails if any registered
cell raises, produces a non-finite loss, or goes unexecuted, and if the
registry ever stops covering the acceptance matrix.

  PYTHONPATH=src python tools/strategy_smoke.py
"""
import os
import sys

# virtual devices must be configured before jax import
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.launch.env import ensure_host_devices  # noqa: E402

ensure_host_devices(2)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.train import Strategy, registered_cells   # noqa: E402
from repro.train.strategy import ACCEPTANCE_CELLS    # noqa: E402

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


# a second leaf exercises the channelwise onebit/dgc reconstruction path
P0 = {"W": jnp.zeros((8, 1)), "b": jnp.zeros((130,))}
STEPS = 2
WORKERS = 2


def main() -> int:
    registry = registered_cells()
    # the registry must keep covering the acceptance matrix — removing a
    # cell from registered_cells() is a test failure, not a silent skip
    missing_required = ACCEPTANCE_CELLS - set(registry)
    if missing_required:
        print(f"FAIL: registry no longer covers the acceptance matrix: "
              f"{sorted(missing_required)}")
        return 1

    executed, failures = set(), []
    for cell in registry:
        strat = Strategy(sync=cell.sync, arch=cell.arch,
                         compression=cell.compression, workers=WORKERS,
                         lr=0.05, staleness=1, density=0.1,
                         backend=cell.backend)
        try:
            engine = strat.build(grad_fn)
            _, hist, wire = engine.run(P0, make_batch, STEPS)
            assert hist, "no history"
            assert all(np.isfinite(h["loss"]) for h in hist), "loss NaN"
            assert wire > 0, "no wire accounting"
            executed.add(cell)
            print(f"ok   {cell.backend:6s} {strat.spec()} "
                  f"({len(hist)} events, {wire} wire B)")
        except Exception as e:  # noqa: BLE001
            failures.append((cell, e))
            print(f"FAIL {cell.backend:6s} {strat.spec()}: {e!r}")

    if failures:
        print(f"FAIL: {len(failures)} of {len(registry)} registered "
              f"cells failing")
        return 1
    print(f"strategies: all {len(executed)} registered cells executed on "
          f"{WORKERS} virtual devices")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
