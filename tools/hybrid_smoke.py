"""`make hybrid` tier-1 gate: representative mesh × ZeRO cells on 8
virtual devices.

Each cell is a short hybrid-parallel training run of the tiny
transformer-FFN reference model (repro.parallel.staged), checked for
finite decreasing loss and wire accounting; the pure-data-parallel mesh
cells are additionally cross-checked against the single-device stacked
reference, and the ZeRO-3 cell asserts the measured per-device
param+optimizer byte reduction.

  PYTHONPATH=src python tools/hybrid_smoke.py
"""
import os
import sys

# virtual devices must be configured before jax import
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.launch.env import ensure_host_devices  # noqa: E402

ensure_host_devices(8)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.parallel import make_tiny_transformer, stacked_grad_fn  # noqa: E402
from repro.train import Strategy                                   # noqa: E402

S_LAYERS, D_MODEL, FF = 2, 8, 16
PARAMS, MODEL = make_tiny_transformer(S_LAYERS, D_MODEL, FF, seed=0)
# 4 stacked layers for the 1F1B cells: an s2 pipeline then holds 2
# layers/device, divisible into the schedule's default v=2 virtual chunks
PARAMS4, MODEL4 = make_tiny_transformer(4, D_MODEL, FF, seed=0)
KEY = jax.random.PRNGKey(1)
W_T = jax.random.normal(KEY, (D_MODEL, D_MODEL))
LR, STEPS = 0.05, 5

# the representative mesh × ZeRO × schedule × precision matrix
# (docs/hybrid.md): every axis exercised alone and composed, every ZeRO
# level, both optimizers and schedules, compression on the data axis
CELLS = (
    "bsp/ring/none@8:d8",                # pure data (trivial mesh path)
    "bsp/ring/none@8:d4.s2",             # data × pipeline
    "bsp/ring/none@8:d4.t2",             # data × tensor
    "bsp/ring/none@8:d2.t2.s2",          # the 3D acceptance mesh
    "bsp/ring/onebit@8:d2.t2.s2",        # 3D + compressed data axis
    "bsp/ps/none@8:d8.z1",               # ZeRO-1 (sgd)
    "bsp/ps/none@8:d8.z2.adamw",         # ZeRO-2 AdamW
    "bsp/ps/none@8:d8.z3.adamw",         # ZeRO-3 AdamW
    "bsp/ps/onebit@8:d2.t2.s2.z3.adamw",  # everything at once
    "bsp/ring/none@8:d2.t2.s2.m8.1f1b",   # interleaved 1F1B schedule
    "bsp/ring/onebit@8:d2.t2.s2.m8.1f1b",  # 1F1B + compressed data axis
    "bsp/ring/none@8:d8.bf16",           # bf16 compute, fp32 master
    "bsp/ring/onebit@8:d8.bf16r",        # bf16 reduce under a codec
    "bsp/ps/none@8:d8.z2.qmom.adamw",    # quantized AdamW moments
    "bsp/ring/none@8:d2.t2.s2.m8.1f1b.bf16.qmom.adamw",  # full stack
)


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    x = jax.random.normal(k, (8, D_MODEL))
    return {"x": x, "y": jnp.tanh(x @ W_T)}


def reference(d_axis: int, model=MODEL, params=PARAMS):
    """Single-device stacked SGD on the concatenated data-axis batches."""
    gf = stacked_grad_fn(model)
    p, losses = params, []
    for t in range(STEPS):
        cat = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                           *[make_batch(t, w) for w in range(d_axis)])
        loss, g = gf(p, cat)
        losses.append(float(loss))
        p = jax.tree.map(lambda a, b: a - LR * b, p, g)
    return losses


def main() -> int:
    failures = []
    refs = {d: reference(d) for d in (2, 4, 8)}
    refs4 = {2: reference(2, MODEL4, PARAMS4)}
    for spec in CELLS:
        strat = Strategy.parse(spec, lr=LR, bucket_mb=1e-4,
                               backend="device")
        # 1F1B cells pipeline the 4-layer model (see PARAMS4 above)
        params, model, model_refs = (
            (PARAMS4, MODEL4, refs4) if strat.schedule == "1f1b"
            else (PARAMS, MODEL, refs))
        try:
            engine = strat.build(model)
            _, hist, wire = engine.run(params, make_batch, STEPS)
            losses = [h["loss"] for h in hist]
            assert all(np.isfinite(losses)), "loss NaN"
            if strat.compressor.method == "none":
                assert losses[-1] < losses[0], "loss not reduced"
            else:
                # error-feedback noise dominates short compressed runs:
                # assert the EF-stability band, not monotone descent
                # (same rationale as the seed-pinned bsp x onebit test)
                assert losses[-1] < losses[0] * 1.5, "EF diverging"
            assert wire > 0, "no wire accounting"
            mets = engine.metrics()
            # uncompressed fp32 sgd cells must match the stacked
            # reference (the 1F1B schedule included — it reorders the
            # same math); bf16 compute holds a loose band instead
            if strat.compressor.method == "none" and \
                    strat.optimizer == "sgd" and strat.zero == 0:
                d = strat.mesh_spec.data
                ref = model_refs[d]
                if strat.precision == "fp32":
                    ld = max(abs(a - b) for a, b in zip(ref, losses))
                    assert ld <= 1e-4, f"diverges from reference: {ld:.2e}"
                else:
                    for a, b in zip(ref, losses):
                        assert abs(a - b) <= 0.25 * abs(a) + 1e-3, \
                            f"bf16 outside the fp32 band: {ref} vs {losses}"
            extra = ""
            if strat.zero == 3:
                st = engine.init(params)
                inner = engine.inner
                b3 = inner.per_device_state_bytes(st)["total"]
                plain = Strategy.parse(
                    "bsp/ring/none@8:d8.adamw" if strat.optimizer ==
                    "adamw" else "bsp/ring/none@8:d8",
                    lr=LR, bucket_mb=1e-4, backend="device").build(MODEL)
                b0 = plain.inner.per_device_state_bytes(
                    plain.inner.init(PARAMS))["total"]
                d = strat.mesh_spec.data
                assert b0 / b3 >= 0.8 * d, \
                    f"ZeRO-3 bytes {b3} vs {b0}: no ~{d}x cut"
                extra = f" state {b0}->{b3} B/dev"
            print(f"ok   {strat.spec():44s} loss {losses[0]:.3f}->"
                  f"{losses[-1]:.3f} wire {wire}{extra}")
        except Exception as e:  # noqa: BLE001
            failures.append((spec, e))
            print(f"FAIL {spec}: {e!r}")

    if failures:
        print(f"FAIL: {len(failures)} of {len(CELLS)} hybrid cells failing")
        return 1
    print(f"hybrid: all {len(CELLS)} mesh x ZeRO cells executed on 8 "
          "virtual devices")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
