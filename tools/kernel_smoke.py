"""`make kernels` tier-1 gate: the kernel backend seam, every codec ×
backend cell on 4 virtual devices, plus one flash-attention forward/decode
cell — all Pallas kernels in interpret mode (this is a CPU correctness
gate; on TPU the same cells run compiled).

For each codec (none / onebit / terngrad / qsgd / dgc) the gate runs the
device engine for 2 BSP steps under ``wire="measured"`` with
``kernel_backend="ref"`` and ``"kernel"`` and asserts:

  * finite losses on both backends;
  * per-step losses agree within 1e-4 (bitwise for ``none``);
  * the measured wire bytes are bitwise identical — the backend knob can
    never change what goes on the wire.

The flash cell checks the training forward (kernel vs jnp oracle), its
reference-math VJP, and the streaming decode kernel against the grouped
jnp decode, full-cache and ring-window.

  PYTHONPATH=src python tools/kernel_smoke.py
"""
import os
import sys

# virtual devices must be configured before jax import
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.launch.env import ensure_host_devices  # noqa: E402

ensure_host_devices(4)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.kernels import flash_attention as FA     # noqa: E402
from repro.train import Strategy                    # noqa: E402

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (64, 1))
WORKERS = 4
STEPS = 2
CODECS = ("none", "onebit", "terngrad", "qsgd", "dgc")


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 64))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


P0 = {"W": jnp.zeros((64, 1)), "b": jnp.zeros((4096,))}


def codec_cells() -> None:
    for comp in CODECS:
        runs = {}
        for kb in ("ref", "kernel"):
            spec = f"bsp/ring/{comp}@{WORKERS}"
            eng = Strategy.parse(spec, lr=0.05, backend="device",
                                 wire="measured",
                                 kernel_backend=kb).build(grad_fn)
            runs[kb] = eng.run(P0, make_batch, STEPS)
        lr_ = [h["loss"] for h in runs["ref"][1]]
        lk = [h["loss"] for h in runs["kernel"][1]]
        assert all(np.isfinite(x) for x in lr_ + lk), comp
        if comp == "none":
            assert lr_ == lk, (comp, lr_, lk)
        else:
            ld = max(abs(a - b) for a, b in zip(lr_, lk))
            assert ld <= 1e-4, (comp, lr_, lk)
        assert runs["ref"][2] == runs["kernel"][2], (
            comp, runs["ref"][2], runs["kernel"][2])
        print(f"  codec {comp:9s} ref=kernel wire={runs['ref'][2]}  OK")


def flash_cell() -> None:
    ks = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = FA.attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = FA.attention_ref(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4

    g_k = jax.grad(lambda q: jnp.sum(
        FA.attention_grad(q, k, v, causal=True) ** 2))(q)
    g_r = jax.grad(lambda q: jnp.sum(
        FA.attention_ref(q, k, v, causal=True) ** 2))(q)
    assert float(jnp.max(jnp.abs(g_k - g_r))) < 1e-4

    qd = jax.random.normal(ks[0], (B, 1, H, hd))
    ck = jax.random.normal(ks[1], (B, 16, KV, hd))
    cv = jax.random.normal(ks[2], (B, 16, KV, hd))
    for window, pos in ((0, 11), (16, 23)):
        o_k = FA.decode(qd, ck, cv, jnp.int32(pos), window=window,
                        block_k=8)
        o_r = FA.decode_ref(qd, ck, cv, jnp.int32(pos), window=window)
        assert float(jnp.max(jnp.abs(o_k - o_r))) < 1e-5, window
    print("  flash fwd/grad/decode kernel=ref  OK")


def main() -> None:
    print(f"kernel backend seam gate: {len(CODECS)} codecs x 2 backends "
          f"on {WORKERS} devices + flash cell")
    codec_cells()
    flash_cell()
    print("kernel smoke OK")


if __name__ == "__main__":
    main()
