"""`make comm` tier-1 gate: the unified communication plane, every
topology × codec cell, on 4 virtual devices.

For each topology (ring / tree / butterfly / fully_connected) × codec
(none / onebit / terngrad / qsgd / dgc) the gate runs the device engine
for 2 BSP steps under ``wire="measured"`` — encoded payloads inside the
schedule — and asserts:

  * finite losses and positive wire accounting;
  * the measured-vs-modeled agreement: the engine's shape-static
    per-worker tx bytes equal the critical-path model
    ``per_device_bytes`` divided by the documented ``model_error_factor``
    within 25% (side-info slack; exact for the none codec);
  * ``none`` executes bitwise-identically under modeled and measured
    modes (the legacy schedules ARE the exact path);
  * compressed cells put strictly fewer bytes on the wire than fp32.

  PYTHONPATH=src python tools/comm_smoke.py
"""
import os
import sys

# virtual devices must be configured before jax import
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.launch.env import ensure_host_devices  # noqa: E402

ensure_host_devices(4)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.comm.codecs import make_codec                    # noqa: E402
from repro.comm.transport import (model_error_factor,       # noqa: E402
                                  pad_for_schedule, per_device_bytes)
from repro.train import Strategy                            # noqa: E402

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (64, 1))
WORKERS = 4
STEPS = 2
TOPOLOGIES = ("ring", "tree", "butterfly", "fully_connected")
CODECS = ("none", "onebit", "terngrad", "qsgd", "dgc")


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 64))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


P0 = {"W": jnp.zeros((64, 1)), "b": jnp.zeros((4096,))}


def check_measured_vs_model(engine, topology, method) -> None:
    """The engine's static per-worker tx bytes must match the topology's
    critical-path model through the documented error factor."""
    plan = engine.inner._plan
    codec = (make_codec("none") if method in ("none",)
             else plan.codec)
    expect = 0.0
    for b in range(len(plan.buckets)):
        P = pad_for_schedule(plan.bucket_len(b), WORKERS)
        model = per_device_bytes(topology, WORKERS,
                                 codec.static_tx_bytes(P))
        expect += model / model_error_factor(topology, WORKERS,
                                             exact=(method == "none"))
    got = engine.metrics()["measured_step_tx_bytes"]
    rel = abs(got - expect) / max(expect, 1.0)
    tol = 1e-6 if method == "none" else 0.25
    assert rel <= tol, (topology, method, got, expect, rel)


def main() -> int:
    failures = []
    for topology in TOPOLOGIES:
        fp32_wire = None
        for method in CODECS:
            spec = f"bsp/{topology}/{method}@{WORKERS}"
            if method == "dgc":
                spec = f"bsp/{topology}/dgc:0.1@{WORKERS}"
            try:
                eng = Strategy.parse(spec, lr=0.05, backend="device",
                                     wire="measured").build(grad_fn)
                _, hist, wire = eng.run(P0, make_batch, STEPS)
                assert hist and all(np.isfinite(h["loss"]) for h in hist)
                assert wire > 0
                check_measured_vs_model(eng, topology, method)
                if method == "none":
                    fp32_wire = wire
                    # bitwise: modeled and measured run the same program
                    pm, hm, _ = Strategy.parse(
                        spec, lr=0.05, backend="device",
                        wire="modeled").build(grad_fn).run(
                            P0, make_batch, STEPS)
                    assert [h["loss"] for h in hm] == \
                           [h["loss"] for h in hist], "none not bitwise"
                else:
                    assert wire < fp32_wire, (wire, fp32_wire)
                print(f"ok   {spec:34s} wire {wire:>9d} B "
                      f"(fp32 {fp32_wire} B)")
            except Exception as e:  # noqa: BLE001
                failures.append((spec, e))
                print(f"FAIL {spec}: {e!r}")
    if failures:
        print(f"FAIL: {len(failures)} comm cells failing")
        return 1
    print(f"comm: all {len(TOPOLOGIES) * len(CODECS)} topology x codec "
          f"cells executed on {WORKERS} virtual devices (wire=measured)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
