"""`make bench-regress` tier-1 gate: the cross-PR benchmark lineage.

Diffs the newest committed ``BENCH_pr<N>.json`` snapshot (or a fresh
rows file via ``--current``) against the older snapshots on the keyed
deterministic metrics in ``repro.obs.regress.METRIC_BANDS`` — wire
bytes, seeded loss bands, modeled step times, virtual-clock serve
latencies — and fails loudly on out-of-band drift, so the per-PR bench
snapshots ROADMAP mandates are read on every tier-1 run instead of
being write-only.

  PYTHONPATH=src python tools/bench_regress.py
  PYTHONPATH=src python tools/bench_regress.py --current fresh.json
  PYTHONPATH=src python tools/bench_regress.py --json
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs.regress import format_report, run_gate  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Cross-PR BENCH_pr<N>.json regression gate "
                    "(docs/observability.md).")
    ap.add_argument("--root", default=REPO,
                    help="directory holding BENCH_pr<N>.json snapshots")
    ap.add_argument("--current", default=None, metavar="ROWS.json",
                    help="compare this fresh rows file against the full "
                         "lineage instead of the newest snapshot")
    ap.add_argument("--json", action="store_true",
                    help="emit the raw report dict as JSON")
    args = ap.parse_args()
    report = run_gate(args.root, current_path=args.current)
    if args.json:
        print(json.dumps(report, sort_keys=True))
    else:
        print(format_report(report))
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    sys.exit(main())
