"""`make elastic` tier-1 gate: crash, resize, straggler, and a
scheduler-driven elastic run on 2 virtual devices.

Four scenarios, each a full ``fit_elastic`` run on a tiny deterministic
regression problem:

  crash      device bsp/allreduce/onebit@2 loses worker 1 mid-run,
             recovers from checkpoint, reshards 2→1 in process
  resize     device ssp:1/allreduce/none@2 shrinks 2→1 and grows back
             1→2 live (no rollback), rebasing the update accounting
  straggler  bsp+backup:1/allreduce/none@2 with a slow:w0 event — the
             drop set must follow the slowdown and the dropped pushes
             must be accounted
  scheduler  a sched/ simulator trace (gandiva + elastic allocation)
             converted by plan_from_sched_trace drives a sim-backend run

  PYTHONPATH=src python tools/elastic_smoke.py
"""
import os
import sys
import tempfile

# virtual devices must be configured before jax import
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.launch.env import ensure_host_devices  # noqa: E402

ensure_host_devices(2)

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.elastic import EventPlan, plan_from_sched_trace   # noqa: E402
from repro.sched import Cluster, make_trace, simulate        # noqa: E402
from repro.train import Strategy, Trainer                    # noqa: E402

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


P0 = {"W": jnp.zeros((8, 1)), "b": jnp.zeros((130,))}
STEPS = 8


def run(name, spec, plan, backend="device", check=None):
    strat = Strategy.parse(spec, lr=0.05, staleness=1, bucket_mb=1e-4,
                           backend=backend)
    with tempfile.TemporaryDirectory() as d:
        params, hist, mets = Trainer(strat).fit(
            grad_fn, P0, make_batch, STEPS, plan=plan,
            checkpoint_dir=d, checkpoint_every=2)
    assert hist, f"{name}: no history"
    assert all(np.isfinite(h["loss"]) for h in hist), f"{name}: loss NaN"
    assert hist[-1]["loss"] < hist[0]["loss"], f"{name}: loss not reduced"
    if check:
        check(mets)
    print(f"ok   {name:10s} {mets['spec']:28s} "
          f"recoveries={len(mets['recoveries'])} resizes={mets['resizes']} "
          f"dropped={mets['dropped_updates']} "
          f"final_workers={mets['final_workers']}")
    return mets


def main() -> int:
    failures = []
    scenarios = [
        ("crash", "bsp/allreduce/onebit@2", "crash:w1@3", "device",
         lambda m: len(m["recoveries"]) == 1 and m["final_workers"] == 1),
        ("resize", "ssp:1/allreduce/none@2", "resize:1@3,resize:2@6",
         "device", lambda m: m["resizes"] == 2 and m["final_workers"] == 2),
        ("straggler", "bsp+backup:1/allreduce/none@2", "slow:w0x4@2",
         "device", lambda m: m["dropped_updates"] == STEPS),
    ]
    for name, spec, plan, backend, check in scenarios:
        try:
            mets = run(name, spec, plan, backend)
            assert check(mets), f"{name}: check failed on {mets}"
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"FAIL {name}: {e!r}")

    # scheduler-driven: gandiva slicing + elastic allocation produce a
    # suspend/resume/resize trace; the adapter turns it into a plan that
    # drives a real (simulated-backend) training run end to end
    try:
        jobs = make_trace(12, 8, seed=3, mean_interarrival=20.0)
        res = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=4),
                       policy="fifo", gandiva=True, elastic=True)
        assert any(e.kind == "suspend" for e in res.trace), "no suspends"
        plan = None
        for j in jobs:
            full = plan_from_sched_trace(res.trace, j.jid,
                                         steps_per_sec=0.005)
            due = [e for e in full if e.step < STEPS
                   and (e.kind != "resize" or e.workers <= 2)]
            if due:
                # keep the smoke fast: the first couple of decisions
                plan = EventPlan(due[:2])
                break
        assert plan is not None, "no usable job trace"
        print(f"     scheduler plan: {plan.spec()}")
        run("scheduler", "ssp:1/allreduce/none@2", plan, backend="sim")
    except Exception as e:  # noqa: BLE001
        failures.append(("scheduler", e))
        print(f"FAIL scheduler: {e!r}")

    if failures:
        print(f"FAIL: {len(failures)} elastic scenarios failing")
        return 1
    print("elastic: all scenarios survived on 2 virtual devices")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
