"""Model-data management tests (survey §3.5.2): roundtrip, sharding,
atomic (crash-safe) writes, manifest extra blob, and the registry."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (ModelRegistry, is_valid_checkpoint,
                              load_checkpoint, read_manifest,
                              save_checkpoint)


def _tree(key):
    ks = jax.random.split(key, 3)
    return {"embed": jax.random.normal(ks[0], (64, 16)),
            "layers": [{"w": jax.random.normal(ks[1], (16, 16)),
                        "b": jnp.zeros((16,))},
                       {"w": jax.random.normal(ks[2], (16, 16)),
                        "b": jnp.ones((16,))}],
            "step_scale": jnp.float32(0.5)}


def test_save_load_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    manifest = save_checkpoint(str(tmp_path / "ckpt"), tree, step=42)
    assert manifest["shards"] >= 1
    restored, step = load_checkpoint(str(tmp_path / "ckpt"), tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharding_by_size(tmp_path):
    tree = {"big": jnp.ones((1000, 100)), "small": jnp.ones((10,))}
    manifest = save_checkpoint(str(tmp_path / "c"), tree, shard_bytes=100_000)
    assert manifest["shards"] >= 2       # 400KB leaf forces multiple shards
    restored, _ = load_checkpoint(str(tmp_path / "c"), tree)
    assert float(restored["big"].sum()) == 100_000


def test_atomic_save_crash_leaves_old_checkpoint_intact(tmp_path,
                                                        monkeypatch):
    """A crash mid-save (np.savez raising) must not tear the previous
    checkpoint: writes stage in a temp dir and commit via os.replace."""
    path = str(tmp_path / "ckpt")
    old = {"w": jnp.arange(8.0)}
    save_checkpoint(path, old, step=7)

    real_savez = np.savez

    def exploding_savez(file, **arrs):
        raise IOError("disk died mid-save")

    monkeypatch.setattr(np, "savez", exploding_savez)
    with pytest.raises(IOError):
        save_checkpoint(path, {"w": jnp.zeros(8)}, step=8)
    monkeypatch.setattr(np, "savez", real_savez)

    # no stray staging dirs, and the old checkpoint still loads
    assert os.listdir(str(tmp_path)) == ["ckpt"]
    assert is_valid_checkpoint(path)
    restored, step = load_checkpoint(path, old)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))


def test_atomic_save_replaces_existing_checkpoint(tmp_path):
    path = str(tmp_path / "c")
    save_checkpoint(path, {"w": jnp.zeros(4)}, step=1)
    save_checkpoint(path, {"w": jnp.ones(4)}, step=2)
    restored, step = load_checkpoint(path, {"w": jnp.zeros(4)})
    assert step == 2
    assert float(np.asarray(restored["w"]).sum()) == 4.0


def test_manifest_extra_roundtrip(tmp_path):
    path = str(tmp_path / "c")
    extra = {"num_workers": 3, "tick": 17, "batch_idx": [4, 2, 0]}
    save_checkpoint(path, {"w": jnp.zeros(4)}, step=5, extra=extra)
    man = read_manifest(path)
    assert man["step"] == 5
    assert man["extra"] == extra
    assert not is_valid_checkpoint(str(tmp_path / "nope"))


def test_registry_query_and_lineage(tmp_path):
    reg = ModelRegistry(str(tmp_path / "reg"))
    a = reg.register("lm", "/ck/a", arch="tinyllama-1.1b",
                     metrics={"loss": 3.2}, hyperparams={"lr": 1e-3})
    b = reg.register("lm", "/ck/b", arch="tinyllama-1.1b",
                     metrics={"loss": 2.8}, parent=a)
    c = reg.register("other", "/ck/c", arch="rwkv6-7b",
                     metrics={"loss": 9.0})
    assert reg.get(b)["version"] == 1
    assert len(reg.query(name="lm")) == 2
    assert reg.query(arch="rwkv6-7b")[0]["id"] == c
    assert reg.lineage(b) == [b, a]
    assert reg.best("lm", "loss", maximize=False)["id"] == b


def test_registry_persistence(tmp_path):
    root = str(tmp_path / "reg2")
    reg = ModelRegistry(root)
    reg.register("m", "/x", metrics={"acc": 0.9})
    reg2 = ModelRegistry(root)       # reload from disk
    assert len(reg2.query(name="m")) == 1
