"""Model-data management tests (survey §3.5.2)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ModelRegistry, load_checkpoint, save_checkpoint


def _tree(key):
    ks = jax.random.split(key, 3)
    return {"embed": jax.random.normal(ks[0], (64, 16)),
            "layers": [{"w": jax.random.normal(ks[1], (16, 16)),
                        "b": jnp.zeros((16,))},
                       {"w": jax.random.normal(ks[2], (16, 16)),
                        "b": jnp.ones((16,))}],
            "step_scale": jnp.float32(0.5)}


def test_save_load_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    manifest = save_checkpoint(str(tmp_path / "ckpt"), tree, step=42)
    assert manifest["shards"] >= 1
    restored, step = load_checkpoint(str(tmp_path / "ckpt"), tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharding_by_size(tmp_path):
    tree = {"big": jnp.ones((1000, 100)), "small": jnp.ones((10,))}
    manifest = save_checkpoint(str(tmp_path / "c"), tree, shard_bytes=100_000)
    assert manifest["shards"] >= 2       # 400KB leaf forces multiple shards
    restored, _ = load_checkpoint(str(tmp_path / "c"), tree)
    assert float(restored["big"].sum()) == 100_000


def test_registry_query_and_lineage(tmp_path):
    reg = ModelRegistry(str(tmp_path / "reg"))
    a = reg.register("lm", "/ck/a", arch="tinyllama-1.1b",
                     metrics={"loss": 3.2}, hyperparams={"lr": 1e-3})
    b = reg.register("lm", "/ck/b", arch="tinyllama-1.1b",
                     metrics={"loss": 2.8}, parent=a)
    c = reg.register("other", "/ck/c", arch="rwkv6-7b",
                     metrics={"loss": 9.0})
    assert reg.get(b)["version"] == 1
    assert len(reg.query(name="lm")) == 2
    assert reg.query(arch="rwkv6-7b")[0]["id"] == c
    assert reg.lineage(b) == [b, a]
    assert reg.best("lm", "loss", maximize=False)["id"] == b


def test_registry_persistence(tmp_path):
    root = str(tmp_path / "reg2")
    reg = ModelRegistry(root)
    reg.register("m", "/x", metrics={"acc": 0.9})
    reg2 = ModelRegistry(root)       # reload from disk
    assert len(reg2.query(name="m")) == 1
