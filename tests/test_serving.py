"""Serving plane: continuous batching over a paged KV cache.

Invariants under test:
  * paged decode is token-identical to contiguous decode (same math,
    different memory layout);
  * the engine (batched prefill + per-slot vmapped decode) reproduces the
    seed's token-by-token warm-up loop bitwise for greedy decode;
  * continuous batching never changes a request's tokens versus one-shot
    static batching — only its latency;
  * an over-subscribed page pool stalls admission (and recovers) instead
    of failing allocation;
  * tensor-parallel decode produces the single-device token stream;
  * sampling is reproducible per request seed, and top_k=1 == greedy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.autoscale import (AutoscalePolicy, Autoscaler, ScaleDecision,
                                   poisson_trace, simulate_queue)
from repro.serve.batcher import Batcher
from repro.serve.cache import BlockAllocator, make_kv_store
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request, SamplingParams
from repro.serve.serve_loop import generate

_CACHE = {}


def small_model(arch="tinyllama-1.1b"):
    if arch not in _CACHE:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _CACHE[arch] = (model, params)
    return _CACHE[arch]


def seed_loop(model, params, prompt, max_new, max_len, window_override=0):
    """The seed's generate(): token-by-token cache warm-up, greedy."""
    B, S0 = prompt.shape
    caches = model.init_cache(B, max_len, dtype=jnp.float32,
                              window_override=window_override)
    step = jax.jit(lambda p, c, tok, pos: model.decode_step(
        p, c, tok, pos, compute_dtype=jnp.float32,
        window_override=window_override))
    tokens = jnp.asarray(prompt)
    logits = None
    for t in range(S0):
        logits, caches = step(params, caches, tokens[:, t:t + 1], t)
    V = model.cfg.vocab_size
    for t in range(S0, S0 + max_new):
        nxt = jnp.argmax(logits[..., :V], axis=-1).astype(jnp.int32)
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        logits, caches = step(params, caches, nxt, t)
    return np.asarray(tokens)


def make_requests(prompts, max_new, arrivals=None, sampling=None):
    per_req = max_new if isinstance(max_new, (list, tuple)) \
        else [max_new] * len(prompts)
    return [Request(rid=i, prompt=[int(t) for t in p],
                    max_new_tokens=per_req[i],
                    arrival=0.0 if arrivals is None else arrivals[i],
                    sampling=sampling or SamplingParams())
            for i, p in enumerate(prompts)]


def run_engine(model, params, reqs, **scfg):
    eng = ServeEngine(model, params, ServeConfig(
        cache_dtype=jnp.float32, compute_dtype=jnp.float32, **scfg))
    metrics = eng.run(reqs)
    return [r.output for r in reqs], metrics


# --------------------------------------------------------------- allocator
def test_block_allocator_reuse_and_errors():
    a = BlockAllocator(num_pages=8, reserved=1)     # 7 usable
    p1 = a.alloc(4)
    assert a.free_pages == 3 and not a.can_alloc(4)
    with pytest.raises(MemoryError):
        a.alloc(4)
    a.free(p1)
    assert a.free_pages == 7
    p2 = a.alloc(7)                                 # freed pages are reused
    assert sorted(p2) == list(range(1, 8))
    with pytest.raises(ValueError):
        a.free([0])                                 # null page is reserved
    a.free(p2)
    with pytest.raises(ValueError):
        a.free([p2[0]])                             # double free


# ---------------------------------------------------- paged == contiguous
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-v2-lite-16b",
                                  "recurrentgemma-9b"])
def test_paged_matches_contiguous(arch):
    """Same tokens from page pools and from per-slot contiguous rows —
    covers full k/v, MLA latent, and mixed rglru+ring-buffer caches."""
    model, params = small_model(arch)
    rng = np.random.RandomState(0)
    prompts = rng.randint(1, model.cfg.vocab_size, size=(3, 5))
    out_c, _ = run_engine(model, params, make_requests(prompts, 6),
                          slots=2, max_len=16)
    out_p, m = run_engine(model, params, make_requests(prompts, 6),
                          slots=2, max_len=16, page_size=4)
    assert out_c == out_p
    assert m["paged"] and m["completed"] == 3


def test_engine_matches_seed_loop_bitwise():
    """Batched prefill + vmapped decode == the seed token-by-token loop."""
    model, params = small_model()
    rng = np.random.RandomState(1)
    prompts = rng.randint(1, model.cfg.vocab_size, size=(3, 7))
    ref = seed_loop(model, params, prompts, 6, 16)[:, 7:].tolist()
    for page_size in (0, 4):
        out, _ = run_engine(model, params, make_requests(prompts, 6),
                            slots=3, max_len=16, page_size=page_size)
        assert out == ref


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-9b"])
def test_generate_compat_bitwise(arch):
    """generate() (now a thin engine wrapper) reproduces the seed loop's
    tokens exactly, prompt included."""
    model, params = small_model(arch)
    rng = np.random.RandomState(2)
    prompt = rng.randint(1, model.cfg.vocab_size, size=(2, 5))
    ref = seed_loop(model, params, prompt, 6, 11)
    out = np.asarray(generate(model, params, jnp.asarray(prompt), 6))
    np.testing.assert_array_equal(out, ref)


def test_generate_compat_window_override():
    model, params = small_model()
    rng = np.random.RandomState(3)
    prompt = rng.randint(1, model.cfg.vocab_size, size=(2, 6))
    ref = seed_loop(model, params, prompt, 5, 11, window_override=4)
    out = np.asarray(generate(model, params, jnp.asarray(prompt), 5,
                              window_override=4))
    np.testing.assert_array_equal(out, ref)


# ------------------------------------------------- continuous vs one-shot
def test_continuous_matches_oneshot_tokens_and_beats_latency():
    """Iteration-level admission changes WHEN a request is served, never
    WHAT it generates; on a staggered open-loop trace it strictly beats
    static batching on p99 time-to-first-token (the 2209.01341 claim)."""
    model, params = small_model()
    rng = np.random.RandomState(4)
    prompts = rng.randint(1, model.cfg.vocab_size, size=(6, 5))
    arrivals = [0.0, 0.0, 1.0, 2.0, 3.0, 8.0]
    # mixed decode lengths: one-shot waves are gated by their slowest
    # member, continuous refills each slot the moment it frees
    budgets = [3, 10, 4, 9, 5, 8]
    out_1, m_1 = run_engine(
        model, params, make_requests(prompts, budgets, arrivals),
        slots=2, max_len=16, page_size=4, policy="oneshot")
    out_c, m_c = run_engine(
        model, params, make_requests(prompts, budgets, arrivals),
        slots=2, max_len=16, page_size=4, policy="continuous")
    assert out_c == out_1
    assert m_c["p99_first_token"] < m_1["p99_first_token"]
    assert m_c["tokens_per_s"] >= m_1["tokens_per_s"]


# ------------------------------------------------------- pool exhaustion
def test_pool_exhaustion_stalls_admission_not_oom():
    """A pool sized for ~1.5 requests serves 4 slots' worth of work by
    stalling admission until pages free up — same tokens, some stalls."""
    model, params = small_model()
    rng = np.random.RandomState(5)
    prompts = rng.randint(1, model.cfg.vocab_size, size=(4, 5))
    ref, _ = run_engine(model, params, make_requests(prompts, 6),
                        slots=4, max_len=16, page_size=4)
    # each request reserves ceil(11/4)=3 pages; 5-page pool fits one
    # request (plus a stalled head) at a time
    out, m = run_engine(model, params, make_requests(prompts, 6),
                        slots=4, max_len=16, page_size=4, num_pages=6)
    assert out == ref
    assert m["admission_stalls"] > 0 and m["completed"] == 4
    assert m["clock"] > 0 and m["p99_first_token"] > 1.0


def test_oversized_request_rejected():
    model, params = small_model()
    eng = ServeEngine(model, params, ServeConfig(slots=1, max_len=8,
                                                 page_size=4))
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=32))
    with pytest.raises(ValueError, match="can never be served"):
        eng.run()


# ------------------------------------------------------------- sampling
def test_sampling_topk1_is_greedy_and_seeded_runs_reproduce():
    model, params = small_model()
    rng = np.random.RandomState(6)
    prompts = rng.randint(1, model.cfg.vocab_size, size=(2, 5))
    greedy, _ = run_engine(model, params, make_requests(prompts, 8),
                           slots=2, max_len=16, page_size=4)
    topk1, _ = run_engine(
        model, params,
        make_requests(prompts, 8,
                      sampling=SamplingParams(temperature=1.0, top_k=1)),
        slots=2, max_len=16, page_size=4)
    assert topk1 == greedy                    # top-1 collapses to argmax

    sp = SamplingParams(temperature=1.0, seed=7)
    a, _ = run_engine(model, params, make_requests(prompts, 8, sampling=sp),
                      slots=2, max_len=16, page_size=4)
    b, _ = run_engine(model, params, make_requests(prompts, 8, sampling=sp),
                      slots=2, max_len=16, page_size=4)
    assert a == b                             # explicit key -> reproducible
    c, _ = run_engine(
        model, params,
        make_requests(prompts, 8,
                      sampling=SamplingParams(temperature=1.0, seed=8)),
        slots=2, max_len=16, page_size=4)
    assert c != a                             # a different seed diverges


# ------------------------------------------------------------ TP decode
def test_tp_decode_matches_single_device(multidevice):
    multidevice("""
import jax, numpy as np
import jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request

cfg = get_config("tinyllama-1.1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.RandomState(0)
prompts = rng.randint(1, cfg.vocab_size, size=(3, 5))

def run(tp):
    reqs = [Request(rid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=6) for i in range(3)]
    ServeEngine(model, params, ServeConfig(
        slots=2, max_len=16, page_size=4, tp=tp,
        cache_dtype=jnp.float32, compute_dtype=jnp.float32)).run(reqs)
    return [r.output for r in reqs]

assert run(2) == run(1), "tp=2 token stream != single-device"
print("TP-SERVE-OK")
""", n_devices=2)


def test_tp_rejects_unsupported_archs():
    from repro.serve.tp import check_tp_supported
    with pytest.raises(ValueError):
        check_tp_supported(get_config("deepseek-v2-lite-16b").reduced(), 2)
    with pytest.raises(ValueError):
        check_tp_supported(get_config("rwkv6-7b").reduced(), 2)
    with pytest.raises(ValueError):   # tp must divide kv heads
        check_tp_supported(get_config("tinyllama-1.1b").reduced(), 3)


# ------------------------------------------------------------ autoscaler
def test_autoscaler_tracks_load_and_cuts_queueing():
    arrivals = poisson_trace(rate=2.0, horizon=60.0, seed=0)
    pol = AutoscalePolicy(replica_rate=0.5, min_replicas=1, max_replicas=8,
                          interval=5.0, scale_down_patience=2)
    plan, decisions = Autoscaler(pol, jid=3).plan(arrivals, horizon=60.0,
                                                  steps_per_sec=2.0)
    assert decisions[0].replicas == 1
    assert max(d.replicas for d in decisions) > 1       # scaled up
    assert any(e.kind == "resize" for e in plan)        # sched->elastic
    fixed = [ScaleDecision(0.0, 0.0, 1)]
    q_fixed = simulate_queue(arrivals, fixed, service_time=1.0, horizon=60.0)
    q_auto = simulate_queue(arrivals, decisions, service_time=1.0,
                            horizon=60.0)
    assert q_auto["p99_wait"] < q_fixed["p99_wait"]


def test_autoscaler_scale_down_hysteresis():
    """A burst then silence: scale-up is immediate, scale-down waits out
    ``scale_down_patience`` decision intervals."""
    arrivals = [float(t) * 0.1 for t in range(100)]     # 10 req/s for 10s
    pol = AutoscalePolicy(replica_rate=2.0, min_replicas=1, max_replicas=8,
                          interval=5.0, scale_down_patience=2)
    decisions = Autoscaler(pol, jid=0, window=10.0).schedule(arrivals, 40.0)
    ups = [d for d in decisions if d.replicas > 1]
    assert ups and ups[0].t <= 10.0
    downs = [d for d in decisions if d.replicas == 1 and d.t > 0]
    assert downs and downs[0].t >= 20.0     # not at the first quiet tick


# ----------------------------------------------------------- batch admin
def test_oneshot_admits_only_when_idle():
    model, params = small_model()
    kv = make_kv_store(model, slots=2, max_len=16, page_size=4)
    b = Batcher(kv, slots=2, policy="oneshot")
    for i in range(3):
        b.submit(Request(rid=i, prompt=[1, 2], max_new_tokens=2))
    first = b.admit(0.0)
    assert len(first) == 2 and b.admit(0.0) == []       # batch is busy
    from repro.serve.request import RequestState
    for r in first:
        r.state = RequestState.DONE
        b.release(r)
    assert len(b.admit(0.0)) == 1                       # next wave
