"""Per-architecture smoke tests (deliverable f): reduced variant of each
family, one forward + one train step + one decode step on CPU; asserts
output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.optim import Adam

B, S = 2, 16


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.max_source_positions, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(key, (B, 4, cfg.d_model))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (B, 3, S))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    loss, mets = model.loss_fn(params, batch, compute_dtype=jnp.float32)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    opt = Adam()
    opt_state = opt.init(params)

    @jax.jit
    def step(p, o):
        (l, _), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, batch, compute_dtype=jnp.float32),
            has_aux=True)(p)
        return opt.step(p, g, o, 1e-3) + (l,)

    new_p, new_o, l = step(params, opt_state)
    assert bool(jnp.isfinite(l))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(new_p),
                                jax.tree.leaves(params)))
    assert delta > 0, arch
    # no NaNs anywhere
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(new_p)
               if jnp.issubdtype(x.dtype, jnp.floating)), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    vpad = cfg.padded_vocab(1)
    if cfg.is_encoder_decoder:
        from repro.models import whisper as W
        frames = jax.random.normal(key,
                                   (B, cfg.max_source_positions, cfg.d_model))
        enc = W.encode(params, cfg, frames, compute_dtype=jnp.float32)
        caches = model.init_cache(B, 8, dtype=jnp.float32)
        caches["cross"] = W.build_cross_cache(params, cfg, enc,
                                              dtype=jnp.float32)
    else:
        caches = model.init_cache(B, 8, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_caches = model.decode_step(params, caches, tok, 0,
                                           compute_dtype=jnp.float32)
    assert logits.shape == (B, 1, vpad)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # cache structure preserved
    assert (jax.tree.structure(new_caches) == jax.tree.structure(caches))
