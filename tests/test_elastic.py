"""Elastic fault-tolerance tests (ISSUE 3 tentpole).

Covers: the event-plan grammar and consume-once cursor, the backup-worker
drop policy and its Strategy grammar, bitwise save→restore→resume on the
sim backend, N→M→N resize within the documented loss tolerance, crash
rollback bookkeeping, the scheduler-trace adapter, and — in a 4-device
subprocess — the acceptance scenario (`ssp:2/ring/onebit@4` loses a
worker at step 5, is resized back at step 10, recovers from checkpoint
and reshards without restarting the process) plus device-backend bitwise
resume and sim↔device backup cross-validation.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.partition import stream_assignment
from repro.elastic import (ElasticEvent, EventPlan, FailurePlan,
                           ResizePlan, StragglerPlan, drop_set,
                           latest_checkpoint, merge_plans,
                           participation_weights, plan_from_sched_trace,
                           restore_engine_state, save_engine_state)
from repro.sched import Cluster, TraceEvent, make_trace, simulate
from repro.train import Strategy, Trainer

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


# second leaf exercises the channelwise onebit reconstruction path
P0 = {"W": jnp.zeros((8, 1)), "b": jnp.zeros((130,))}


# ------------------------------------------------------------ event plans
def test_plan_parse_spec_roundtrip():
    spec = "restart@3,crash:w1@5,slow:w2x3.5@7,resize:4@10"
    plan = EventPlan.parse(spec)
    assert plan.spec() == spec
    assert EventPlan.parse(plan.spec()).spec() == spec
    assert len(plan) == 4
    assert plan.needs_checkpoints


def test_plan_rejects_bad_items():
    for bad in ("crash:w1", "crash:1@5", "resize:0@5", "slow:w1@3",
                "slow:w1x0@3", "warp:w1@3", "crash:w1@-1"):
        with pytest.raises(ValueError):
            EventPlan.parse(bad)


def test_typed_plans_merge():
    plan = merge_plans(FailurePlan(crashes=((5, 1),)),
                       ResizePlan(resizes=((10, 4),)),
                       StragglerPlan(slows=((2, 0, 3.0),)))
    assert [e.kind for e in plan] == ["slow", "crash", "resize"]
    assert plan.spec() == "slow:w0x3@2,crash:w1@5,resize:4@10"


def test_plan_run_consumes_each_event_once():
    run = EventPlan.parse("slow:w0x2@3,crash:w1@5").start()
    assert run.take_one(2) is None
    ev = run.take_one(5)
    assert ev.kind == "slow"            # due events come in plan order
    ev = run.take_one(5)
    assert ev.kind == "crash"
    # after a rollback to step 0, consumed events do not re-fire
    assert run.take_one(5) is None
    assert not run.pending


# ---------------------------------------------------------- backup policy
def test_drop_set_deterministic_and_slowdown_aware():
    periods = (1, 2, 3, 4)
    assert drop_set(periods, 0) == frozenset()
    assert drop_set(periods, 1) == frozenset({3})
    assert drop_set(periods, 2) == frozenset({2, 3})
    # ties break toward the higher worker id
    assert drop_set((2, 2, 2), 1) == frozenset({2})
    # an active slowdown can make an otherwise-fast worker the straggler
    assert drop_set(periods, 1, slowdowns=[10.0, 1, 1, 1]) == frozenset({0})
    with pytest.raises(ValueError):
        drop_set(periods, 4)


def test_participation_weights_mean_preserving():
    w = participation_weights(4, frozenset({3}))
    np.testing.assert_allclose(w, [4 / 3, 4 / 3, 4 / 3, 0.0])
    assert participation_weights(4, frozenset()).tolist() == [1.0] * 4


def test_backup_spec_grammar():
    s = Strategy.parse("bsp+backup:1/ring/onebit@4")
    assert (s.sync, s.backup, s.arch, s.topology) == \
        ("bsp", 1, "allreduce", "ring")
    assert s.spec() == "bsp+backup:1/allreduce/onebit@4"
    assert Strategy.parse(s.spec()).backup == 1
    for bad in ("bsp+backup/ring", "ssp+backup:1", "bsp+backup:4@4"):
        with pytest.raises(ValueError):
            Strategy.parse(bad)
    with pytest.raises(ValueError):
        Strategy(sync="ssp", backup=1)


def test_topology_alias_spec_roundtrip():
    s = Strategy.parse("bsp/tree/none@4")
    assert (s.arch, s.topology) == ("allreduce", "tree")
    assert s.spec() == "bsp/tree/none@4"
    assert Strategy.parse(s.spec()).topology == "tree"
    # ring is the default topology; its canonical form stays "allreduce"
    assert Strategy.parse("bsp/ring/none@4").spec() == \
        "bsp/allreduce/none@4"


def test_sim_backup_drops_and_accounts():
    K, steps = 4, 5
    eng = Strategy(sync="bsp", backup=1, workers=K, lr=0.05,
                   compression="onebit", backend="sim").build(grad_fn)
    _, hist, wire = eng.run(P0, make_batch, steps)
    # default periods rank worker K-1 slowest -> always dropped
    assert all(h["dropped"] == [K - 1] for h in hist)
    assert eng.metrics()["dropped_updates"] == steps
    # dropped pushes are not wire-accounted: (K-1) events/step
    per_event = eng.inner.cfg.compressor.roundtrip(
        jax.tree.map(jnp.zeros_like, P0),
        eng.inner.cfg.compressor.init_state(P0), KEY)[2]
    assert wire == per_event * (K - 1) * steps


def test_backup_drop_follows_straggler_event(tmp_path):
    params, hist, mets = Trainer(
        Strategy(sync="bsp", backup=1, workers=4, lr=0.05, backend="sim")
    ).fit(grad_fn, P0, make_batch, 6, plan="slow:w0x10@3")
    assert [h["dropped"] for h in hist[:3]] == [[3]] * 3
    assert [h["dropped"] for h in hist[3:]] == [[0]] * 3
    assert mets["dropped_updates"] == 6


# ------------------------------------------------------- snapshot / resume
@pytest.mark.parametrize("mode,comp", [("bsp", "onebit"), ("ssp", "onebit"),
                                       ("asp", "none")])
def test_sim_save_restore_resume_bitwise(tmp_path, mode, comp):
    mk = lambda: Strategy(sync=mode, workers=4, staleness=2, lr=0.05,
                          compression=comp, backend="sim").build(grad_fn)
    eng = mk()
    st = eng.init(P0)
    losses_a = []
    for t in range(10):
        st, ev = eng.step(st, make_batch, t)
        losses_a.extend(e["loss"] for e in ev)
    p_a = eng.finalize(st)

    eng_b = mk()
    st_b = eng_b.init(P0)
    losses_b = []
    for t in range(5):
        st_b, ev = eng_b.step(st_b, make_batch, t)
        losses_b.extend(e["loss"] for e in ev)
    save_engine_state(str(tmp_path / "ck"), eng_b, st_b, 5)

    eng_c = mk()                        # a fresh process-equivalent engine
    st_c, meta = restore_engine_state(str(tmp_path / "ck"), eng_c, P0)
    assert meta["step"] == 5
    for t in range(5, 10):
        st_c, ev = eng_c.step(st_c, make_batch, t)
        losses_b.extend(e["loss"] for e in ev)
    p_c = eng_c.finalize(st_c)

    assert losses_a == losses_b
    assert eng.metrics()["wire_bytes"] == eng_c.metrics()["wire_bytes"]
    for x, y in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_c)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_restore_reshards_engine_to_snapshot_size(tmp_path):
    eng = Strategy(sync="ssp", workers=3, lr=0.05,
                   backend="sim").build(grad_fn)
    st = eng.init(P0)
    st, _ = eng.step(st, make_batch, 0)
    save_engine_state(str(tmp_path / "ck"), eng, st, 1)
    # a rebuilt engine at a different size reshards itself on restore
    eng2 = Strategy(sync="ssp", workers=4, lr=0.05,
                    backend="sim").build(grad_fn)
    st2, meta = restore_engine_state(str(tmp_path / "ck"), eng2, P0)
    assert meta["num_workers"] == 3
    assert eng2.inner.cfg.num_workers == 3
    st2, ev = eng2.step(st2, make_batch, 1)
    assert ev and np.isfinite(ev[-1]["loss"])


def test_restart_is_bit_identical_to_uninterrupted(tmp_path):
    strat = Strategy(sync="ssp", workers=4, staleness=2, lr=0.05,
                     compression="onebit", backend="sim")
    p_plain, h_plain, _ = Trainer(strat).fit(grad_fn, P0, make_batch, 8)
    p_rst, h_rst, mets = Trainer(strat).fit(
        grad_fn, P0, make_batch, 8, plan="restart@4",
        checkpoint_dir=str(tmp_path))
    assert len(mets["recoveries"]) == 1
    assert mets["recoveries"][0]["lost_steps"] == 0
    assert [h["loss"] for h in h_plain] == [h["loss"] for h in h_rst]
    for x, y in zip(jax.tree.leaves(p_plain), jax.tree.leaves(p_rst)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_crash_rollback_preserves_earlier_slow_event(tmp_path):
    """A slow event commits a checkpoint, so a later crash rollback
    (which never re-fires consumed events) cannot erase the straggler."""
    strat = Strategy(sync="bsp", backup=1, workers=4, lr=0.05,
                     backend="sim")
    p, hist, mets = Trainer(strat).fit(
        grad_fn, P0, make_batch, 8, plan="slow:w0x10@2,crash:w3@5",
        checkpoint_dir=str(tmp_path), checkpoint_every=100)
    (r,) = mets["recoveries"]
    assert r["restored_step"] == 2      # the slow event's own commit
    # the x10 slowdown still ranks worker 0 slowest after the rollback
    assert all(h["dropped"] == [0] for h in hist[2:])


def test_reshard_remaps_survivor_periods():
    eng = Strategy(sync="bsp", workers=4, lr=0.05, periods=(4, 3, 2, 1),
                   backend="sim").build(grad_fn)
    st = eng.init(P0)
    st, _ = eng.step(st, make_batch, 0)
    eng.reshard(st, 3, step=1, lost=(0,))
    # survivors keep their speed identity; no reset to default_periods
    assert eng.inner.periods == (3, 2, 1)
    eng.reshard(st, 4, step=2)          # grown slot takes the default tail
    assert eng.inner.periods == (3, 2, 1, 4)
    with pytest.raises(ValueError, match="out of range"):
        eng.reshard(st, 3, step=3, lost=(7,))


# --------------------------------------------------------- resize / crash
def test_sim_resize_down_up_within_tolerance(tmp_path):
    strat = Strategy(sync="ssp", workers=4, staleness=2, lr=0.05,
                     compression="onebit", backend="sim")
    p_u, h_u, _ = Trainer(strat).fit(grad_fn, P0, make_batch, 12)
    p_e, h_e, mets = Trainer(strat).fit(
        grad_fn, P0, make_batch, 12, plan="resize:2@4,resize:4@8",
        checkpoint_dir=str(tmp_path))
    assert mets["resizes"] == 2 and mets["final_workers"] == 4
    init, lu, le = h_u[0]["loss"], h_u[-1]["loss"], h_e[-1]["loss"]
    # the documented tolerance (docs/elasticity.md): at most 4x the
    # uninterrupted final loss, and both runs reduce the start by >= 2x
    assert le <= 4 * lu
    assert lu <= init / 2 and le <= init / 2


def test_fit_elastic_crash_rollback_bookkeeping(tmp_path):
    strat = Strategy(sync="ssp", workers=4, staleness=2, lr=0.05,
                     backend="sim")
    p, hist, mets = Trainer(strat).fit(
        grad_fn, P0, make_batch, 10, plan="crash:w1@6",
        checkpoint_dir=str(tmp_path), checkpoint_every=2)
    (r,) = mets["recoveries"]
    assert r["kind"] == "crash" and r["lost_worker"] == 1
    assert r["restored_step"] == 4      # latest cadence checkpoint < 6
    assert r["lost_steps"] == 2
    assert mets["final_workers"] == 3
    assert mets["executed_steps"] == 10 + r["lost_steps"]
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert latest_checkpoint(str(tmp_path)) is not None


def test_fit_elastic_requires_checkpoint_dir_for_crashes():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        Trainer(Strategy(sync="bsp", workers=2, backend="sim")).fit(
            grad_fn, P0, make_batch, 4, plan="crash:w1@2")


def test_stream_assignment_identity_shrink_grow():
    assert stream_assignment(4, 4) == [[0], [1], [2], [3]]
    shrunk = stream_assignment(4, 2)
    assert len(shrunk) == 2
    # after a shrink the M workers still cover ALL N streams
    assert sorted(s for part in shrunk for s in part) == [0, 1, 2, 3]
    grown = stream_assignment(2, 4)
    assert grown == [[0], [1], [0], [1]]


def test_fit_elastic_ignores_stale_checkpoints(tmp_path):
    """A reused checkpoint_dir with leftovers from an earlier run must
    not leak foreign state: recovery restores only what THIS run wrote."""
    strat = Strategy(sync="ssp", workers=4, staleness=2, lr=0.05,
                     backend="sim")
    # an earlier, longer run leaves a high-step checkpoint behind
    Trainer(strat).fit(grad_fn, P0, make_batch, 8, plan="restart@6",
                       checkpoint_dir=str(tmp_path))
    assert latest_checkpoint(str(tmp_path)).endswith("step_000006")
    p, hist, mets = Trainer(strat).fit(
        grad_fn, P0, make_batch, 5, plan="crash:w1@3",
        checkpoint_dir=str(tmp_path), checkpoint_every=2)
    (r,) = mets["recoveries"]
    # restored from this run's step-2 cadence save, not the stale step-6
    assert r["restored_step"] == 2 and r["lost_steps"] == 1
    assert len(hist) >= 5


# ----------------------------------------------------- scheduler ↔ trainer
def test_sched_trace_and_adapter_drive_training(tmp_path):
    jobs = make_trace(12, 8, seed=3, mean_interarrival=20.0)
    res = simulate(jobs, Cluster(n_nodes=2, gpus_per_node=4),
                   policy="fifo", gandiva=True, elastic=True)
    kinds = {e.kind for e in res.trace}
    assert {"start", "suspend", "resume", "finish"} <= kinds
    # the adapter maps suspend/resume pairs onto the job's step clock
    planned = [(j.jid, plan_from_sched_trace(res.trace, j.jid,
                                             steps_per_sec=0.005))
               for j in jobs]
    jid, plan = next((j, p) for j, p in planned if len(p))
    assert all(e.kind in ("restart", "resize") for e in plan)
    # ...and the resulting plan drives a real elastic training run
    short = EventPlan([e for e in plan if e.step < 5][:1])
    assert len(short) == 1
    p, hist, mets = Trainer(
        Strategy(sync="ssp", workers=2, staleness=1, lr=0.05,
                 backend="sim")
    ).fit(grad_fn, P0, make_batch, 6, plan=short,
          checkpoint_dir=str(tmp_path))
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert len(mets["recoveries"]) + mets["resizes"] == 1


def test_adapter_emits_resize_for_shrunk_start():
    trace = [TraceEvent(0.0, 7, "start", 2),
             TraceEvent(100.0, 7, "suspend", 2),
             TraceEvent(120.0, 7, "resume", 4),
             TraceEvent(400.0, 7, "finish", 4)]
    plan = plan_from_sched_trace(trace, 7, steps_per_sec=0.05,
                                 nominal_gpus=4)
    assert plan.spec() == "resize:2@0,resize:4@5"
    # without the nominal size the shrunk start is invisible
    assert plan_from_sched_trace(trace, 7, steps_per_sec=0.05).spec() == \
        "resize:4@5"


def test_elastic_allocation_can_shrink():
    jobs = make_trace(16, 8, seed=1, mean_interarrival=5.0)
    el = simulate(jobs, Cluster(n_nodes=1, gpus_per_node=4),
                  policy="fifo", elastic=True)
    requested = {j.jid: j.num_gpus for j in jobs}
    shrunk = [e for e in el.trace if e.kind == "start"
              and e.gpus < requested[e.jid]]
    assert shrunk, "elastic allocation never shrank a job"
    # shrunk allocations stay power-of-two and every job still finishes
    assert all(e.gpus & (e.gpus - 1) == 0 for e in shrunk)
    finished = {e.jid for e in el.trace if e.kind == "finish"}
    assert finished == set(requested)


# ------------------------------------- device backend (subprocess, 4 dev)
SCRIPT_DEVICE = r"""
import os, tempfile
import numpy as np, jax, jax.numpy as jnp
from repro.train import Strategy, Trainer
from repro.elastic import save_engine_state, restore_engine_state

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))
def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}
def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)
P0 = {"W": jnp.zeros((8, 1)), "b": jnp.zeros((130,))}
PID = os.getpid()

# 1. the ISSUE-3 acceptance scenario: ssp:2/ring/onebit@4 loses worker 2
# at step 5, is resized back to 4 at step 10, recovers from checkpoint
# and reshards in the SAME process, and lands within the documented loss
# tolerance of an uninterrupted run.
strat = Strategy.parse("ssp:2/ring/onebit@4", lr=0.05, backend="device",
                       bucket_mb=1e-4)
p_u, h_u, m_u = Trainer(strat).fit(grad_fn, P0, make_batch, 15)
with tempfile.TemporaryDirectory() as d:
    p_e, h_e, m_e = Trainer(strat).fit(
        grad_fn, P0, make_batch, 15, plan="crash:w2@5,resize:4@10",
        checkpoint_dir=d, checkpoint_every=3)
assert os.getpid() == PID
(r,) = m_e["recoveries"]
assert r["kind"] == "crash" and r["lost_worker"] == 2, r
assert m_e["resizes"] == 1 and m_e["final_workers"] == 4, m_e
init, lu, le = h_u[0]["loss"], h_u[-1]["loss"], h_e[-1]["loss"]
assert le <= 4 * lu, (le, lu)
assert lu <= init / 2 and le <= init / 2, (init, lu, le)
print(f"ACCEPT-OK lost@5 resized@10 loss {le:.4f} vs {lu:.4f}")

# 2. device save->restore->resume is bitwise on both sync families
for sync, comp in (("bsp", "onebit"), ("ssp", "onebit")):
    mk = lambda: Strategy(sync=sync, workers=4, staleness=2, lr=0.05,
                          compression=comp, backend="device",
                          bucket_mb=1e-4).build(grad_fn)
    e1 = mk(); st = e1.init(P0)
    for t in range(8): st, _ = e1.step(st, make_batch, t)
    pA = e1.finalize(st)
    with tempfile.TemporaryDirectory() as d:
        e2 = mk(); st2 = e2.init(P0)
        for t in range(4): st2, _ = e2.step(st2, make_batch, t)
        save_engine_state(os.path.join(d, "ck"), e2, st2, 4)
        e3 = mk()
        st3, meta = restore_engine_state(os.path.join(d, "ck"), e3, P0)
        for t in range(4, 8): st3, _ = e3.step(st3, make_batch, t)
        pB = e3.finalize(st3)
    for x, y in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print(f"RESUME-OK {sync}/{comp}")

# 3. backup workers: device drop set, losses and wire match the simulator
for comp in ("none", "onebit"):
    base = dict(sync="bsp", backup=1, workers=4, lr=0.05,
                compression=comp, bucket_mb=1e-4)
    sim = Strategy(backend="sim", **base).build(grad_fn)
    p_s, h_s, w_s = sim.run(P0, make_batch, 4)
    dev = Strategy(backend="device", **base).build(grad_fn)
    p_d, h_d, w_d = dev.run(P0, make_batch, 4)
    assert [h["dropped"] for h in h_d] == [h["dropped"] for h in h_s]
    ldiff = max(abs(a["loss"] - b["loss"]) for a, b in zip(h_s, h_d))
    assert ldiff <= 1e-4, (comp, ldiff)
    assert w_s == w_d, (comp, w_s, w_d)
    pdiff = max(float(jnp.max(jnp.abs(x - y)))
                for x, y in zip(jax.tree.leaves(p_s), jax.tree.leaves(p_d)))
    assert pdiff <= 1e-4, (comp, pdiff)
    print(f"BACKUP-OK {comp}")
print("ELASTIC-DEVICE-OK")
"""


def test_elastic_device_4dev(multidevice):
    out = multidevice(SCRIPT_DEVICE, 4)
    assert "ACCEPT-OK" in out
    assert out.count("RESUME-OK") == 2
    assert out.count("BACKUP-OK") == 2
    assert "ELASTIC-DEVICE-OK" in out
