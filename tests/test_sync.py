"""Synchronization-model tests (survey Table 1 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Compressor, SyncConfig, SyncEngine

KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (8, 1))


def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    X = jax.random.normal(k, (16, 8))
    return {"X": X, "y": X @ W_TRUE}


def grad_fn(params, batch):
    def loss(p):
        return jnp.mean((batch["X"] @ p["W"] - batch["y"]) ** 2)
    return jax.value_and_grad(loss)(params)


P0 = {"W": jnp.zeros((8, 1))}


@pytest.mark.parametrize("mode", ["bsp", "ssp", "asp", "sma"])
def test_all_modes_converge(mode):
    eng = SyncEngine(SyncConfig(mode=mode, num_workers=4, lr=0.05),
                     grad_fn)
    _, hist, _ = eng.run(P0, make_batch, 25)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.5, mode


def test_ssp_staleness_bounded():
    s = 2
    eng = SyncEngine(SyncConfig(mode="ssp", num_workers=4, staleness=s,
                                lr=0.05, periods=(1, 2, 3, 5)), grad_fn)
    _, hist, _ = eng.run(P0, make_batch, 15)
    # SSP clock-bound invariant: no gradient from a worker more than
    # (bound+1) * num_workers versions behind (loose but monotone check)
    max_stale = max(h["max_staleness"] for h in hist)
    eng_asp = SyncEngine(SyncConfig(mode="asp", num_workers=4, lr=0.05,
                                    periods=(1, 2, 3, 5)), grad_fn)
    _, hist_asp, _ = eng_asp.run(P0, make_batch, 15)
    max_stale_asp = max(h["max_staleness"] for h in hist_asp)
    assert max_stale <= max_stale_asp   # the bound can only reduce staleness


def test_asp_has_staleness_with_heterogeneous_workers():
    eng = SyncEngine(SyncConfig(mode="asp", num_workers=4, lr=0.05,
                                periods=(1, 3, 5, 7)), grad_fn)
    _, hist, _ = eng.run(P0, make_batch, 15)
    assert max(h["max_staleness"] for h in hist) > 0


def test_bsp_no_staleness():
    eng = SyncEngine(SyncConfig(mode="bsp", num_workers=4, lr=0.05), grad_fn)
    _, hist, _ = eng.run(P0, make_batch, 10)
    assert all(h["max_staleness"] == 0 for h in hist)


@pytest.mark.parametrize("method", ["onebit", "qsgd", "dgc"])
def test_bsp_with_compression_converges(method):
    eng = SyncEngine(SyncConfig(mode="bsp", num_workers=2, lr=0.05,
                                compressor=Compressor(method, density=0.1)),
                     grad_fn)
    _, hist, wire = eng.run(P0, make_batch, 40)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7, method
    eng0 = SyncEngine(SyncConfig(mode="bsp", num_workers=2, lr=0.05), grad_fn)
    _, _, wire0 = eng0.run(P0, make_batch, 40)
    assert wire < wire0
