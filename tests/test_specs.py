"""Sharding-rule consistency: every sharded dim divides its mesh axis for
every (arch x shape) — catches partition misconfig without compiling."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, SKIPS
from repro.core.parallelism import param_specs
from repro.launch.specs import (cache_specs, decode_window,
                                train_input_specs, VOCAB_PAD)
from repro.models import build_model

AXIS = {"data": 16, "model": 16, "pod": 2}


def _check(spec, shape, where):
    assert len(tuple(spec)) == len(shape), (where, spec, shape)
    for dim, ax in zip(shape, tuple(spec)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= AXIS[a]
        assert dim % n == 0, f"{where}: dim {dim} not divisible by {ax}={n}"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divisible(arch):
    cfg = ARCHS[arch]
    model = build_model(cfg)
    shapes = jax.eval_shape(
        lambda k: model.init(k, dtype=jnp.bfloat16,
                             vocab_pad_multiple=VOCAB_PAD),
        jax.random.PRNGKey(0))
    specs = param_specs(shapes)
    flat_s, _ = jax.tree.flatten(shapes)
    flat_p = jax.tree.structure(shapes).flatten_up_to(specs)
    for s, sp in zip(flat_s, flat_p):
        _check(sp, s.shape, arch)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    if (arch, shape_name) in SKIPS:
        pytest.skip(SKIPS[(arch, shape_name)])
    cfg = ARCHS[arch]
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg)
    window = decode_window(cfg, shape)
    if cfg.is_encoder_decoder:
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     dtype=jnp.bfloat16))
    else:
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     dtype=jnp.bfloat16,
                                     window_override=window))

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)

    specs = cache_specs(c_shapes, FakeMesh, False,
                        shape.global_batch % 16 == 0)
    flat_s = jax.tree.leaves(c_shapes)
    flat_p = jax.tree.structure(c_shapes).flatten_up_to(specs)
    for s, sp in zip(flat_s, flat_p):
        _check(sp, s.shape, f"{arch}/{shape_name}")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_input_specs_complete(arch):
    cfg = ARCHS[arch]
    specs = train_input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert "tokens" in specs and "labels" in specs
    if cfg.is_encoder_decoder:
        assert specs["frames"].shape == (256, 1500, cfg.d_model)
    if cfg.family == "vlm":
        assert "vision_embeds" in specs and "positions" in specs
