"""Hypothesis property tests on the compression-kernel invariants.

Kept separate from tests/test_kernels.py so the deterministic kernel-vs-ref
sweeps still run on hosts without the optional hypothesis dev dep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import onebit, qsgd, terngrad


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 300), st.integers(0, 2**31 - 1))
def test_onebit_error_feedback_telescopes(r, c, seed):
    """EF invariant: compensated gradient == transmitted + residual exactly,
    so no information is ever lost across steps (Seide et al.)."""
    k = jax.random.PRNGKey(seed)
    g = jax.random.normal(k, (r, c))
    e = jax.random.normal(jax.random.fold_in(k, 1), (r, c))
    signs, scale, new_e = onebit.onebit_ref(g, e)
    recon = signs.astype(jnp.float32) * scale + new_e
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g + e),
                               atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 20), st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_terngrad_unbiased_support(r, c, seed):
    """TernGrad values are in {-1,0,1} * s and sign-consistent with g."""
    k = jax.random.PRNGKey(seed)
    g = jax.random.normal(k, (r, c))
    u = jax.random.uniform(jax.random.fold_in(k, 1), (r, c))
    t, s = terngrad.terngrad_ref(g, u)
    assert set(np.unique(np.asarray(t))) <= {-1, 0, 1}
    nz = np.asarray(t) != 0
    assert np.all(np.sign(np.asarray(t)[nz]) == np.sign(np.asarray(g)[nz]))
    assert float(s) >= 0


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 20), st.integers(1, 200), st.integers(0, 2**31 - 1),
       st.sampled_from([3, 15, 127]))
def test_qsgd_reconstruction_bounded(r, c, seed, levels):
    """QSGD: |decompressed - g| <= ||g||/s per element (stochastic rounding
    never moves more than one level)."""
    k = jax.random.PRNGKey(seed)
    g = jax.random.normal(k, (r, c))
    u = jax.random.uniform(jax.random.fold_in(k, 1), (r, c))
    q, norm = qsgd.qsgd_ref(g, u, levels)
    recon = qsgd.decompress(q, norm, s_levels=levels)
    assert np.all(np.abs(np.asarray(recon - g)) <= float(norm) / levels + 1e-5)
