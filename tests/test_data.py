"""Data-management substrate tests (survey §3.5.1)."""
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.data import (LMDataConfig, ShardedLoader, dirichlet_partition,
                        iid_partition, make_lm_batches, synthetic_lm_batch)
from repro.data.partition import label_skew, make_classification_data
from repro.data.pipeline import EpochCache


def test_batches_deterministic():
    cfg = LMDataConfig(seed=7)
    b1 = synthetic_lm_batch(cfg, 3, 1)
    b2 = synthetic_lm_batch(cfg, 3, 1)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])


def test_batches_distinct_across_workers_and_steps():
    cfg = LMDataConfig(seed=7)
    assert not jnp.array_equal(synthetic_lm_batch(cfg, 0, 0)["tokens"],
                               synthetic_lm_batch(cfg, 0, 1)["tokens"])
    assert not jnp.array_equal(synthetic_lm_batch(cfg, 0, 0)["tokens"],
                               synthetic_lm_batch(cfg, 1, 0)["tokens"])


def test_labels_are_next_tokens():
    b = synthetic_lm_batch(LMDataConfig(), 0)
    assert jnp.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_learnable():
    """Most next-tokens follow the chain rule => structure exists."""
    cfg = LMDataConfig(vocab_size=64, seq_len=256, batch_size=4)
    b = synthetic_lm_batch(cfg, 0)
    toks = np.asarray(b["tokens"])
    labels = np.asarray(b["labels"])
    match = (labels == (3 * toks + 7) % cfg.vocab_size).mean()
    assert match > 0.8


def test_sharded_loader_prefetch():
    cfg = LMDataConfig()
    fn = make_lm_batches(cfg)
    loader = ShardedLoader(lambda t: fn(t, 0), prefetch=2, num_steps=5)
    items = list(loader)
    assert len(items) == 5
    loader.close()


def test_epoch_cache():
    calls = []

    def fn(t):
        calls.append(t)
        return t * 2

    cache = EpochCache(fn, steps_per_epoch=3)
    out = [cache(t) for t in range(9)]       # 3 epochs
    assert out == [0, 2, 4] * 3
    assert len(calls) == 3                   # only the first epoch misses


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 10), st.integers(50, 300), st.integers(0, 1000))
def test_iid_partition_covers_everything(k, n, seed):
    parts = iid_partition(n, k, seed)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == n
    assert set(all_idx.tolist()) == set(range(n))


def test_dirichlet_more_skewed_than_iid():
    X, y = make_classification_data(2000, 8, 10, seed=1)
    iid = iid_partition(len(y), 10, seed=1)
    noniid = dirichlet_partition(y, 10, alpha=0.1, seed=1)
    assert label_skew(noniid, y) > label_skew(iid, y) + 0.2
    # coverage
    assert sum(len(p) for p in noniid) == len(y)
