"""MoE dispatch invariants (the §Perf pair-3 code path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.moe import _capacity, moe_apply, moe_init


def _cfg(E=4, K=2, cap=8.0):
    return dataclasses.replace(
        get_config("kimi-k2-1t-a32b").reduced(),
        num_experts=E, experts_per_token=K, capacity_factor=cap,
        num_shared_experts=0, d_model=32, moe_d_ff=16)


def test_no_drop_equals_dense_computation():
    """With capacity >= all assignments, MoE output must equal the explicit
    per-token sum over its top-k experts."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, cfg.d_model))
    out, aux = moe_apply(p, x, cfg)

    # reference: dense evaluation of every expert for every token
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"]))
    h = h * jnp.einsum("td,edf->tef", xt, p["w_up"])
    all_e = jnp.einsum("tef,efd->ted", h, p["w_down"])   # [T, E, d]
    ref = jnp.einsum("tkd,tk->td",
                     jnp.take_along_axis(all_e, ids[..., None], axis=1),
                     gate)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=1e-4)
    assert float(aux) >= 0


def test_capacity_drops_are_bounded():
    """With capacity 1.0, each expert processes at most C tokens and the
    output stays finite (dropped tokens contribute zero, not NaN)."""
    cfg = _cfg(cap=1.0)
    key = jax.random.PRNGKey(2)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, 8, cfg.d_model))
    out, _ = moe_apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.integers(1, 4), st.integers(2, 16))
def test_capacity_formula(T, K, E):
    C = _capacity(T, K, E, 1.0)
    assert C >= 1
    assert C * E >= T * K                 # no-overflow bound at factor 1.0


def test_aux_loss_penalizes_imbalance():
    """Router collapse (all tokens -> one expert) must cost more aux loss
    than a uniform router."""
    cfg = _cfg(E=4, K=1)
    key = jax.random.PRNGKey(4)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 5), (2, 16, cfg.d_model))
    # uniform router
    p_uniform = dict(p)
    p_uniform["router"] = {"w": jnp.zeros_like(p["router"]["w"])}
    _, aux_uniform = moe_apply(p_uniform, x, cfg)
    # collapsed router: huge bias toward expert 0
    w = jnp.zeros_like(p["router"]["w"]).at[:, 0].set(100.0)
    p_collapsed = dict(p)
    p_collapsed["router"] = {"w": w}
    _, aux_collapsed = moe_apply(p_collapsed, x, cfg)
    assert float(aux_collapsed) > float(aux_uniform)


def test_moe_grads_flow_to_experts_and_router():
    cfg = _cfg()
    key = jax.random.PRNGKey(6)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 7), (2, 6, cfg.d_model))

    def loss(pp):
        out, aux = moe_apply(pp, x, cfg)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["w_down"]).sum()) > 0
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
