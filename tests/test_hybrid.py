"""Hybrid-parallel subsystem tests (ISSUE 4 tentpole).

Covers: the mesh suffix grammar and its Strategy roundtrip, MeshPlan
construction (role-based tensor dims, local block shapes, ZeRO shard
sizes), the ZeRO memory model, and — in an 8-virtual-device subprocess —
the acceptance criteria: a ``d2.t2.s2`` mesh matching the single-device
stacked reference to ≤1e-4, ``dK.t1.s1`` bitwise-identical to the plain
data-parallel engine, ZeRO-3 cutting measured per-device param+optimizer
bytes by ~the data-axis factor, and ZeRO-3 AdamW surviving the
``crash:w1@5,resize:4@10`` elastic plan.
"""
import numpy as np
import pytest

from repro.parallel import (MeshSpec, parse_suffix, plan_mesh,
                            state_bytes_per_device, suffix_spec,
                            wire_bytes_per_device)
from repro.train import Strategy


# ------------------------------------------------------------- grammar
def test_mesh_suffix_parse_and_roundtrip():
    fields, named = parse_suffix("d2.t2.s2")
    assert fields["mesh"] == MeshSpec(2, 2, 2)
    assert named["mesh"] and not named["zero"]
    fields, named = parse_suffix("d4.z3.adamw")
    assert (fields["mesh"], fields["zero"], fields["optimizer"]) == \
        (MeshSpec(4, 1, 1), 3, "adamw")
    assert suffix_spec(MeshSpec(2, 2, 2), 3, "adamw", 6) == \
        "d2.t2.s2.z3.m6.adamw"
    assert suffix_spec(MeshSpec(4, 1, 1)) == ""     # trivial mesh: minimal


def test_mesh_suffix_rejects_bad_tokens():
    for bad in ("d2.q3", "d2.d4", "adamw.adamw", "sgd.adamw", "d", "z9x",
                ""):
        with pytest.raises(ValueError):
            parse_suffix(bad)
    # the stage token and the sgd optimizer token share a first letter —
    # they must not collide in the duplicate check
    fields, _ = parse_suffix("s2.sgd")
    assert fields["mesh"].stage == 2 and fields["optimizer"] == "sgd"


def test_strategy_mesh_spec_roundtrip():
    s = Strategy.parse("bsp/ring/onebit@8:d2.t2.s2")
    assert s.mesh == MeshSpec(2, 2, 2) and s.is_hybrid
    assert s.spec() == "bsp/allreduce/onebit@8:d2.t2.s2"
    assert Strategy.parse(s.spec()) == s
    z = Strategy.parse("bsp/ps/none@4:d4.z3.adamw")
    assert (z.zero, z.optimizer, z.is_hybrid) == (3, "adamw", True)
    assert Strategy.parse(z.spec()) == z


def test_trivial_mesh_normalizes_to_plain_data_parallel():
    s = Strategy.parse("bsp/allreduce/none@4:d4.t1.s1")
    assert s.mesh is None and not s.is_hybrid
    assert s.spec() == "bsp/allreduce/none@4"
    assert s == Strategy.parse("bsp/allreduce/none@4")


def test_mesh_field_rejects_non_axis_tokens():
    # Strategy(mesh="d4.z3") must not silently train un-sharded
    with pytest.raises(ValueError, match="non-axis"):
        Strategy(sync="bsp", arch="ps", workers=4, mesh="d4.z3")
    with pytest.raises(ValueError, match="non-axis"):
        MeshSpec.parse("d4.adamw")


def test_strategy_rejects_bad_hybrid_specs():
    for bad in ("bsp/ring/none@8:d2.t2",        # product != workers
                "bsp/ring/none@8:d2.t2.s2.z1",  # zero needs arch=ps
                "ssp/ring/none@8:d2.t2.s2",     # hybrid is bsp-only
                "bsp+backup:1/ring/none@8:d2.t2.s2",  # no backup on meshes
                "bsp+detect/ps/none@8:d8.z3.adamw",   # detect is inert here
                "bsp/ps/none@4:d4.z4",          # no such ZeRO level
                ):
        with pytest.raises(ValueError):
            Strategy.parse(bad)
    with pytest.raises(ValueError, match="device-only"):
        Strategy.parse("bsp/ps/none@4:d4.z2", backend="sim").resolve_backend()


def test_hybrid_cells_resolve_to_device_backend():
    s = Strategy.parse("bsp/ring/none@8:d2.t2.s2")
    assert s.resolve_backend() == "device"


# ------------------------------------------------------------ mesh plan
def _staged_params(layers=4, d=8, f=16):
    return {"w_up": np.zeros((layers, d, f), np.float32),
            "w_down": np.zeros((layers, f, d), np.float32)}


def test_plan_mesh_role_dims_and_local_shapes():
    plan = plan_mesh(_staged_params(), MeshSpec(2, 2, 2), staged=True,
                     bucket_mb=1e-4)
    # w_up is column-parallel (shard d_ff = dim 2), w_down row-parallel
    # (shard d_ff = dim 1); leading layer dim divides over 2 stages
    shapes = {tuple(x.shape) for x in
              [plan.local_example["w_up"], plan.local_example["w_down"]]}
    assert shapes == {(2, 8, 8), (2, 8, 8)}
    assert sorted(plan.tensor_dims) == [1, 2]
    assert plan.micro == 4                       # auto: 2 * stages
    # ZeRO shards: per-bucket local size / data axis, rounded up
    for n, m in zip(plan.bucket_sizes, plan.shard_sizes):
        assert m == -(-n // 2)


def test_plan_mesh_rejects_bad_geometry():
    with pytest.raises(ValueError, match="stage axis"):
        plan_mesh(_staged_params(layers=3), MeshSpec(1, 1, 2), staged=True)
    with pytest.raises(ValueError, match="divisible by tensor"):
        plan_mesh(_staged_params(f=6), MeshSpec(1, 4, 1), staged=True)
    with pytest.raises(ValueError, match="model-parallel"):
        plan_mesh({"u": np.zeros((4, 8, 8), np.float32)}, MeshSpec(1, 2, 1),
                  staged=True)


def test_zero_memory_model_scales_with_data_axis():
    plan = plan_mesh(_staged_params(), MeshSpec(4, 1, 1), staged=True,
                     bucket_mb=1e-4)
    z0 = state_bytes_per_device(plan, 0, "adamw")
    z1 = state_bytes_per_device(plan, 1, "adamw")
    z3 = state_bytes_per_device(plan, 3, "adamw")
    assert z0["opt"] == pytest.approx(2 * z0["params"], rel=0.01)
    assert z1["opt"] <= z0["opt"] / 3            # ~/4 with padding slack
    assert z3["total"] <= z0["total"] / 3
    # wire model: z2/z3 (RS+AG) never exceed z1 (AR+AG)
    assert wire_bytes_per_device(plan, 2) <= wire_bytes_per_device(plan, 1)


# -------------------------------------- 8-virtual-device acceptance run
SCRIPT_ACCEPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.train import Strategy, Trainer
from repro.parallel import make_tiny_transformer, stacked_grad_fn

S, D_MODEL, FF = 2, 8, 16
params, model = make_tiny_transformer(S, D_MODEL, FF, seed=0)
KEY = jax.random.PRNGKey(1)
W_T = jax.random.normal(KEY, (D_MODEL, D_MODEL))
def make_batch(t, w):
    k = jax.random.fold_in(KEY, t * 100 + w)
    x = jax.random.normal(k, (8, D_MODEL))
    return {"x": x, "y": jnp.tanh(x @ W_T)}
LR, STEPS = 0.05, 4
gf = stacked_grad_fn(model)

def ref_run(d_axis):
    p, losses = params, []
    for t in range(STEPS):
        cat = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                           *[make_batch(t, w) for w in range(d_axis)])
        loss, g = gf(p, cat)
        losses.append(float(loss))
        p = jax.tree.map(lambda a, b: a - LR * b, p, g)
    return p, losses

# 1. the d2.t2.s2 acceptance mesh matches the single-device reference
p_ref, l_ref = ref_run(2)
eng = Strategy.parse("bsp/ring/none@8:d2.t2.s2", lr=LR, bucket_mb=1e-4,
                     backend="device").build(model)
p_dev, h_dev, wire = eng.run(params, make_batch, STEPS)
ld = max(abs(a - b["loss"]) for a, b in zip(l_ref, h_dev))
pd = max(float(jnp.max(jnp.abs(x - y))) for x, y in
         zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_dev)))
assert ld <= 1e-4 and pd <= 1e-4, (ld, pd)
assert wire > 0
print(f"MESH-REF-OK {ld:.2e} {pd:.2e}")

# 2. a dK.t1.s1 mesh is bitwise the plain data-parallel engine
for spec_a, spec_b in (("bsp/ring/onebit@4", "bsp/ring/onebit@4:d4.t1.s1"),):
    a = Strategy.parse(spec_a, lr=LR, bucket_mb=1e-4, backend="device").build(model)
    b = Strategy.parse(spec_b, lr=LR, bucket_mb=1e-4, backend="device").build(model)
    assert type(a.inner) is type(b.inner)
    pa, ha, wa = a.run(params, make_batch, 3)
    pb, hb, wb = b.run(params, make_batch, 3)
    assert [h["loss"] for h in ha] == [h["loss"] for h in hb]
    assert wa == wb
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("TRIVIAL-MESH-BITWISE-OK")

# 3. measured ZeRO-3 per-device param+opt bytes drop ~the data factor,
#    and the z3 trajectory matches z0 exactly (same optimizer math)
D = 4
z0 = Strategy.parse("bsp/ring/none@4:d4.adamw", lr=LR, bucket_mb=1e-4,
                    backend="device").build(model)
z3 = Strategy.parse("bsp/ps/none@4:d4.z3.adamw", lr=LR, bucket_mb=1e-4,
                    backend="device").build(model)
st0, st3 = z0.inner.init(params), z3.inner.init(params)
b0 = z0.inner.per_device_state_bytes(st0)
b3 = z3.inner.per_device_state_bytes(st3)
ratio = b0["total"] / b3["total"]
assert ratio >= 0.8 * D, (b0, b3, ratio)
p0, h0, _ = z0.run(params, make_batch, 3)
p3, h3, _ = z3.run(params, make_batch, 3)
ld = max(abs(a["loss"] - b["loss"]) for a, b in zip(h0, h3))
assert ld <= 1e-5, ld
print(f"ZERO3-BYTES-OK ratio {ratio:.2f} (z0 {b0['total']} z3 {b3['total']})")

# 4. ZeRO-3 AdamW survives the crash:w1@5,resize:4@10 plan
import tempfile
strat = Strategy.parse("bsp/ps/none@4:d4.z3.adamw", lr=LR, bucket_mb=1e-4,
                       backend="device")
p_u, h_u, m_u = Trainer(strat).fit(model, params, make_batch, 12)
with tempfile.TemporaryDirectory() as d:
    p_e, h_e, m_e = Trainer(strat).fit(
        model, params, make_batch, 12, plan="crash:w1@5,resize:4@10",
        checkpoint_dir=d, checkpoint_every=3)
(r,) = m_e["recoveries"]
assert r["kind"] == "crash" and r["lost_worker"] == 1
assert m_e["resizes"] == 1 and m_e["final_workers"] == 4
lu, le = h_u[-1]["loss"], h_e[-1]["loss"]
assert np.isfinite(le) and le <= 4 * max(lu, h_u[0]["loss"] / 4)
print(f"ZERO3-ELASTIC-OK {le:.4f} vs {lu:.4f}")

# 5. crashing a device of a t*s>1 mesh drops its whole model-parallel
# block (one data replica: 8 -> 4 devices), and slow events map flat
# device ids onto data slots instead of raising
strat3d = Strategy.parse("bsp/ring/none@8:d2.t2.s2", lr=LR,
                         bucket_mb=1e-4, backend="device")
eng3d = strat3d.build(model)
assert eng3d.inner.crash_plan(5) == (4, (1,))
eng3d.set_slowdown(5, 2.0)
assert eng3d.inner.slowdowns == [1.0, 2.0]
try:
    eng3d.set_slowdown(9, 2.0)
    raise AssertionError("out-of-range slow event accepted")
except ValueError:
    pass
with tempfile.TemporaryDirectory() as d:
    p_c, h_c, m_c = Trainer(strat3d).fit(
        model, params, make_batch, 8, plan="crash:w5@4",
        checkpoint_dir=d, checkpoint_every=2)
(r,) = m_c["recoveries"]
assert r["kind"] == "crash" and m_c["final_workers"] == 4, m_c
assert np.isfinite(h_c[-1]["loss"])
print("MESH-CRASH-OK")
print("HYBRID-ACCEPT-OK")
"""


def test_hybrid_acceptance_8dev(multidevice):
    out = multidevice(SCRIPT_ACCEPT, 8)
    assert "MESH-REF-OK" in out
    assert "TRIVIAL-MESH-BITWISE-OK" in out
    assert "ZERO3-BYTES-OK" in out
    assert "ZERO3-ELASTIC-OK" in out
    assert "MESH-CRASH-OK" in out
    assert "HYBRID-ACCEPT-OK" in out
