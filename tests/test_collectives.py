"""Multi-device (8 virtual hosts) tests: allreduce topologies == psum,
PS push/pull == allreduce SGD, GPipe == sequential."""
import pytest

SCRIPT_TOPO = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.allreduce import TOPOLOGIES
from repro.core.collectives import shard_map
mesh = Mesh(np.array(jax.devices()).reshape(8), ("w",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 40))
for name, fn in TOPOLOGIES.items():
    f = shard_map(lambda a, _fn=fn: _fn(a[0], "w")[None], mesh=mesh,
                  in_specs=P("w", None), out_specs=P("w", None),
                  check_vma=False)
    out = f(x)
    expect = jnp.broadcast_to(x.sum(0)[None], (8, 40))
    err = float(jnp.max(jnp.abs(out - expect)))
    assert err < 1e-4, (name, err)
# odd-size tensor through ring (padding path)
y = jax.random.normal(jax.random.PRNGKey(1), (8, 37))
f = shard_map(lambda a: TOPOLOGIES["ring"](a[0], "w")[None], mesh=mesh,
              in_specs=P("w", None), out_specs=P("w", None),
              check_vma=False)
err = float(jnp.max(jnp.abs(f(y) - y.sum(0)[None])))
assert err < 1e-4, err
print("TOPO-OK")
"""

SCRIPT_PS = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.parameter_server import make_ps_step
from repro.core.collectives import shard_map
mesh = Mesh(np.array(jax.devices()).reshape(8), ("w",))
def update(p_sh, g_sh, opt):
    return jax.tree.map(lambda a, b: a - 0.1 * b, p_sh, g_sh), opt
ps = make_ps_step(update, "w")
pp = {"W": jax.random.normal(jax.random.PRNGKey(0), (13, 3)),
      "b": jnp.ones((5,))}
gg = jax.tree.map(lambda x: jnp.stack([x * 0 + i for i in range(8)]), pp)
f = shard_map(lambda p, g: ps(p, jax.tree.map(lambda a: a[0], g), None)[0],
              mesh=mesh, in_specs=(P(), P("w")), out_specs=P(),
              check_vma=False)
newp = f(pp, gg)
expect = jax.tree.map(lambda x: x - 0.1 * sum(range(8)), pp)
for a, b in zip(jax.tree.leaves(newp), jax.tree.leaves(expect)):
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4
print("PS-OK")
"""

SCRIPT_PIPE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.pipeline import gpipe_forward, bubble_fraction
from repro.core.collectives import shard_map
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("stage", "data"))
stacked = jnp.stack([jnp.eye(6) * (i + 1) + 0.01 * i for i in range(4)])
xm = jax.random.normal(jax.random.PRNGKey(0), (8, 2, 6))
def stage_fn(w, x):
    return jnp.tanh(x @ w)
f = shard_map(lambda w, x: gpipe_forward(stage_fn, w[0], x, "stage")[None],
              mesh=mesh, in_specs=(P("stage"), P(None)),
              out_specs=P("stage"), check_vma=False)
out = f(stacked, xm).sum(0)      # only last stage nonzero
seq = xm
for i in range(4):
    seq = jnp.tanh(seq @ stacked[i])
assert float(jnp.max(jnp.abs(out - seq))) < 1e-5
# gradient flows through the pipeline
g = jax.grad(lambda w: jnp.sum(f(w, xm) ** 2))(stacked)
assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.abs(g).sum()) > 0
assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9
print("PIPE-OK")
"""


def test_allreduce_topologies(multidevice):
    assert "TOPO-OK" in multidevice(SCRIPT_TOPO, 8)


def test_parameter_server(multidevice):
    assert "PS-OK" in multidevice(SCRIPT_PS, 8)


def test_gpipe(multidevice):
    assert "PIPE-OK" in multidevice(SCRIPT_PIPE, 8)
