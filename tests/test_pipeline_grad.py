"""GPipe gradient tests (ISSUE 4 satellite): the ``lax.scan`` +
``ppermute`` pipeline of core/pipeline.py is differentiable, and its
loss/gradients match the unpipelined stacked model to ≤1e-5 — including
micro-batch counts that do not divide the stage count, where only the
bubble grows.  Bubble/tick accounting is asserted host-side.
"""
import pytest

from repro.core.pipeline import bubble_fraction, gpipe_ticks


# ----------------------------------------------------- bubble accounting
def test_gpipe_tick_and_bubble_accounting():
    # M micro-batches drain through S stages in M + S - 1 ticks
    assert gpipe_ticks(1, 4) == 4
    assert gpipe_ticks(4, 1) == 4
    assert gpipe_ticks(2, 3) == 4
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    # more micro-batches amortize the bubble monotonically
    fracs = [bubble_fraction(4, m) for m in (1, 2, 4, 8, 16)]
    assert fracs == sorted(fracs, reverse=True)
    # tick count times per-tick work bounds the ideal speedup
    assert gpipe_ticks(4, 16) == 19          # vs 64 sequential stage calls


# --------------------------------------- pipeline grads vs stacked model
SCRIPT_GRADS = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.collectives import shard_map
from repro.core.pipeline import (bubble_fraction, gpipe_forward,
                                 gpipe_ticks, stacked_forward)
from repro.parallel import make_tiny_transformer

D_MODEL, FF = 8, 16
KEY = jax.random.PRNGKey(7)

def run_case(n_stages, n_micro, mb):
    params, model = make_tiny_transformer(n_stages, D_MODEL, FF,
                                          seed=n_stages)
    stage_fn = lambda sp, x: model.stage_fn(sp, x)
    x = jax.random.normal(KEY, (n_micro, mb, D_MODEL))
    tgt = jax.random.normal(jax.random.fold_in(KEY, 1),
                            (n_micro, mb, D_MODEL))

    # ---- reference: unpipelined stacked forward + MSE loss and grads
    def ref_loss(p):
        y = stacked_forward(stage_fn, p, x)
        return jnp.mean((y - tgt) ** 2)
    l_ref, g_ref = jax.value_and_grad(ref_loss)(params)

    # ---- pipelined: shard_map over the stage axis, loss on last stage
    mesh = Mesh(np.array(jax.devices()[:n_stages]), ("stage",))
    def body(stacked):
        sp = stacked            # [chunk=1 layers...] via stage sharding
        def loss_fn(pl):
            outs = gpipe_forward(
                lambda spp, xx: stage_fn(
                    jax.tree.map(lambda l: l[0], spp), xx), pl, x, "stage")
            l = jnp.mean((outs - tgt) ** 2)
            me = jax.lax.axis_index("stage")
            from repro.parallel.staged import tensor_reduce
            l = jnp.where(me == n_stages - 1, l, 0.0)
            return tensor_reduce("stage")(l)
        return jax.value_and_grad(loss_fn)(sp)
    spec = jax.tree.map(lambda _: P("stage"), params)
    fn = shard_map(body, mesh=mesh, in_specs=(spec,),
                   out_specs=(P(), spec), check_vma=False)
    l_pipe, g_pipe = jax.jit(fn)(params)

    ld = abs(float(l_ref) - float(l_pipe))
    gd = max(float(jnp.max(jnp.abs(a - b))) for a, b in
             zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pipe)))
    assert ld <= 1e-5, (n_stages, n_micro, ld)
    assert gd <= 1e-5, (n_stages, n_micro, gd)
    # bubble accounting: the executed schedule ran exactly
    # gpipe_ticks(S, M) ticks, of which (S-1)/(M+S-1) are idle
    ticks = gpipe_ticks(n_stages, n_micro)
    assert ticks == n_micro + n_stages - 1
    assert 0 <= bubble_fraction(n_stages, n_micro) < 1
    print(f"GRAD-OK S={n_stages} M={n_micro} ticks={ticks} "
          f"bubble={bubble_fraction(n_stages, n_micro):.3f} "
          f"ld={ld:.1e} gd={gd:.1e}")

# divisible and NON-divisible micro counts, 2 and 4 stages
for n_stages, n_micro in ((2, 1), (2, 3), (2, 4), (4, 3), (4, 6)):
    run_case(n_stages, n_micro, mb=4)
print("PIPELINE-GRADS-OK")
"""


def test_gpipe_grads_match_stacked_model(multidevice):
    out = multidevice(SCRIPT_GRADS, 4)
    assert out.count("GRAD-OK") == 5
    assert "PIPELINE-GRADS-OK" in out
